"""Optional-hypothesis shim for the property-test modules.

`hypothesis` is a [test]-extra dependency (see pyproject.toml), not a runtime
one. When it is absent the suite must still *collect* — only the property
tests themselves should skip. Importing `given`/`settings`/`st` from here
instead of from `hypothesis` directly gives exactly that: with hypothesis
installed this module is a pure re-export; without it, `@given` turns the
test into a skip and the strategy expressions evaluate to inert stubs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Evaluates any `st.<name>(...)` expression to an inert placeholder."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _StrategyStub()
