"""Batched ShiftAddViT serving: the inference fast path, the shape-bucketed
engine (no recompilation after warmup — the acceptance criterion), and the
policy sweep's modeled-energy ordering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import DENSE, SHIFTADD, STAGE1
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve.vision import (BucketedViTEngine, build_policy_model,
                                policy_sweep, vit_energy_per_image)


def _vit(policy=DENSE, **kw):
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64, policy=policy, **kw)
    model = ShiftAddViT(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def _imgs(n, seed=0, size=16):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, size, size, 3))


@pytest.mark.parametrize("policy", [DENSE, STAGE1, SHIFTADD])
def test_infer_matches_train_false_call(policy):
    """The aux-free fast path must compute the same logits as the full
    forward with train=False (router noise off, clean-logit argmax).

    The MoE arm runs at ample capacity: serving plans capacity PER IMAGE
    (batch-invariance contract) while the training forward plans it over
    the flattened co-batch, so the two paths agree exactly when no token is
    dropped in either grouping — every token then goes through its top-1
    expert with its clean gate regardless of capacity-domain boundaries.
    (Under tight capacity the drop SETS legitimately differ; that serving
    semantics change is pinned by tests/test_batch_invariance.py instead.)"""
    model, params, _ = _vit(policy, moe_capacity=8.0)
    imgs = _imgs(6)
    fast = model.infer(params, imgs)
    full, _aux = model(params, imgs, train=False)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_infer_deterministic_without_rng():
    """Two inference calls, identical logits, no rng anywhere — pins the
    no-noise/no-sampling property the serving engine relies on."""
    model, params, _ = _vit(SHIFTADD)
    imgs = _imgs(8, seed=3)
    a = model.infer(params, imgs)
    b = model.infer(params, imgs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_padding_is_transparent():
    """Padded rows must not change real images' logits. Stage-1 policy is
    MoE-free, so per-image independence is exact and the engine's padded
    bucket must agree with a direct unpadded forward."""
    model, params, _ = _vit(STAGE1)
    engine = BucketedViTEngine(model, params, buckets=(1, 4, 8))
    imgs = _imgs(5, seed=7)
    got = engine.infer(imgs)                       # padded to bucket 8
    want = model.infer(params, imgs)               # unpadded batch of 5
    assert got.shape == (5, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_no_recompilation_after_warmup():
    """Mixed request sizes over warm buckets must never retrace — the
    compile-count acceptance criterion."""
    model, params, _ = _vit(SHIFTADD)
    engine = BucketedViTEngine(model, params, buckets=(1, 4, 8)).warmup()
    assert engine.trace_count == 3                 # one program per bucket
    for i, n in enumerate((3, 1, 8, 5, 2, 7, 20)):  # 20 > max bucket: chunked
        out = engine.infer(_imgs(n, seed=20 + i))
        assert out.shape == (n, 10)
    # Non-float32 client input must be canonicalized, not retraced.
    engine.infer(jnp.zeros((4, 16, 16, 3), jnp.uint8))
    engine.infer(jnp.zeros((2, 16, 16, 3), jnp.bfloat16))
    assert engine.trace_count == 3, "bucketed serving retraced after warmup"


def test_engine_bucket_selection_and_chunking():
    model, params, _ = _vit(DENSE)
    engine = BucketedViTEngine(model, params, buckets=(1, 4, 8))
    assert engine.bucket_for(1) == 1
    assert engine.bucket_for(3) == 4
    assert engine.bucket_for(8) == 8
    assert engine.bucket_for(30) == 8              # chunked by infer()
    out = engine.infer(_imgs(19))
    assert out.shape == (19, 10)
    assert engine.images_served == 19
    assert engine.batches_served == 3              # 8 + 8 + 3→bucket 4
    assert engine.padded_images_served == 20       # 8 + 8 + bucket 4
    assert engine.padding_waste == pytest.approx(1 - 19 / 20)


def test_engine_surfaces_effective_buckets():
    """The engine normalizes (sorts, dedups) its bucket set and surfaces it;
    records/gates read it from here instead of re-declaring."""
    model, params, _ = _vit(DENSE)
    engine = BucketedViTEngine(model, params, buckets=(8, 1, 4, 4))
    assert engine.buckets == (1, 4, 8)


@pytest.mark.parametrize("policy", [DENSE, STAGE1, SHIFTADD])
@pytest.mark.parametrize("image_size", [28, 32])
def test_frozen_unfrozen_exact_logit_parity(policy, image_size):
    """The acceptance criterion: inference on the DeployPlan's frozen params
    must produce BIT-IDENTICAL logits to inference on the live params, for
    all three policies, on both odd (28px → 49 tokens, DeiT-style
    non-aligned) and aligned (32px → 64 tokens) shapes."""
    cfg = ViTConfig(image_size=image_size, patch_size=4, n_layers=2,
                    d_model=32, n_heads=2, d_ff=64, policy=policy)
    model = ShiftAddViT(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = model.prepare_inference(params, impl="xla")
    imgs = _imgs(5, seed=11, size=image_size)
    unfrozen = model.infer(params, imgs)
    frozen = model.infer(plan.params, imgs)
    np.testing.assert_array_equal(np.asarray(unfrozen), np.asarray(frozen))


def test_frozen_unfrozen_engine_parity():
    """Both engine arms (freeze on/off) must serve identical logits — the
    freeze benchmark's A/B arms measure the same function."""
    model, params, _ = _vit(SHIFTADD)
    imgs = _imgs(6, seed=13)
    e_frozen = BucketedViTEngine(model, params, buckets=(8,), freeze=True)
    e_live = BucketedViTEngine(model, params, buckets=(8,), freeze=False)
    np.testing.assert_array_equal(np.asarray(e_frozen.infer(imgs)),
                                  np.asarray(e_live.infer(imgs)))
    assert e_frozen.frozen and e_frozen.plan is not None
    assert not e_live.frozen and e_live.plan is None
    # Shiftadd stage 2: 4 projections + 2 shift-expert linears per layer.
    assert e_frozen.plan.frozen_linears == 2 * (4 + 2)


def test_frozen_engine_trace_count_stays_flat():
    """Regression: the jitted forward closes over the plan as constants —
    mixed warm-bucket traffic must never retrace (the closed-over params must
    not become fresh tracers per call)."""
    model, params, _ = _vit(SHIFTADD)
    engine = BucketedViTEngine(model, params, buckets=(1, 4, 8),
                               freeze=True).warmup()
    assert engine.trace_count == 3
    for i, n in enumerate((2, 8, 1, 5, 12)):
        out = engine.infer(_imgs(n, seed=40 + i))
        assert out.shape == (n, 10)
    engine.infer(jnp.zeros((3, 16, 16, 3), jnp.uint8))
    assert engine.trace_count == 3, "frozen engine retraced after warmup"


def test_interpret_impl_frozen_close():
    """The interpret (Pallas-body) frozen path serves logits close to the
    xla frozen path — CI forces this arm with --impl interpret. (Not exact:
    the packed kernel contracts in bf16 on the MXU dataflow.)"""
    model, params, _ = _vit(SHIFTADD)
    imgs = _imgs(4, seed=17)
    want = model.infer(model.prepare_inference(params, impl="xla").params,
                       imgs, impl="xla")
    # impl threads explicitly end-to-end — no set_default_impl process
    # global (the old override leaked "interpret" into any engine compiled
    # concurrently; tests/test_autotune.py pins the jaxpr-level contract).
    got = model.infer(
        model.prepare_inference(params, impl="interpret").params, imgs,
        impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_modeled_energy_ordering():
    """The analytic energy model must reproduce the paper's ordering on the
    default config: each reparameterization stage strictly cuts energy."""
    cfg = ViTConfig()
    e = {name: vit_energy_per_image(dataclasses.replace(cfg, policy=p))
         for name, p in (("dense", DENSE), ("stage1", STAGE1),
                         ("shiftadd", SHIFTADD))}
    assert e["shiftadd"]["total_pj"] < e["stage1"]["total_pj"] < e["dense"]["total_pj"]


def test_policy_sweep_record_shape_and_energy_claim():
    """The BENCH_vit.json record: all three policy arms with latency+energy,
    shiftadd strictly below dense in modeled energy, zero recompiles."""
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64)
    rec = policy_sweep(cfg, batch=8, iters=2, buckets=(8,))
    assert set(rec["policies"]) == {"dense", "stage1", "shiftadd"}
    assert rec["buckets"] == [8]                # engine-surfaced, not redeclared
    for r in rec["policies"].values():
        assert r["latency_s_per_batch"] > 0
        assert r["images_per_s"] > 0
        assert r["energy_pj_per_image"] > 0
        assert r["recompiles_after_warmup"] == 0
        # Shared BENCH_* summary schema (serve.metrics) + engine-read buckets.
        assert {"p50_s", "p95_s", "p99_s", "mean_s"} <= set(r["latency"])
        assert r["latency"]["p50_s"] <= r["latency"]["p99_s"]
        assert r["buckets"] == rec["buckets"]
        assert r["padding_waste"] == 0.0        # batch == bucket: no padding
    assert (rec["policies"]["shiftadd"]["energy_pj_per_image"]
            < rec["policies"]["dense"]["energy_pj_per_image"])


def test_sweep_arms_share_pretrained_weights():
    """Every sweep arm must be a conversion of the SAME dense weights —
    the paper's reparameterize-not-retrain premise."""
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64)
    dense_model = ShiftAddViT(dataclasses.replace(cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(0))
    _, s1 = build_policy_model(cfg, "stage1", dense_model, dense_params)
    _, s2 = build_policy_model(cfg, "shiftadd", dense_model, dense_params)
    w = np.asarray(dense_params["blocks"][0]["mixer"]["q"]["kernel"])
    np.testing.assert_array_equal(
        w, np.asarray(s1["blocks"][0]["mixer"]["q"]["kernel"]))
    # shiftadd projections are shift-reparameterized latents of the same w
    np.testing.assert_array_equal(
        w, np.asarray(s2["blocks"][0]["mixer"]["q"]["w_latent"]))
