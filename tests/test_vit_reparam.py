"""The paper's home model: ShiftAddViT forward/loss + two-stage
reparameterization from a pretrained dense ViT (paper §4, App. E)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reparam
from repro.core.policy import ShiftAddPolicy, DENSE, SHIFTADD, STAGE1, ALL_SHIFT
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig


def _vit(policy=DENSE, **kw):
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64, policy=policy, **kw)
    model = ShiftAddViT(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def test_vit_forward_and_loss():
    model, params, cfg = _vit()
    data = SyntheticImageData(image_size=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("policy", [STAGE1, ALL_SHIFT, SHIFTADD])
def test_vit_policies_train(policy):
    model, params, cfg = _vit(policy=policy)
    data = SyntheticImageData(image_size=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))


def test_two_stage_reparam_structure():
    dense_model, dense_params, _ = _vit(DENSE)
    sa_model, _, _ = _vit(SHIFTADD)
    converted = sa_model.convert_from(dense_model, dense_params, stage=2)
    counts = reparam.count_reparameterized(converted)
    assert counts["shift_latent"] > 0
    # Converted params must run through the shiftadd model.
    imgs = jnp.asarray(SyntheticImageData(image_size=16, global_batch=4)
                       .batch_at(0)["images"])
    logits, aux = sa_model(converted, imgs, train=False)
    assert np.isfinite(np.asarray(logits)).all()


def test_reparam_mult_expert_inherits_pretrained_mlp():
    """In the converted MoE, the Mult expert must be the pretrained MLP."""
    dense_model, dense_params, _ = _vit(DENSE)
    sa_model, _, _ = _vit(SHIFTADD)
    converted = sa_model.convert_from(dense_model, dense_params, stage=2)
    w_src = np.asarray(dense_params["blocks"][0]["feed"]["up"]["kernel"])
    w_dst = np.asarray(converted["blocks"][0]["feed"]["experts"][0]["up"]["kernel"])
    np.testing.assert_array_equal(w_src, w_dst)
    # Shift expert carries the latent copy of the same weights.
    w_shift = np.asarray(
        converted["blocks"][0]["feed"]["experts"][1]["up"]["w_latent"])
    np.testing.assert_array_equal(w_src, w_shift)


def test_stage1_conversion_preserves_mlp():
    dense_model, dense_params, _ = _vit(DENSE)
    s1_model, _, _ = _vit(STAGE1)
    converted = s1_model.convert_from(dense_model, dense_params, stage=1)
    w_src = np.asarray(dense_params["blocks"][0]["feed"]["up"]["kernel"])
    w_dst = np.asarray(converted["blocks"][0]["feed"]["up"]["kernel"])
    np.testing.assert_array_equal(w_src, w_dst)


def test_stage1_conversion_matches_pure_linear_attention_at_init():
    """The dwconv-zero-init invariant: a stage-1 converted model's forward at
    init must equal the pure (binary-)linear attention of the pretrained
    weights — i.e. the same policy WITHOUT the DWConv branch run directly on
    the unconverted dense params."""
    dense_model, dense_params, _ = _vit(DENSE)
    s1_model, _, _ = _vit(STAGE1)                     # dwconv_v=True (default)
    converted = s1_model.convert_from(dense_model, dense_params, stage=1)
    nodw_model, _, _ = _vit(dataclasses.replace(STAGE1, dwconv_v=False))
    imgs = jnp.asarray(SyntheticImageData(image_size=16, global_batch=4)
                       .batch_at(0)["images"])
    got, _ = s1_model(converted, imgs, train=False)
    want, _ = nodw_model(dense_params, imgs, train=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_stage2_moe_preserves_mult_expert_forward_exactly():
    """The converted MoE's Mult expert must BE the pretrained MLP: identical
    forward on arbitrary token batches, bit for bit."""
    dense_model, dense_params, _ = _vit(DENSE)
    sa_model, _, _ = _vit(SHIFTADD)
    converted = sa_model.convert_from(dense_model, dense_params, stage=2)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 9, 32))
    for i in range(len(sa_model.blocks)):
        mult = sa_model.blocks[i].feed.experts[0]
        got = mult(converted["blocks"][i]["feed"]["experts"][0], x)
        want = dense_model.blocks[i].feed(dense_params["blocks"][i]["feed"], x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_converted_inference_mode_is_deterministic():
    """Inference forward on a converted model: two calls, identical logits,
    no rng required (clean-logit argmax routing end to end)."""
    dense_model, dense_params, _ = _vit(DENSE)
    sa_model, _, _ = _vit(SHIFTADD)
    converted = sa_model.convert_from(dense_model, dense_params, stage=2)
    imgs = jnp.asarray(SyntheticImageData(image_size=16, global_batch=4)
                       .batch_at(0)["images"])
    a = sa_model.infer(converted, imgs)
    b = sa_model.infer(converted, imgs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(np.asarray(a)).all()


def test_stage0_conversion_is_identity():
    dense_model, dense_params, _ = _vit(DENSE)
    sa_model, _, _ = _vit(SHIFTADD)
    out = sa_model.convert_from(dense_model, dense_params, stage=0)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(dense_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shift_packed_roundtrip_function():
    """latent → packed freeze preserves the quantized forward exactly."""
    from repro.core.shift_linear import ShiftLinear

    sl = ShiftLinear(16, 8)
    p = sl.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    y_latent = sl(p, x)
    sl_packed = ShiftLinear(16, 8, mode="packed")
    y_packed = sl_packed(sl.freeze(p), x)
    np.testing.assert_allclose(np.asarray(y_latent), np.asarray(y_packed),
                               rtol=1e-5, atol=1e-5)
