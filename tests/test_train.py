"""Training-step semantics: microbatch equivalence, compression convergence,
optimizer behavior, frozen packed weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticLMData
from repro.nn.model import LanguageModel
from repro.optim.optimizer import adamw, cosine_schedule, global_norm
from repro.train.step import init_train_state, make_train_step


def _setup(**tkw):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", scan_layers=True, remat="none")
    t = dict(learning_rate=1e-3, warmup_steps=2, total_steps=50,
             global_batch=8, seq_len=16)
    t.update(tkw)
    tcfg = TrainConfig(**t)
    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                           seed=1)
    return model, tcfg, data


def test_microbatch_equivalence():
    """Gradient accumulation over 4 microbatches == single big batch."""
    model, tcfg1, data = _setup(microbatch=None)
    _, tcfg4, _ = _setup(microbatch=4)
    state = init_train_state(model, tcfg1, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1, m1 = make_train_step(model, tcfg1)(state, batch)
    state2 = init_train_state(model, tcfg4, jax.random.PRNGKey(0))
    s4, m4 = make_train_step(model, tcfg4)(state2, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_int8_ef_compression_converges_close_to_uncompressed():
    model, tcfg, data = _setup(total_steps=40, learning_rate=3e-3)
    _, tcfg_c, _ = _setup(total_steps=40, learning_rate=3e-3,
                          grad_compression="int8_ef")

    def run(tc):
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, tc))
        for i in range(tc.total_steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
        return float(metrics["loss"])

    base = run(tcfg)
    comp = run(tcfg_c)
    assert comp < base + 0.25, (base, comp)


def test_adamw_decoupled_weight_decay():
    opt = adamw(0.1, weight_decay=0.5, clip_norm=None)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    new_p, _ = opt.update({"w": jnp.zeros((4,))}, state, params)
    # zero grad ⇒ pure decay: p - lr*wd*p
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1 - 0.1 * 0.5,
                               rtol=1e-6)


def test_grad_clipping():
    opt = adamw(1e-3, weight_decay=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    big = {"w": jnp.full((4,), 100.0)}
    _, st = opt.update(big, state, params)
    assert float(global_norm(st.m)) <= (1 - 0.9) * 1.0 + 1e-5


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(s(55)) < float(s(20))


def test_packed_weights_frozen_under_optimizer():
    """int-dtype leaves (deployment shift weights) must not be updated."""
    from repro.core.shift_linear import ShiftLinear

    sl = ShiftLinear(8, 4, mode="packed")
    params = {"lin": sl.init(jax.random.PRNGKey(0))}
    opt = adamw(0.1)
    state = opt.init(params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) if jnp.issubdtype(p.dtype, jnp.inexact)
        else p, params)
    new_p, _ = opt.update(grads, state, params)
    np.testing.assert_array_equal(np.asarray(new_p["lin"]["w_packed"]),
                                  np.asarray(params["lin"]["w_packed"]))
