"""A hazard-free module — the lint must report NOTHING here."""
import jax
import jax.numpy as jnp


@jax.jit
def clean_step(x):
    return jnp.tanh(x) * 2.0


def make_decode(model):
    def decode(params, tok, cache):
        return tok + 1, cache

    return jax.jit(decode, donate_argnums=(2,))


def infer_clean(params, x):
    return clean_step(x)
