"""Planted AST-lint violations — NEVER imported by runtime code.

Each function below plants exactly one rule violation; test_analysis.py
asserts the lint reports this file's violations and nothing else. The
`allowed_counter` case plants an LT004 hit WITH an inline waiver, asserting
the suppression mechanism works.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def numpy_on_traced(x):          # LT001
    return jnp.sin(np.asarray(x))


@jax.jit
def host_sync_item(x):           # LT002 (.item())
    return x.sum().item()


@jax.jit
def host_sync_float(x):          # LT002 (float(param))
    return float(x) * 2.0


def infer_with_rng(params, x, rng):   # LT003 (rng parameter on infer*)
    return x + jax.random.normal(rng, x.shape)


class StatefulModule:
    def __init__(self):
        self.calls = 0

    def make_step(self):
        @jax.jit
        def step(x):             # LT004 (trace-time self mutation)
            self.calls += 1
            return x * 2

        return step

    def make_counted_step(self):
        @jax.jit
        def step(x):
            # LT004 planted WITH a waiver — must NOT be reported:
            self.calls += 1  # lint: allow(LT004 deliberate compile counter)
            return x * 2

        return step


def decode_step_fn(params, tok, cache):
    return tok, cache


undonated = jax.jit(decode_step_fn)   # LT005 (cache param, no donation)
