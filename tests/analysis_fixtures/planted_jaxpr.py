"""Planted jaxpr-audit violations — functions test_analysis.py traces with
`jax.make_jaxpr` and feeds to `audit_closed_jaxpr`, asserting each reports
exactly its planted rule.
"""
import jax
import jax.numpy as jnp


def callback_under_jit(x):       # JX001: debug print = host callback
    jax.debug.print("x = {}", x)
    return x * 2


def weak_boundary(x):            # JX003: weak scalar escapes a pjit boundary

    @jax.jit
    def inner(v):
        return jnp.where(v >= 0, 1.0, -1.0).astype(v.dtype)  # weak branches

    return inner(x)


def rng_in_infer(x):             # JX006: rng primitive on an infer path
    key = jax.random.PRNGKey(0)
    return x + jax.random.normal(key, x.shape)


def float_scatter_add(x):        # JX007: nondeterministic float scatter-add
    idx = jnp.zeros((x.shape[0],), jnp.int32)
    return jnp.zeros((4,), x.dtype).at[idx].add(x)


def f64_promotion(x):            # JX002 (trace under enable_x64)
    return x.astype("float64") * 2.0
