"""Data pipeline determinism + analytic energy model sanity."""
import numpy as np
import pytest

from repro.core import energy
from repro.data.pipeline import SyntheticImageData, SyntheticLMData


def test_lm_data_deterministic_per_step():
    d1 = SyntheticLMData(64, 16, 4, seed=5)
    d2 = SyntheticLMData(64, 16, 4, seed=5)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(d1.batch_at(18)["inputs"], b1["inputs"])


def test_lm_data_learnable_structure():
    d = SyntheticLMData(64, 64, 8, seed=0)
    b = d.batch_at(0)
    follows = np.mean(d.perm[b["inputs"]] == b["labels"])
    assert follows > 0.7  # ~80% bigram-following by construction


def test_image_data_places_object():
    d = SyntheticImageData(image_size=16, n_classes=3, global_batch=4, patch=8)
    b = d.batch_at(0)
    assert b["images"].shape == (4, 16, 16, 3)
    y, x = b["object_yx"][0]
    patch = b["images"][0, y:y + 8, x:x + 8]
    assert patch.std() > b["images"][0].std() * 0.5


def test_energy_hierarchy_matches_paper_table1():
    """Paper Tab. 1: shift and add are orders cheaper than mult."""
    m = energy.matmul_energy(64, 64, 64, "fp32")
    a = energy.add_matmul_energy(64, 64, 64)
    s = energy.shift_matmul_energy(64, 64, 64)
    assert a.compute_pj < m.compute_pj / 10
    assert s.compute_pj < m.compute_pj / 10
    # data movement also drops (int8 operands)
    assert a.dram_pj < m.dram_pj
    assert s.dram_pj < m.dram_pj


def test_latency_estimates_order():
    """Shift expert faster than Mult (packed weights, int8 MXU path) — this
    ordering drives α_i and the capacity split."""
    lm = energy.mlp_latency_estimate(1024, 512, 2048, "mult")
    ls = energy.mlp_latency_estimate(1024, 512, 2048, "shift")
    assert ls < lm


def test_psum_bytes_accounting():
    from repro.distributed.collectives import psum_bytes

    assert psum_bytes((4, 4), np.float32) == 64
    assert psum_bytes((4, 4), np.float32, compressed=True) == 16
