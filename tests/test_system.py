"""End-to-end behaviour: the paper's full pipeline on a learnable synthetic
task — pretrain dense ViT → two-stage ShiftAdd reparameterization → finetune
→ accuracy recovers (the system-level claim of the paper, at CPU scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import DENSE, SHIFTADD
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw


def _train(model, params, data, steps, lr=3e-3, seed=0):
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, metrics

    metrics = None
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()
                 if k != "object_yx"}
        params, state, metrics = step(params, state, batch)
    return params, metrics


def _eval_acc(model, params, data, steps=5, offset=1000):
    accs = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(offset + i).items()
                 if k != "object_yx"}
        _, m = model.loss(params, batch, train=False)
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


@pytest.mark.slow
def test_end_to_end_pretrain_reparam_finetune():
    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=2,
                    d_model=48, n_heads=2, d_ff=96)
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32,
                              seed=7)
    dense = ShiftAddViT(cfg)
    dparams = dense.init(jax.random.PRNGKey(0))
    dparams, _ = _train(dense, dparams, data, steps=150)
    acc_dense = _eval_acc(dense, dparams, data)
    assert acc_dense > 0.6, f"dense baseline failed to learn: {acc_dense}"

    # Two-stage reparameterization (the paper's deployment story).
    sa_cfg = ViTConfig(**{**cfg.__dict__, "policy": SHIFTADD})
    sa = ShiftAddViT(sa_cfg)
    sparams = sa.convert_from(dense, dparams, stage=2)
    acc_sa_0 = _eval_acc(sa, sparams, data)
    # Finetune at a conservative LR (the paper finetunes at 1e-5; higher
    # rates destabilize the freshly reparameterized model — at 3e-4 this
    # run's loss recovers to ~0.44 by step 60 and then blows up to NaN by
    # step 79, collapsing accuracy to chance: the power-of-two shift
    # weights make the post-conversion loss surface sharper than the dense
    # one, so the dense pretraining LR/10 is already past the edge of
    # stability here).
    sparams, _ = _train(sa, sparams, data, steps=80, lr=1e-4)
    acc_sa = _eval_acc(sa, sparams, data)
    # Finetuning must recover accuracy close to dense (paper Tab. 2/3).
    assert acc_sa > acc_dense - 0.2, (acc_dense, acc_sa_0, acc_sa)


def test_lm_loss_decreases_end_to_end():
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.data.pipeline import SyntheticLMData
    from repro.nn.model import LanguageModel
    from repro.train import train_loop

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=48,
                      n_heads=2, n_kv_heads=2, d_ff=96, vocab_size=64,
                      dtype="float32", scan_layers=True, remat="none",
                      policy=SHIFTADD, moe_primitives_capacity=2.0)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40,
                       global_batch=8, seq_len=32)
    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                           seed=11)
    state, hist = train_loop(model, tcfg, data)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3
