"""The trip-count-corrected HLO analyzer must agree with unrolled ground truth
(this is the §Roofline 'profiler'; XLA's own cost_analysis counts loop bodies
once — verified here so the methodology stays honest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _flops(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):        # jax<=0.4.x: one entry per computation
        cost = cost[0]
    return H.analyze(comp.as_text()), cost


def test_scan_flops_match_unrolled():
    d = 64
    x = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, d, d), jnp.float32)

    def unrolled(x, ws):
        for i in range(6):
            x = jnp.tanh(x @ ws[i])
        return x

    def scanned(x, ws):
        return jax.lax.scan(lambda x, w: (jnp.tanh(x @ w), None), x, ws)[0]

    cu, _ = _flops(unrolled, x, ws)
    cs, xla = _flops(scanned, x, ws)
    analytic = 2 * 8 * d * d * 6
    assert cu.flops == pytest.approx(analytic, rel=0.01)
    assert cs.flops == pytest.approx(analytic, rel=0.01)
    # and XLA undercounts the scanned one (the reason this module exists)
    assert xla["flops"] < analytic * 0.5


def test_nested_scan_trip_multiplication():
    d = 32
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, d, d), jnp.float32)

    def nested(x, ws):
        def outer(x, w):
            def inner(x, _):
                return x @ w, None
            return jax.lax.scan(inner, x, jnp.arange(5))[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c, _ = _flops(nested, x, ws)
    assert c.flops == pytest.approx(2 * 4 * d * d * 3 * 5, rel=0.01)


def test_collective_bytes_parsed(tmp_path):
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as sl
        from repro.launch import hlo_analysis as H
        mesh = sl.make_mesh((4,), ("model",))
        def f(x, w):
            return x @ w                       # contraction over sharded dim
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 16), jnp.float32)
        with mesh:
            comp = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P("model", None)))).lower(x, w).compile()
        c = H.analyze(comp.as_text())
        assert c.collective_bytes > 0, "expected an all-reduce"
        assert "all-reduce" in c.collective_breakdown
        print("COLL", c.collective_bytes)
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL" in out.stdout


def test_dot_flops_from_shapes():
    txt = """
HloModule m
ENTRY %main.1 (p0: f32[8,32], p1: f32[32,16]) -> f32[8,16] {
  %p0 = f32[8,32]{1,0} parameter(0)
  %p1 = f32[32,16]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    c = H.analyze(txt)
    assert c.flops == 2 * 8 * 32 * 16
