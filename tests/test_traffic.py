"""Traffic generator + micro-batch scheduler invariants (pure logic — no
model in the loop; engine-in-the-loop coverage is tests/test_traffic_serve.py).
"""
import numpy as np
import pytest

from repro.serve.metrics import latency_summary, padding_waste
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.traffic import (DEADLINE_CLASSES, SCENARIOS, Request,
                                 default_budgets, make_trace)

BUDGETS = {"interactive": 1.0, "standard": 2.0, "relaxed": 5.0}


def _trace(scenario="poisson", n=200, seed=0, **kw):
    kw.setdefault("target_images_per_s", 100.0)
    kw.setdefault("budgets_s", BUDGETS)
    return make_trace(scenario, n, seed, **kw)


def _req(rid, t, size=1, klass="standard", budget=2.0):
    return Request(rid=rid, arrival_s=t, size=size, klass=klass,
                   deadline_s=t + budget, seed=rid)


def _sched(buckets=(1, 4, 8), svc=0.1, **kw):
    model = {b: svc * (0.5 + 0.5 * b / max(buckets)) for b in buckets}
    return MicroBatchScheduler(buckets, model, **kw)


# -- trace generator --------------------------------------------------------

@pytest.mark.parametrize("scenario", SCENARIOS)
def test_trace_seeded_determinism(scenario):
    a = _trace(scenario)
    b = _trace(scenario)
    assert a.requests == b.requests
    c = _trace(scenario, seed=1)
    assert c.requests != a.requests


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_trace_structure(scenario):
    tr = _trace(scenario, n=300)
    arr = [r.arrival_s for r in tr.requests]
    assert arr == sorted(arr) and arr[0] > 0
    assert {r.klass for r in tr.requests} <= set(DEADLINE_CLASSES)
    for r in tr.requests:
        assert r.size >= 1
        assert r.deadline_s == pytest.approx(r.arrival_s + BUDGETS[r.klass])
    # Offered image rate lands on the target: exactly for the renormalized
    # modulated scenarios, law-of-large-numbers close for raw Poisson.
    rate = tr.total_images / tr.horizon_s
    assert rate == pytest.approx(100.0,
                                 rel=0.25 if scenario == "poisson" else 1e-6)


def test_trace_oversize_and_max_size():
    tr = _trace("poisson", n=500, max_size=8, oversize_prob=0.1)
    sizes = np.array([r.size for r in tr.requests])
    assert sizes.max() > 8           # some oversize requests were drawn
    assert (sizes[sizes <= 8] >= 1).all()
    none = _trace("poisson", n=200, max_size=8, oversize_prob=0.0)
    assert max(r.size for r in none.requests) <= 8


def test_bursty_has_idle_gaps_poisson_does_not():
    gaps = lambda tr: np.diff([0.0] + [r.arrival_s for r in tr.requests])
    mean_p = gaps(_trace("poisson", n=400)).mean()
    g_b = gaps(_trace("bursty", n=400))
    # The on/off process produces gaps far beyond anything a same-rate
    # Poisson process plausibly emits relative to its own mean.
    assert g_b.max() > 4 * mean_p


def test_diurnal_ramps_rate_mid_trace():
    tr = _trace("diurnal", n=900)
    gaps = np.diff([0.0] + [r.arrival_s for r in tr.requests])
    third = len(gaps) // 3
    edge = np.concatenate([gaps[:third], gaps[-third:]]).mean()
    mid = gaps[third:2 * third].mean()
    assert mid < edge                # faster arrivals at the peak


def test_default_budgets_scale_with_service():
    b1, b2 = default_budgets(0.1), default_budgets(0.2)
    for k in DEADLINE_CLASSES:
        assert b2[k] == pytest.approx(2 * b1[k])
        assert b1[k] > 0


# -- scheduler: ordering ----------------------------------------------------

def test_fifo_within_deadline_class():
    """Within one class, dispatch order is arrival order — across many
    batches and interleaved classes."""
    s = _sched(buckets=(1, 4, 8))
    rid = 0
    for i in range(20):
        klass = DEADLINE_CLASSES[i % 3]
        s.offer(_req(rid, t=0.01 * i, klass=klass,
                     budget=BUDGETS[klass]), now=0.01 * i)
        rid += 1
    order = {k: [] for k in DEADLINE_CLASSES}
    t = 1.0
    while s.has_queued():
        batch = s.form_batch(t, drain=True)
        for p in batch.parts:
            order[p.req.klass].append(p.rid)
        t += 0.01
    for k, rids in order.items():
        assert rids == sorted(rids), f"class {k} served out of arrival order"


def test_heads_fill_by_earliest_deadline():
    s = _sched(buckets=(1, 4, 8))
    s.offer(_req(0, t=0.0, klass="relaxed", budget=5.0), now=0.0)
    s.offer(_req(1, t=0.1, klass="interactive", budget=1.0), now=0.1)
    batch = s.form_batch(10.0, drain=True)
    # interactive head (deadline 1.1) outranks the earlier-arrived relaxed
    # head (deadline 5.0) — but both fit, and FIFO within class holds.
    assert [p.rid for p in batch.parts] == [1, 0]


# -- scheduler: fill-or-deadline triggers -----------------------------------

def test_fill_dispatches_immediately():
    s = _sched(buckets=(1, 4, 8))
    for i in range(8):
        s.offer(_req(i, t=0.0), now=0.0)
    batch = s.form_batch(0.0)
    assert batch is not None and batch.reason == "fill"
    assert batch.n_images == 8 and batch.bucket == 8 and batch.padding == 0


def test_partial_waits_until_linger_then_pads():
    s = _sched(buckets=(1, 4, 8), linger_s=0.5, slack_s=0.01)
    s.offer(_req(0, t=0.0, size=3, budget=100.0), now=0.0)
    assert s.form_batch(0.0) is None          # no trigger yet
    assert s.form_batch(0.49) is None
    assert s.next_forced_dispatch_s() == pytest.approx(0.5)
    batch = s.form_batch(0.5)
    assert batch is not None and batch.reason == "linger"
    assert batch.n_images == 3 and batch.bucket == 4 and batch.padding == 1


def test_deadline_slack_forces_before_linger():
    svc_max = 0.1           # _sched's service model at the max bucket
    s = _sched(buckets=(1, 4, 8), linger_s=100.0, slack_s=0.1)
    s.offer(_req(0, t=0.0, size=2, budget=1.0), now=0.0)
    forced = s.next_forced_dispatch_s()
    assert forced == pytest.approx(1.0 - svc_max - 0.1)
    assert s.form_batch(forced - 1e-6) is None
    batch = s.form_batch(forced)
    assert batch is not None and batch.reason == "deadline"


def test_infinite_thresholds_only_fill_or_drain():
    s = _sched(buckets=(1, 4, 8), linger_s=float("inf"),
               slack_s=float("inf"))
    s.offer(_req(0, t=0.0, size=2, budget=float("inf")), now=0.0)
    assert s.next_forced_dispatch_s() is None
    assert s.form_batch(1e9) is None
    batch = s.form_batch(1e9, drain=True)
    assert batch is not None and batch.reason == "drain"


# -- scheduler: admission + splitting ---------------------------------------

def test_admission_control_sheds_whole_requests():
    s = _sched(buckets=(1, 4, 8), max_queue_images=10)
    assert s.offer(_req(0, t=0.0, size=8), now=0.0)
    assert not s.offer(_req(1, t=0.0, size=4), now=0.0)   # 12 > 10: shed
    assert s.offer(_req(2, t=0.0, size=2), now=0.0)       # still fits
    assert s.shed_requests == 1 and s.shed_images == 4
    assert s.queued_images == 10 and s.admitted_requests == 2


def test_oversize_request_splits_into_max_bucket_parts():
    s = _sched(buckets=(1, 4, 8))
    s.offer(_req(0, t=0.0, size=20), now=0.0)
    assert s.queued_images == 20
    batches = []
    t = 0.0
    while s.has_queued():
        b = s.form_batch(t, drain=True)
        batches.append(b)
        t += 1.0
    assert [b.n_images for b in batches] == [8, 8, 4]
    assert [(p.part_idx, p.offset, p.size) for b in batches
            for p in b.parts] == [(0, 0, 8), (1, 8, 8), (2, 16, 4)]


def test_scheduler_is_deterministic():
    def play():
        s = _sched(buckets=(1, 4, 8), linger_s=0.3)
        log = []
        rid = 0
        for i in range(30):
            t = 0.05 * i
            klass = DEADLINE_CLASSES[i % 3]
            s.offer(_req(rid, t=t, size=1 + i % 5, klass=klass,
                         budget=BUDGETS[klass]), now=t)
            rid += 1
            b = s.form_batch(t)
            if b is not None:
                log.append((b.formed_s, b.reason, b.bucket,
                            tuple(p.rid for p in b.parts)))
        while s.has_queued():
            b = s.form_batch(100.0, drain=True)
            log.append((b.formed_s, b.reason, b.bucket,
                        tuple(p.rid for p in b.parts)))
        return log

    assert play() == play()


# -- shared metrics schema --------------------------------------------------

def test_latency_summary_schema():
    out = latency_summary([0.1, 0.2, 0.3, 0.4])
    assert set(out) == {"p50_s", "p95_s", "p99_s", "mean_s", "max_s", "n",
                        "timer_resolution_s", "method"}
    assert out["n"] == 4 and out["max_s"] == pytest.approx(0.4)
    assert out["method"] == "nearest-rank"
    assert out["p50_s"] <= out["p95_s"] <= out["p99_s"] <= out["max_s"]
    one = latency_summary([0.7])
    assert one["p50_s"] == one["p99_s"] == pytest.approx(0.7)
    empty = latency_summary([])
    assert empty["n"] == 0 and empty["p99_s"] == 0.0


def test_padding_waste():
    assert padding_waste(0, 0) == 0.0
    assert padding_waste(6, 8) == pytest.approx(0.25)
    assert padding_waste(8, 8) == 0.0
