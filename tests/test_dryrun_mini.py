"""Miniature end-to-end dry-run: the exact lower_cell machinery (shardings,
state/cache sharding trees, jit lowering, HLO analysis) on an 8-host-device
(2,4) mesh with reduced configs — the CI guard for deliverable (e)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.parametrize("arch,shape", [
    ("yi-9b", "train_4k"),
    ("qwen3-moe-30b-a3b", "prefill_32k"),
    ("recurrentgemma-9b", "decode_32k"),
    ("rwkv6-3b", "long_500k"),
    ("minicpm3-4b", "decode_32k"),
])
def test_mini_dryrun_cell(arch, shape):
    out = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        import repro.configs.shapes as shp
        from repro.configs import registry

        # shrink the grid: reduced configs, small shapes, (2,4) mesh
        registry_get = registry.get_config
        dr.get_config = lambda a, policy=None: registry_get(
            a, policy=policy, reduced=True)
        shp.SHAPES = {{
            "train_4k": shp.ShapeSpec("train_4k", "train", 64, 8),
            "prefill_32k": shp.ShapeSpec("prefill_32k", "prefill", 128, 4),
            "decode_32k": shp.ShapeSpec("decode_32k", "decode", 128, 8),
            "long_500k": shp.ShapeSpec("long_500k", "decode", 512, 2),
        }}
        dr.shp.SHAPES = shp.SHAPES
        dr.make_production_mesh = lambda multi_pod=False: mesh_mod.make_test_mesh(
            (2, 4), ("data", "model"))

        res = dr.lower_cell("{arch}", "{shape}", "single", n_micro=2)
        assert not res.get("skipped"), res
        assert res["hlo_flops_per_device"] > 0
        assert res["memory"]["temp_bytes"] >= 0
        print("CELL-OK", res["kind"], f"{{res['hlo_flops_per_device']:.3e}}")
    """)
    assert "CELL-OK" in out


def test_mini_dryrun_skip_rule():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import repro.launch.dryrun as dr
        from repro.configs import registry
        registry_get = registry.get_config
        dr.get_config = lambda a, policy=None: registry_get(a, policy=policy,
                                                            reduced=True)
        res = dr.lower_cell("hubert-xlarge", "decode_32k", "single")
        assert res["skipped"] and "encoder-only" in res["reason"]
        print("SKIP-OK")
    """)
    assert "SKIP-OK" in out
