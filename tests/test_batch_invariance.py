"""Batch-invariance property tier (ISSUE 5).

The serving contract: a given image's logits are BIT-IDENTICAL no matter
(a) which row of the batch it sits in, (b) which neighbor images it is
co-batched with, (c) which engine bucket it is padded into, and (d) whether
it is served at batch=1 or inside a batch=N — for EVERY sweep policy,
shiftadd included. Two mechanisms carry it: MoE inference plans expert
capacity PER IMAGE ROW (`nn.dispatch.group_rows` + the per-image
`capacity_plan`), so no token ever competes with another image's tokens for
expert slots; and every reduction in `ShiftAddViT.infer` is within-row
(including the explicitly row-wise classifier head). The per-image dispatch
buffers are additionally pinned against a numpy oracle.

Deterministic example tests run in tier-1; the hypothesis sweeps (via the
optional `_propshim`) are marked `slow` and run in the vit-serve CI job.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.core.policy import DENSE
from repro.nn.dispatch import combine_infer, dispatch_infer
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve.vision import (SWEEP_POLICIES, BucketedViTEngine,
                                build_policy_model)

POLICIES = tuple(SWEEP_POLICIES)          # ("dense", "stage1", "shiftadd")

CFG = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                n_heads=2, d_ff=64)


@functools.lru_cache(maxsize=None)
def _arm(policy):
    """(model, params, jitted infer) for one sweep arm — cached so every
    test (and every hypothesis example) reuses the same compiled programs."""
    dense_model = ShiftAddViT(dataclasses.replace(CFG, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(0))
    model, params = build_policy_model(CFG, policy, dense_model, dense_params)
    infer = jax.jit(lambda imgs: model.infer(params, imgs))
    return model, params, infer


@functools.lru_cache(maxsize=None)
def _engine(policy):
    model, params, _ = _arm(policy)
    return BucketedViTEngine(model, params, buckets=(1, 4, 8)).warmup()


def _imgs(n, seed=0):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (n, CFG.image_size, CFG.image_size, CFG.in_channels))


# ---------------------------------------------------------------------------
# (a) batch-row permutation
# ---------------------------------------------------------------------------

def _check_permutation(policy, n, perm_seed, img_seed=1):
    _, _, infer = _arm(policy)
    imgs = _imgs(n, seed=img_seed)
    base = np.asarray(infer(imgs))
    perm = np.random.default_rng(perm_seed).permutation(n)
    np.testing.assert_array_equal(np.asarray(infer(imgs[perm])), base[perm])


@pytest.mark.parametrize("policy", POLICIES)
def test_row_permutation_invariance(policy):
    _check_permutation(policy, n=6, perm_seed=0)
    _check_permutation(policy, n=6, perm_seed=3)


# ---------------------------------------------------------------------------
# (b) co-batching with arbitrary neighbors
# ---------------------------------------------------------------------------

def _check_cobatch(policy, neighbor_seed, img_seed=2):
    """Image 0's logits must not move when its co-batch changes entirely."""
    _, _, infer = _arm(policy)
    probe = _imgs(1, seed=img_seed)
    alone = np.asarray(infer(probe))
    for n_neighbors in (1, 3, 7):
        neighbors = _imgs(n_neighbors, seed=neighbor_seed)
        batched = np.asarray(
            infer(jnp.concatenate([probe, neighbors], axis=0)))
        np.testing.assert_array_equal(batched[:1], alone)


@pytest.mark.parametrize("policy", POLICIES)
def test_cobatch_neighbor_invariance(policy):
    _check_cobatch(policy, neighbor_seed=10)
    _check_cobatch(policy, neighbor_seed=11)


# ---------------------------------------------------------------------------
# (c) padding to any engine bucket
# ---------------------------------------------------------------------------

def _check_bucket_padding(policy, n, img_seed=3):
    """The engine pads n images up to its covering bucket (and 20 > max
    bucket exercises the chunked path); real rows must equal the direct
    unpadded jitted forward bit-for-bit."""
    _, _, infer = _arm(policy)
    engine = _engine(policy)
    imgs = _imgs(n, seed=img_seed)
    want = np.asarray(infer(imgs))
    np.testing.assert_array_equal(np.asarray(engine.infer(imgs)), want)


@pytest.mark.parametrize("policy", POLICIES)
def test_bucket_padding_invariance(policy):
    for n in (1, 2, 3, 5, 8, 20):
        _check_bucket_padding(policy, n)


@pytest.mark.parametrize("policy", POLICIES)
def test_explicit_zero_padding_rows_are_inert(policy):
    """Same property without the engine in the loop: appending zero rows
    (what bucket padding does) must not perturb the real rows."""
    _, _, infer = _arm(policy)
    imgs = _imgs(3, seed=4)
    base = np.asarray(infer(imgs))
    pad = jnp.zeros((5,) + imgs.shape[1:], imgs.dtype)
    padded = np.asarray(infer(jnp.concatenate([imgs, pad], axis=0)))
    np.testing.assert_array_equal(padded[:3], base)


# ---------------------------------------------------------------------------
# (d) batch=1 vs batch=N
# ---------------------------------------------------------------------------

def _check_one_vs_n(policy, n, img_seed=5):
    _, _, infer = _arm(policy)
    imgs = _imgs(n, seed=img_seed)
    batched = np.asarray(infer(imgs))
    rows = np.concatenate(
        [np.asarray(infer(imgs[i:i + 1])) for i in range(n)], axis=0)
    np.testing.assert_array_equal(batched, rows)


@pytest.mark.parametrize("policy", POLICIES)
def test_batch_one_vs_n_bit_identical(policy):
    _check_one_vs_n(policy, n=5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps over (policy, composition, seeds) — slow tier
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(2, 8), st.integers(0, 10_000),
       st.integers(0, 10_000))
def test_permutation_invariance_property(policy, n, perm_seed, img_seed):
    _check_permutation(policy, n, perm_seed, img_seed=img_seed % 7)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(0, 10_000))
def test_cobatch_invariance_property(policy, neighbor_seed):
    _check_cobatch(policy, neighbor_seed)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(1, 8), st.integers(0, 6))
def test_bucket_padding_invariance_property(policy, n, img_seed):
    _check_bucket_padding(policy, n, img_seed=img_seed)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(2, 8), st.integers(0, 6))
def test_one_vs_n_property(policy, n, img_seed):
    _check_one_vs_n(policy, n, img_seed=img_seed)


# ---------------------------------------------------------------------------
# Numpy oracle for the per-image dispatch buffers
# ---------------------------------------------------------------------------

def _np_per_image_dispatch(x, idx, gate, caps):
    """Reference per-image dispatch: for each batch row independently,
    tokens fill their expert's segment in token order up to its capacity.
    Returns (segments, y, pos, keep): `segments[b][e]` the live buffer rows
    of expert e for image b, `y` the identity-expert combine
    (gate·keep-scaled tokens), plus each token's within-expert rank and
    keep flag. Nothing here reads across rows — the oracle IS the
    independence statement the vmapped dispatch must reproduce."""
    b, s, d = x.shape
    y = np.zeros_like(x)
    pos = np.zeros((b, s), np.int64)
    keep = np.zeros((b, s), bool)
    segments = []
    for bi in range(b):
        fill = [0] * len(caps)
        segs = [[] for _ in caps]
        for t in range(s):
            e = int(idx[bi, t])
            pos[bi, t] = fill[e]
            if fill[e] < caps[e]:
                keep[bi, t] = True
                segs[e].append(x[bi, t])
                y[bi, t] = gate[bi, t] * x[bi, t]
            fill[e] += 1
        segments.append([
            np.asarray(sg, x.dtype).reshape(len(sg), d) for sg in segs])
    return segments, y, pos, keep


def _identity_segments(buf, caps):
    outs, off = [], 0
    for c in caps:
        outs.append(buf[:, off:off + c, :])
        off += c
    return outs


def _check_dispatch_vs_oracle(b, s, e, caps, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, s, 4))
    idx = jax.random.randint(ks[1], (b, s), 0, e)
    gate = jax.nn.softmax(jax.random.normal(ks[2], (b, s, e)), -1)[..., 0]
    buf, info = dispatch_infer(x, idx, gate, caps)
    y = combine_infer(_identity_segments(buf, caps), info)
    segs, y_np, pos, keep = _np_per_image_dispatch(
        np.asarray(x), np.asarray(idx), np.asarray(gate), caps)
    np.testing.assert_array_equal(np.asarray(info["pos"]), pos)
    np.testing.assert_array_equal(np.asarray(info["keep"]), keep)
    np.testing.assert_array_equal(np.asarray(y), y_np)
    # Live buffer rows per (image, expert) — rows past the live count are
    # deliberately unmasked (combine never reads them), so only live rows
    # are comparable.
    buf_np = np.asarray(buf)
    off = 0
    for ei, cap in enumerate(caps):
        for bi in range(b):
            live = segs[bi][ei][:cap]
            np.testing.assert_array_equal(
                buf_np[bi, off:off + len(live)], live)
        off += cap
    # Row independence at the buffer level: dispatching any single row
    # alone reproduces exactly that row's buffers, info and combine.
    for bi in range(b):
        buf1, info1 = dispatch_infer(x[bi:bi + 1], idx[bi:bi + 1],
                                     gate[bi:bi + 1], caps)
        np.testing.assert_array_equal(np.asarray(info1["pos"])[0], pos[bi])
        np.testing.assert_array_equal(np.asarray(info1["keep"])[0], keep[bi])
        y1 = combine_infer(_identity_segments(buf1, caps), info1)
        np.testing.assert_array_equal(np.asarray(y1)[0], y_np[bi])


def test_per_image_dispatch_matches_numpy_oracle_examples():
    for seed, (b, s, e, caps) in enumerate([
            (1, 8, 2, [4, 5]),           # single image, possible drops
            (4, 16, 2, [10, 11]),        # the cf-1.25 serving split shape
            (3, 12, 3, [2, 3, 5]),       # heterogeneous capacities
            (2, 10, 2, [1, 10]),         # starved expert 0
    ]):
        _check_dispatch_vs_oracle(b, s, e, caps, seed)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(4, 20), st.integers(2, 4),
       st.integers(1, 8), st.integers(0, 10_000))
def test_per_image_dispatch_matches_numpy_oracle_property(b, s, e, cap, seed):
    _check_dispatch_vs_oracle(b, s, e, [cap] * e, seed)


# ---------------------------------------------------------------------------
# MoE-level: the served dispatch is the per-image one
# ---------------------------------------------------------------------------

def test_moe_dispatch_info_is_per_image():
    """`MoEPrimitives._dispatch_tokens` (the serving front half) must route
    one group per batch row with the per-image capacity plan, and each row's
    routing info must be reproducible from that row alone."""
    model, params, _ = _arm("shiftadd")
    moe = model.blocks[0].feed
    p = params["blocks"][0]["feed"]
    x = jax.random.normal(jax.random.PRNGKey(8), (5, CFG.n_patches,
                                                  CFG.d_model))
    _, info, _, _ = moe._dispatch_tokens(p, x)
    assert info["expert"].shape == (5, CFG.n_patches)      # G == batch rows
    assert info["caps"] == moe.capacity_plan(CFG.n_patches)[0]
    for bi in range(5):
        _, info1, _, _ = moe._dispatch_tokens(p, x[bi:bi + 1])
        for key in ("expert", "pos", "keep", "gate"):
            np.testing.assert_array_equal(np.asarray(info1[key])[0],
                                          np.asarray(info[key])[bi])
