"""Elastic serving control plane (serve.elastic): autoscaler policy,
warm-pool membership + the zero-recompile invariant, failure injection with
requeue recovery, the degradation ladder, and bit-identical replay of the
full control-plane history — on synthetic service models, so every
scheduling assertion is machine-independent."""
import jax
import numpy as np
import pytest

from repro.distributed.fault_tolerance import ReplicaFault
from repro.serve.elastic import (Autoscaler, AutoscalerPolicy, DegradeArm,
                                 DegradePolicy, ElasticWarmPool,
                                 default_autoscaler_policy, degrade_level,
                                 serve_elastic_trace)
from repro.serve.scheduler import MicroBatchScheduler, SlotScheduler
from repro.serve.traffic import default_budgets, make_trace

# Synthetic calibration: scheduling decisions depend only on these numbers,
# never on machine speed (engine execution stays real; time stays virtual).
SVC = {1: 0.010, 2: 0.018, 4: 0.030}
BUCKETS = (1, 2, 4)


@pytest.fixture(scope="module")
def tiny_model():
    from repro.nn.vit import ShiftAddViT, ViTConfig

    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=1,
                    d_model=32, n_heads=2, d_ff=64)
    model = ShiftAddViT(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def warm_pool(tiny_model):
    model, params = tiny_model
    pool = ElasticWarmPool(model, params, max_replicas=2, spares=1,
                           buckets=BUCKETS).warmup()
    yield pool
    pool.close()


@pytest.fixture(scope="module")
def degrade_pool(tiny_model):
    model, params = tiny_model
    pool = ElasticWarmPool(model, params, max_replicas=1, spares=0,
                           buckets=BUCKETS).warmup()
    yield pool
    pool.close()


def _sched(max_queue_images=32):
    return MicroBatchScheduler(BUCKETS, SVC, slack_s=0.015, linger_s=0.030,
                               max_queue_images=max_queue_images)


def _trace(n=60, seed=0, utilization=1.2, scenario="diurnal"):
    capacity = BUCKETS[-1] / SVC[BUCKETS[-1]]      # one replica, img/s
    return make_trace(scenario, n, seed,
                      target_images_per_s=utilization * capacity,
                      budgets_s=default_budgets(SVC[BUCKETS[-1]]),
                      max_size=BUCKETS[-1])


# ---------------------------------------------------------------------------
# Autoscaler policy: pure decision logic
# ---------------------------------------------------------------------------

def test_autoscaler_backfill_below_min_bypasses_cooldown():
    sc = Autoscaler(AutoscalerPolicy(min_replicas=2, max_replicas=3,
                                     up_cooldown_s=100.0))
    sc.last_up_s = 0.0
    # n_active < min always grows, whatever the cooldown or backlog says.
    assert sc.decide(0.001, n_active=1, n_idle=0, backlog_s=0.0) == +1


def test_autoscaler_grows_on_backlog_and_respects_cooldown():
    p = AutoscalerPolicy(min_replicas=1, max_replicas=3, up_backlog_s=0.03,
                         up_cooldown_s=0.05)
    sc = Autoscaler(p)
    # per-replica backlog 0.08/1 > 0.03 → grow.
    assert sc.decide(1.0, n_active=1, n_idle=0, backlog_s=0.08) == +1
    sc.last_up_s = 1.0
    # same pressure inside the cooldown → hold.
    assert sc.decide(1.02, n_active=2, n_idle=0, backlog_s=0.16) == 0
    # cooldown elapsed → grow again; at max_replicas → hold forever.
    assert sc.decide(1.06, n_active=2, n_idle=0, backlog_s=0.16) == +1
    assert sc.decide(9.00, n_active=3, n_idle=0, backlog_s=9.99) == 0


def test_autoscaler_urgency_requires_no_idle_slot():
    p = AutoscalerPolicy(min_replicas=1, max_replicas=2, up_backlog_s=9e9,
                         slack_up_s=0.06)
    sc = Autoscaler(p)
    # Head forces dispatch in 0.01 s < slack_up with all replicas busy.
    assert sc.decide(0.0, n_active=1, n_idle=0, backlog_s=0.0,
                     until_forced_s=0.01) == +1
    # An idle replica can absorb the urgent head — no growth.
    assert sc.decide(0.0, n_active=1, n_idle=1, backlog_s=0.0,
                     until_forced_s=0.01) == 0


def test_autoscaler_shrinks_only_idle_and_cooled_down():
    p = AutoscalerPolicy(min_replicas=1, max_replicas=3, up_backlog_s=9.0,
                         down_backlog_s=0.01, down_cooldown_s=0.1)
    sc = Autoscaler(p)
    assert sc.decide(5.0, n_active=2, n_idle=1, backlog_s=0.0) == -1
    sc.last_down_s = 5.0
    assert sc.decide(5.05, n_active=2, n_idle=1, backlog_s=0.0) == 0
    # Never below min, never with no idle replica, never under backlog.
    assert sc.decide(9.0, n_active=1, n_idle=1, backlog_s=0.0) == 0
    assert sc.decide(9.0, n_active=2, n_idle=0, backlog_s=0.0) == 0
    assert sc.decide(9.0, n_active=2, n_idle=1, backlog_s=5.0) == 0


def test_default_autoscaler_policy_scales_with_service_time():
    p = default_autoscaler_policy(0.04, min_replicas=1, max_replicas=4)
    assert p.up_backlog_s == pytest.approx(0.04)
    assert p.down_cooldown_s == pytest.approx(4 * p.up_cooldown_s)
    assert p.down_backlog_s < p.up_backlog_s


# ---------------------------------------------------------------------------
# Degradation ladder: pure decision logic
# ---------------------------------------------------------------------------

def test_degrade_level_ladder():
    p = DegradePolicy(order=("relaxed", "standard", "interactive"),
                      min_backlog_s=0.03, step_backlog_s=0.06)
    # Unsaturated pools never degrade, whatever the backlog.
    assert degrade_level(p, saturated=False, backlog_s=9.9) == 0
    # Saturated: ladder engages past min_backlog, one class per step.
    assert degrade_level(p, saturated=True, backlog_s=0.02) == 0
    assert degrade_level(p, saturated=True, backlog_s=0.05) == 1
    assert degrade_level(p, saturated=True, backlog_s=0.10) == 2
    # Capped at the class count.
    assert degrade_level(p, saturated=True, backlog_s=99.0) == 3


def test_degrade_level_default_step_is_one_class():
    p = DegradePolicy(min_backlog_s=0.01)     # step defaults to inf
    assert degrade_level(p, saturated=True, backlog_s=1e9) == 1


# ---------------------------------------------------------------------------
# Warm pool: membership verbs and the zero-recompile invariant
# ---------------------------------------------------------------------------

def test_warm_pool_membership(warm_pool):
    pool = warm_pool
    pool.reset_membership()
    assert pool.reserve == 3 and pool.n_parked == 3 and pool.n_active == 0
    # attach takes the lowest parked id; active stays sorted.
    assert pool.attach() == 0 and pool.attach() == 1
    # max_replicas caps the ACTIVE set even though a spare is parked.
    assert pool.attach() is None and pool.n_parked == 1
    pool.detach(0)
    assert pool.active == [1] and pool.attach() == 0   # lowest again
    pool.kill(1)
    assert pool.state[1] == "dead" and pool.active == [0]
    # The dead engine is never reused; the spare is.
    assert pool.attach() == 2 and pool.attach() is None
    pool.reset_membership()
    assert pool.n_parked == 3 and pool.speed_factor == [1.0] * 3


def test_warm_pool_trace_count_spans_all_reserve_engines(warm_pool):
    pool = warm_pool
    pool.reset_membership()
    tc = pool.trace_count
    # Warmup compiled every bucket on every reserve engine — parked spares
    # included — so membership changes and serving trace NOTHING.
    assert tc >= pool.reserve * len(BUCKETS)
    pool.attach()
    img = np.zeros((2, 16, 16, 3), np.float32)
    pool.submit(0, img).result()
    pool.detach(0)
    pool.attach()
    pool.kill(0)
    pool.attach()                                 # the spare
    pool.submit(1, img).result()
    assert pool.trace_count == tc                 # the elastic invariant
    pool.reset_membership()


def test_warm_pool_submit_guards(warm_pool):
    pool = warm_pool
    pool.reset_membership()
    with pytest.raises(AssertionError):
        pool.submit(0, np.zeros((1, 16, 16, 3), np.float32))   # parked


# ---------------------------------------------------------------------------
# Scheduler requeue: recovery restores the exact pre-dispatch queue
# ---------------------------------------------------------------------------

def test_microbatch_requeue_restores_queue_state():
    sched = _sched()
    trace = _trace(n=12, seed=3)
    for req in trace.requests:
        sched.offer(req, req.arrival_s)
    now = trace.horizon_s + 1.0
    queued_before = sched.queued_images
    b1 = sched.form_batch(now)
    sched.requeue(b1.parts)
    assert sched.queued_images == queued_before
    b2 = sched.form_batch(now)
    # The retry is bit-identical scheduling: same parts, same order, same
    # enqueue stamps (so linger/deadline decisions replay identically).
    assert [(p.rid, p.part_idx, p.enqueued_s) for p in b1.parts] \
        == [(p.rid, p.part_idx, p.enqueued_s) for p in b2.parts]
    assert (b1.bucket, b1.n_images) == (b2.bucket, b2.n_images)


def test_slot_scheduler_requeue_restores_order():
    sched = SlotScheduler()
    trace = _trace(n=8, seed=5)
    for req in trace.requests:
        sched.offer(req, req.arrival_s)
    now = trace.horizon_s
    popped = [sched.next_request(now) for _ in range(3)]
    sched.requeue(popped)
    replayed = [sched.next_request(now) for _ in range(3)]
    assert [(r.rid, e) for r, e in popped] == \
        [(r.rid, e) for r, e in replayed]


# ---------------------------------------------------------------------------
# End-to-end: the control plane on a real (tiny) engine pool
# ---------------------------------------------------------------------------

def _run_elastic(pool, degrade_pool=None, faults=(), trace=None,
                 max_replicas=2, collect_logits=True):
    pool.reset_membership()
    trace = trace if trace is not None else _trace()
    policy = default_autoscaler_policy(SVC[BUCKETS[-1]], min_replicas=1,
                                       max_replicas=max_replicas)
    degrade = None
    if degrade_pool is not None:
        degrade_pool.reset_membership()
        degrade = DegradeArm(
            pool=degrade_pool, scheduler=_sched(max_queue_images=None),
            policy=DegradePolicy(min_backlog_s=SVC[BUCKETS[-1]],
                                 step_backlog_s=2 * SVC[BUCKETS[-1]]))
    return serve_elastic_trace(pool, _sched(), trace, policy=policy,
                               faults=faults, degrade=degrade,
                               collect_logits=collect_logits)


def test_elastic_scales_and_beats_fixed_baseline(warm_pool, degrade_pool):
    trace = _trace()
    # Fixed baseline: the same loop pinned at one replica, nothing else.
    warm_pool.reset_membership()
    fixed = AutoscalerPolicy(min_replicas=1, max_replicas=1)
    base = serve_elastic_trace(warm_pool, _sched(), trace, policy=fixed,
                               collect_logits=False)
    res = _run_elastic(warm_pool, degrade_pool, trace=trace,
                       collect_logits=False)
    assert base.report["deadline_miss_rate"] > 0      # overloaded by design
    assert res.report["deadline_miss_rate"] \
        < base.report["deadline_miss_rate"]
    assert res.report["scale_ups"] >= 1
    assert res.report["max_active"] == 2
    assert res.report["recompiles_after_warmup"] == 0
    assert res.report["shed_requests"] == 0
    # Elasticity pays for fewer replica-seconds than a fixed max pool.
    assert res.report["replica_seconds"] \
        < 2 * res.report["virtual_makespan_s"]


def test_elastic_kill_requeues_and_recovers(warm_pool, degrade_pool):
    trace = _trace()
    kill = (ReplicaFault(at_s=0.4 * trace.horizon_s, kind="kill", slot=0),)
    res = _run_elastic(warm_pool, degrade_pool, faults=kill, trace=trace)
    rep = res.report
    assert rep["kills"] == 1 and rep["faults_fired"] == 1
    assert rep["killed_batches"] <= 1
    # Every admitted request completed: the killed replica's in-flight
    # micro-batch was requeued and re-served from the warm pool.
    assert rep["served_requests"] == rep["requests"]
    assert all(not r["shed"] for r in res.requests)
    assert rep["recompiles_after_warmup"] == 0        # recovery never traces
    # A replacement was attached after the kill (scale-up or recovery).
    kill_t = res.events["faults"][0][1]
    assert any(kind in ("up", "recover") and t >= kill_t
               for kind, t, _ in res.events["scale"])


def test_elastic_straggler_eviction_feeds_autoscaler(warm_pool,
                                                     degrade_pool):
    trace = _trace()
    slow = (ReplicaFault(at_s=0.3 * trace.horizon_s, kind="slowdown",
                         slot=0, factor=4.0),)
    res = _run_elastic(warm_pool, degrade_pool, faults=slow, trace=trace)
    rep = res.report
    # The monitor sees ratio 4.0 against a median of healthy 1.0s and
    # quarantines the replica; the warm pool backfills it.
    assert rep["straggler_evictions"] == 1
    assert any(kind == "straggler_evict" for kind, *_ in
               res.events["faults"])
    assert rep["served_requests"] == rep["requests"]
    assert rep["recompiles_after_warmup"] == 0


def test_elastic_degradation_ladder_engages_when_saturated(warm_pool,
                                                           degrade_pool):
    # max_replicas=1 on a heavy trace: the pool saturates immediately and
    # the ladder must shed classes to the degrade arm instead of dropping.
    trace = _trace(n=40, utilization=1.6)
    res = _run_elastic(warm_pool, degrade_pool, trace=trace,
                       max_replicas=1, collect_logits=False)
    rep = res.report
    assert rep["degraded_requests"] >= 1
    assert rep["shed_requests"] == 0
    # Laxest-first: relaxed degrades before interactive.
    by_klass = rep["degraded_by_class"]
    assert by_klass["relaxed"] >= by_klass["interactive"]
    degraded = [r for r in res.requests if r.get("arm") == "degraded"]
    assert all(r["degrade_reason"] in ("ladder", "overflow")
               for r in degraded)
    assert rep["recompiles_after_warmup"] == 0


def test_elastic_replay_bit_identical_with_faults(warm_pool, degrade_pool):
    trace = _trace()
    faults = (ReplicaFault(at_s=0.35 * trace.horizon_s, kind="kill",
                           slot=0),
              ReplicaFault(at_s=0.6 * trace.horizon_s, kind="slowdown",
                           slot=0, factor=4.0))
    r1 = _run_elastic(warm_pool, degrade_pool, faults=faults, trace=trace)
    r2 = _run_elastic(warm_pool, degrade_pool, faults=faults, trace=trace)
    # The full control-plane history replays: routing (incl. arm), scale
    # timeline, fault firings, degradation decisions...
    assert r1.elastic_signature() == r2.elastic_signature()
    # ...and the logits are bit-identical, faults and degradation included.
    assert set(r1.logits) == set(r2.logits)
    assert all(np.array_equal(r1.logits[k], r2.logits[k])
               for k in r1.logits)


def test_elastic_logits_match_fault_free_run(warm_pool, degrade_pool):
    # Scheduling, scaling, killing and requeueing may move WHEN a request
    # runs, never WHAT it computes: logits must match the fault-free run
    # bit for bit (batch-invariance contract under the control plane).
    trace = _trace(n=30)
    kill = (ReplicaFault(at_s=0.4 * trace.horizon_s, kind="kill", slot=0),)
    r_fault = _run_elastic(warm_pool, None, faults=kill, trace=trace)
    r_clean = _run_elastic(warm_pool, None, faults=(), trace=trace)
    common = set(r_fault.logits) & set(r_clean.logits)
    assert common
    assert all(np.array_equal(r_fault.logits[k], r_clean.logits[k])
               for k in common)


# ---------------------------------------------------------------------------
# Elastic LM: kill → requeue → restart-from-prefill, bit-identical tokens
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_pool():
    from repro.configs.base import ModelConfig
    from repro.core.policy import SHIFTADD
    from repro.nn.model import LanguageModel
    from repro.serve.elastic import ElasticLMPool

    cfg = ModelConfig(name="lm-elastic-test", family="dense",
                      policy=SHIFTADD, n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
                      scan_layers=True, remat="none",
                      moe_primitives_capacity=2.0)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pool = ElasticLMPool(model, params, max_replicas=2, spares=1,
                         n_slots=2, prompt_buckets=(4, 8), chunk=4).warmup()
    yield pool
    pool.close()


def _run_lm(pool, faults=(), n=24, seed=0):
    from repro.serve.elastic import serve_elastic_lm_trace

    # Synthetic LM timing law — decisions machine-independent, as above.
    svc = {"prefill_s": {4: 0.008, 8: 0.012}, "chunk_s": 0.005}
    per_req = svc["prefill_s"][4] + 3 * svc["chunk_s"]
    cap_req_s = pool.n_slots / per_req
    trace = make_trace("diurnal", n, seed,
                       target_images_per_s=1.3 * cap_req_s * 4.0,
                       budgets_s=default_budgets(svc["prefill_s"][8]
                                                 + 6 * svc["chunk_s"]),
                       max_size=8)
    policy = AutoscalerPolicy(min_replicas=1, max_replicas=2,
                              up_backlog_s=2 * per_req,
                              up_cooldown_s=per_req,
                              down_backlog_s=0.25 * per_req,
                              down_cooldown_s=4 * per_req)
    pool.reset_membership()
    return serve_elastic_lm_trace(pool, SlotScheduler(), trace, svc,
                                  policy=policy, per_request_s=per_req,
                                  faults=faults)


def test_elastic_lm_kill_recovers_with_identical_tokens(lm_pool):
    kill_frac = 0.4
    r_clean = _run_lm(lm_pool)
    horizon = max(r["arrival_s"] for r in r_clean.requests)
    kill = (ReplicaFault(at_s=kill_frac * horizon, kind="kill", slot=0),)
    r_fault = _run_lm(lm_pool, faults=kill)
    rep = r_fault.report
    assert rep["kills"] == 1
    assert rep["served_requests"] == rep["requests"]
    assert rep["recompiles_after_warmup"] == 0
    # A killed engine's in-progress requests restarted from prefill on a
    # warm replacement — greedy decode makes the retry bit-identical.
    assert set(r_fault.tokens) == set(r_clean.tokens)
    assert all(np.array_equal(r_fault.tokens[k], r_clean.tokens[k])
               for k in r_fault.tokens)


def test_elastic_lm_replay_identical(lm_pool):
    r1 = _run_lm(lm_pool, n=20, seed=2)
    r2 = _run_lm(lm_pool, n=20, seed=2)
    assert r1.dispatch_signature() == r2.dispatch_signature()
    assert r1.report["scale_events"] == r2.report["scale_events"]
    assert all(np.array_equal(r1.tokens[k], r2.tokens[k])
               for k in r1.tokens)
    assert r1.report["scale_ups"] + r1.report["recoveries"] >= 1
