"""Multi-device behavior (8 simulated host devices in a subprocess):
sharded train step, compressed psum via shard_map, logical sharding rules."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed import sharding as sl
from repro.distributed.sharding import LOGICAL_AXIS_RULES, logical_to_pspec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_logical_rules_cover_required_axes():
    for name in ("batch", "embed", "vocab", "heads", "mlp", "experts"):
        assert name in LOGICAL_AXIS_RULES


def test_pspec_divisibility_fallback():
    # AbstractMesh carries shape/axis_names without requiring real devices.
    mesh = sl.make_abstract_mesh((2, 4), ("data", "model"))
    # indivisible dims fall back to replication
    spec = logical_to_pspec(("batch", "vocab"), mesh, (3, 5))
    assert all(s is None for s in spec) or len(spec) == 0
    # divisible dims shard
    spec = logical_to_pspec(("batch", "vocab"), mesh, (4, 8))
    assert spec[0] == ("data",) or spec[0] == "data"
    assert spec[1] == ("model",) or spec[1] == "model"
    # a mesh axis is used at most once across dims
    spec = logical_to_pspec(("vocab", "mlp"), mesh, (8, 8))
    flat = [a for s in spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))]
    assert len(flat) == len(set(flat))


def test_sharded_train_step_runs_on_mesh():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, TrainConfig
        from repro.nn.model import LanguageModel
        from repro.train.step import init_train_state, make_train_step
        from repro.distributed import sharding as sl
        from repro.launch.dryrun import state_shardings, batch_shardings

        mesh = sl.make_mesh((2, 4), ("data", "model"))
        sl.set_active_mesh(mesh)
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                          dtype="float32", scan_layers=True, remat="none")
        tcfg = TrainConfig(learning_rate=1e-3, total_steps=4, global_batch=8,
                           seq_len=16, microbatch=2)
        model = LanguageModel(cfg)
        with mesh:
            state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
            shapes = jax.eval_shape(lambda: state)
            pshard = sl.shardings_from_spec(
                model.spec(shapes["params"]), shapes["params"], mesh)
            st = state_shardings(shapes, pshard, mesh)
            state = jax.tree_util.tree_map(jax.device_put, state, st)
            step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
            import numpy as np
            batch = {"inputs": np.zeros((8, 16), np.int32),
                     "labels": np.ones((8, 16), np.int32)}
            for _ in range(3):
                state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss)
            print("LOSS", loss)
    """)
    assert "LOSS" in out


def test_compressed_psum_matches_plain_psum():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as sl
        from repro.distributed.collectives import compressed_psum

        mesh = sl.make_mesh((8,), ("pod",))

        def f(x):
            reduced, residual = compressed_psum(x, "pod")
            exact = jax.lax.psum(x, "pod")
            return reduced, exact, residual

        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        r, e, res = jax.jit(sl.shard_map(f, mesh=mesh, in_specs=P("pod"),
                                         out_specs=P("pod")))(x)
        rel = float(jnp.max(jnp.abs(r - e)) / (jnp.max(jnp.abs(e)) + 1e-9))
        # int8 quantization: ~1% relative error on the reduction
        assert rel < 0.05, rel
        # error feedback residual equals the local quantization error
        assert float(jnp.max(jnp.abs(res))) < float(jnp.max(jnp.abs(x))) / 64
        print("REL", rel)
    """)
    assert "REL" in out


def test_moe_dispatch_shards_over_groups():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.nn.moe import TokenChoiceMoE
        from repro.distributed import sharding as sl

        mesh = sl.make_mesh((2, 4), ("data", "model"))
        sl.set_active_mesh(mesh)
        cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                          dtype="float32",
                          moe=MoEConfig(n_experts=8, top_k=2, d_expert=64))
        moe = TokenChoiceMoE(cfg)
        params = moe.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, 64))
        with mesh:
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            y, aux = jax.jit(lambda p, x: moe(p, x, train=False))(params, xs)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        print("MOE-OK", float(aux["drop_fraction"]))
    """)
    assert "MOE-OK" in out
