"""Token-level continuous-batching property tier (ISSUE 8).

The LM serving contract, mirroring the vision tier in
tests/test_batch_invariance.py one level down — at token granularity:

a request's greedy tokens AND logits are BIT-IDENTICAL no matter (a) which
slot of the packed decode batch it occupies, (b) which requests it is
co-resident with, (c) at which chunk boundary it joins the running batch,
(d) when its neighbors are admitted or evicted, and (e) whether it is served
alone or packed — for both serving arms, shiftadd MoE included (drop-free at
the serving capacity factor 2.0). Decode is row-wise per slot and admission/
eviction are single-row gather/scatters, so scheduling can move latency but
never a logit. ((b)–(e) are structural; (a) additionally depends on XLA
compiling row-uniform reductions, which holds at the geometry gated here —
see lm_serial_oracle's slot pin for the one CPU shape where it doesn't.) The same engine stream is also pinned against the fully
independent one-shot oracle `serve.decode.generate` (parallel chunked
prefill + scan-fused decode — a different code path end to end).

Deterministic example tests run in tier-1; the hypothesis schedule sweeps
(via the optional `_propshim`) are marked `slow` and run in the lm-traffic
CI job. SlotScheduler's EDF/FIFO/shedding contracts and the seeded-trace
replay of `serve.frontend.serve_lm_trace` are pinned here too.
"""
import functools

import jax
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.configs.base import ModelConfig
from repro.core.policy import SHIFTADD, STAGE1
from repro.nn.model import LanguageModel
from repro.serve.decode import generate
from repro.serve.frontend import lm_serial_oracle, serve_lm_trace
from repro.serve.replicas import make_lm_replicas
from repro.serve.scheduler import SlotScheduler
from repro.serve.traffic import (Request, default_budgets, lm_new_tokens,
                                 lm_prompt_tokens, make_trace)

POLICIES = ("stage1", "shiftadd")
POLICY_BY_NAME = {"stage1": STAGE1, "shiftadd": SHIFTADD}

VOCAB = 64
BUCKETS = (4, 8)
CHUNK = 4
N_SLOTS = 3


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_state():
    """Drop the cached pools (and their ~35 jitted programs with donated
    buffers) once this module is done: holding them for the rest of the
    suite pushed the process over an XLA-CPU JIT limit that segfaulted a
    later unrelated compile (reproducibly, in tests/test_serve.py)."""
    yield
    _pool.cache_clear()
    _arm.cache_clear()
    jax.clear_caches()


@functools.lru_cache(maxsize=None)
def _arm(policy):
    cfg = ModelConfig(name=f"lm-cont-{policy}", family="dense",
                      policy=POLICY_BY_NAME[policy], n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
                      dtype="float32", scan_layers=True, remat="none",
                      moe_primitives_capacity=2.0)
    model = LanguageModel(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _pool(policy):
    """One warmed single-replica pool per arm — every test (and every
    hypothesis example) reuses the same compiled programs."""
    model, params = _arm(policy)
    return make_lm_replicas(model, params, n_replicas=1, n_slots=N_SLOTS,
                            prompt_buckets=BUCKETS, chunk=CHUNK).warmup()


def _engine(policy):
    return _pool(policy).engines[0]


def _prompt(seed, n):
    return np.random.default_rng(seed).integers(0, VOCAB, n).astype(np.int32)


def _serve_packed(eng, plan):
    """Drive the engine through an explicit slot schedule.

    plan: list of (admit_round, slot, prompt, n_new) — entry i is admitted
    into `slot` at chunk boundary `admit_round` and generates `n_new`
    tokens. Returns {i: (tokens (n_new,), logits (n_new, V))}, collected
    exactly the way serve.frontend.serve_lm_trace collects streams.
    """
    eng.reset()
    slots, out = {}, {}
    order = sorted(range(len(plan)), key=lambda i: (plan[i][0], plan[i][1]))
    nxt = rnd = 0
    while nxt < len(order) or slots:
        for s in list(slots):                       # chunk boundary: evict
            if slots[s]["gen"] >= slots[s]["target"]:
                rec = slots.pop(s)
                eng.evict(s)
                out[rec["i"]] = (np.concatenate(rec["toks"]),
                                 np.concatenate(rec["lgs"], axis=0))
        while nxt < len(order) and plan[order[nxt]][0] <= rnd:   # admit
            i = order[nxt]
            _, slot, prompt, n_new = plan[i]
            assert slot not in slots, f"plan reuses occupied slot {slot}"
            first, lg = eng.admit(slot, prompt, rid=i)
            slots[slot] = {"i": i, "gen": 1, "target": n_new,
                           "toks": [np.asarray([first], np.int32)],
                           "lgs": [lg[None]]}
            nxt += 1
        if slots:                                   # one chunk, ALL slots
            ts, ls = eng.decode_chunk()
            for s, rec in slots.items():
                take = min(eng.chunk, rec["target"] - rec["gen"])
                if take > 0:
                    rec["toks"].append(ts[:take, s].copy())
                    rec["lgs"].append(ls[:take, s].copy())
                    rec["gen"] += take
        rnd += 1
    eng.reset()
    return out


def _serve_serial(eng, prompt, n_new, slot=0):
    return _serve_packed(eng, [(0, slot, prompt, n_new)])[0]


def _assert_streams_equal(got, want):
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


# ---------------------------------------------------------------------------
# (e) engine vs the independent one-shot oracle (generate)
# ---------------------------------------------------------------------------

def _check_generate_parity(policy, prompt_len, n_new, seed=0):
    """Slot-array serving must reproduce `generate`'s greedy tokens: the
    oracle runs exact-length prompts through a different prefill/decode
    composition, so this pins the lengths-masked bucket prefill AND the
    chunked slot decode against an independent path."""
    model, params = _arm(policy)
    eng = _engine(policy)
    prompt = _prompt(seed, prompt_len)
    toks, _ = _serve_serial(eng, prompt, n_new)
    want = np.asarray(generate(model, params, prompt[None], n_new))
    np.testing.assert_array_equal(toks, want[0, prompt_len:])


@pytest.mark.parametrize("policy", POLICIES)
def test_engine_matches_generate_oracle(policy):
    for plen, n_new in ((2, 6), (4, 5), (7, 6)):    # bucket edge + padding
        _check_generate_parity(policy, plen, n_new)


def test_moe_prefill_vs_decode_regression():
    """Longer shiftadd run: prefill routes the whole prompt as one group
    while decode routes per token — at the serving capacity (2.0, drop-free)
    both must land on generate's exact greedy stream (serve.decode MoE note;
    a capacity-induced drop would diverge the trajectories here)."""
    _check_generate_parity("shiftadd", 7, 13, seed=5)


# ---------------------------------------------------------------------------
# (a,b,c) join order, co-residency, slot choice
# ---------------------------------------------------------------------------

def _baselines(eng, prompts, n_news):
    return [_serve_serial(eng, p, n) for p, n in zip(prompts, n_news)]


@pytest.mark.parametrize("policy", POLICIES)
def test_staggered_join_invariance(policy):
    """Three requests joining a RUNNING batch at different chunk boundaries
    reproduce their solo streams bit for bit."""
    eng = _engine(policy)
    prompts = [_prompt(10, 3), _prompt(11, 7), _prompt(12, 4)]
    n_news = (9, 6, 7)
    base = _baselines(eng, prompts, n_news)
    plan = [(0, 1, prompts[0], n_news[0]),
            (1, 0, prompts[1], n_news[1]),
            (2, 2, prompts[2], n_news[2])]
    packed = _serve_packed(eng, plan)
    for i in range(3):
        _assert_streams_equal(packed[i], base[i])


@pytest.mark.parametrize("policy", POLICIES)
def test_slot_permutation_invariance(policy):
    eng = _engine(policy)
    prompts = [_prompt(20, 5), _prompt(21, 2)]
    a = _serve_packed(eng, [(0, 0, prompts[0], 6), (0, 1, prompts[1], 6)])
    b = _serve_packed(eng, [(0, 2, prompts[0], 6), (0, 0, prompts[1], 6)])
    _assert_streams_equal(a[0], b[0])
    _assert_streams_equal(a[1], b[1])


@pytest.mark.parametrize("policy", POLICIES)
def test_eviction_timing_invariance(policy):
    """A probe request's stream must not move when a neighbor leaves early
    (slot reset mid-flight) vs staying resident the whole time."""
    eng = _engine(policy)
    probe, neigh = _prompt(30, 6), _prompt(31, 3)
    early = _serve_packed(eng, [(0, 0, probe, 9), (0, 1, neigh, 2)])
    late = _serve_packed(eng, [(0, 0, probe, 9), (0, 1, neigh, 9)])
    _assert_streams_equal(early[0], late[0])
    # ... and a THIRD request recycled into the freed slot is inert too.
    recycled = _serve_packed(eng, [(0, 0, probe, 9), (0, 1, neigh, 2),
                                   (1, 2, _prompt(32, 8), 5)])
    _assert_streams_equal(recycled[0], late[0])


# ---------------------------------------------------------------------------
# no recompilation after warmup
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_zero_recompiles_after_warmup(policy):
    eng = _engine(policy)
    tc0 = eng.trace_count
    # Mixed workload over every program: both buckets, an oversize prompt
    # (clipped to the largest bucket), admits/evicts/chunks/reset.
    _serve_packed(eng, [(0, 0, _prompt(40, 3), 5),
                        (0, 1, _prompt(41, 8), 6),
                        (1, 2, _prompt(42, 12), 5)])
    assert eng.trace_count == tc0, "a serving call retraced after warmup"
    assert eng.trace_count == eng.expected_programs
    assert eng.prefill_trace_count == len(eng.prompt_buckets)


# ---------------------------------------------------------------------------
# SlotScheduler: EDF across class heads, FIFO within, whole-request shed
# ---------------------------------------------------------------------------

def _req(rid, klass, deadline_s, arrival_s=0.0, size=4, seed=0):
    return Request(rid=rid, arrival_s=arrival_s, size=size, klass=klass,
                   deadline_s=deadline_s, seed=seed)


def test_slot_scheduler_fifo_within_class():
    sched = SlotScheduler()
    for rid, dl in ((0, 9.0), (1, 1.0), (2, 5.0)):   # deadlines do NOT
        assert sched.offer(_req(rid, "standard", dl), 0.0)  # reorder a class
    assert [sched.next_request(0.0)[0].rid for _ in range(3)] == [0, 1, 2]
    assert sched.next_request(0.0) is None


def test_slot_scheduler_edf_across_class_heads():
    sched = SlotScheduler()
    sched.offer(_req(0, "interactive", 5.0), 0.0)
    sched.offer(_req(1, "relaxed", 1.0), 0.0)        # earliest deadline wins
    sched.offer(_req(2, "standard", 3.0), 0.0)
    assert [sched.next_request(0.0)[0].rid for _ in range(3)] == [1, 2, 0]
    # Deadline ties break by class declaration order (deterministic).
    sched.offer(_req(3, "relaxed", 2.0), 0.0)
    sched.offer(_req(4, "interactive", 2.0), 0.0)
    assert sched.next_request(0.0)[0].rid == 4
    assert sched.next_request(0.0)[0].rid == 3


def test_slot_scheduler_sheds_whole_requests():
    sched = SlotScheduler(max_queue_requests=2)
    assert sched.offer(_req(0, "standard", 1.0), 0.0)
    assert sched.offer(_req(1, "standard", 1.0), 0.0)
    assert not sched.offer(_req(2, "interactive", 0.5), 0.0)
    assert (sched.queued_requests, sched.shed_requests,
            sched.admitted_requests) == (2, 1, 2)
    assert sched.next_request(0.0)[0].rid == 0
    assert sched.offer(_req(3, "standard", 2.0), 0.0)  # capacity freed


# ---------------------------------------------------------------------------
# serve_lm_trace: seeded replay, continuous-vs-static parity, serial oracle
# ---------------------------------------------------------------------------

_SVC = {"prefill_s": {4: 1e-3, 8: 2e-3}, "chunk_s": 4e-3}


def _trace(n=8, seed=3):
    return make_trace("poisson", n, seed, target_images_per_s=2000.0,
                      budgets_s=default_budgets(0.02), max_size=BUCKETS[-1])


@pytest.mark.parametrize("policy", POLICIES)
def test_trace_replay_and_static_parity(policy):
    pool = _pool(policy)
    pool.reset()
    trace = _trace()
    kw = dict(new_token_range=(2, 6), collect_logits=True)
    runs = []
    for _ in range(2):
        runs.append(serve_lm_trace(pool, SlotScheduler(), trace, _SVC,
                                   mode="continuous", **kw))
        pool.reset()
    a, b = runs
    assert a.dispatch_signature() == b.dispatch_signature()
    for rid in a.tokens:
        np.testing.assert_array_equal(a.tokens[rid], b.tokens[rid])
        np.testing.assert_array_equal(a.logits[rid], b.logits[rid])

    static = serve_lm_trace(pool, SlotScheduler(), trace, _SVC,
                            mode="static", **kw)
    pool.reset()
    # Same served set, identical token streams (admission policy is
    # latency-only), and the structural throughput ordering.
    assert set(static.tokens) == set(a.tokens)
    for rid in a.tokens:
        np.testing.assert_array_equal(static.tokens[rid], a.tokens[rid])
    assert (a.report["tokens_per_s"] >= static.report["tokens_per_s"])
    for res in (a, static):
        assert res.report["recompiles_after_warmup"] == 0
        assert (res.report["prefill_trace_count"]
                == res.report["expected_prefill_traces"])
        assert res.report["shed_requests"] == 0

    toks1, lgs1 = lm_serial_oracle(pool, trace, set(a.tokens),
                                   new_token_range=(2, 6))
    assert set(toks1) == set(a.tokens)
    for rid in toks1:
        np.testing.assert_array_equal(a.tokens[rid], toks1[rid])
        np.testing.assert_array_equal(a.logits[rid], lgs1[rid])


def test_trace_payload_helpers_are_deterministic():
    trace = _trace()
    for req in trace.requests[:4]:
        p1, p2 = (lm_prompt_tokens(req, VOCAB) for _ in range(2))
        np.testing.assert_array_equal(p1, p2)
        assert p1.shape == (req.size,) and p1.dtype == np.int32
        n = lm_new_tokens(req, 2, 6)
        assert 2 <= n <= 6 and n == lm_new_tokens(req, 2, 6)


# ---------------------------------------------------------------------------
# Hypothesis sweeps over (policy, schedule, payload seeds) — slow tier
# ---------------------------------------------------------------------------

def _random_plan(rng, n_reqs):
    """A valid schedule: distinct slots, arbitrary join rounds/lengths."""
    slots = rng.permutation(N_SLOTS)[:n_reqs]
    return [(int(rng.integers(0, 3)), int(slots[i]),
             _prompt(int(rng.integers(0, 1000)), int(rng.integers(1, 11))),
             int(rng.integers(1, 9)))
            for i in range(n_reqs)]


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(1, N_SLOTS),
       st.integers(0, 10_000))
def test_schedule_invariance_property(policy, n_reqs, seed):
    """ANY admit-round/slot/length schedule reproduces the solo streams."""
    eng = _engine(policy)
    plan = _random_plan(np.random.default_rng(seed), n_reqs)
    packed = _serve_packed(eng, plan)
    for i, (_, _, prompt, n_new) in enumerate(plan):
        _assert_streams_equal(packed[i], _serve_serial(eng, prompt, n_new))


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(st.sampled_from(POLICIES), st.integers(0, 10_000))
def test_generate_parity_property(policy, seed):
    rng = np.random.default_rng(seed)
    _check_generate_parity(policy, int(rng.integers(1, 11)),
                           int(rng.integers(1, 12)), seed=seed % 97)
