"""Parallel-prefill / sequential-decode parity.

The serving contract: a chunked `prefill()` over a P-token prompt must leave
the model in EXACTLY the state (≤1e-4) that P sequential `decode_step` calls
would — same recurrent carry / KV rows / conv windows, same next-token
logits. P is chosen to NOT be a multiple of the causal chunk so the padded
tail-chunk masking is exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import add_attention as la
from repro.core.policy import STAGE1, ShiftAddPolicy
from repro.nn.model import LanguageModel

LINEAR_ELU1 = ShiftAddPolicy(attention="linear")


def _model(policy=None, **kw):
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=64, dtype="float32", scan_layers=True, remat="none")
    base.update(kw)
    pol = {} if policy is None else {"policy": policy}
    cfg = ModelConfig(name="t", family="dense", **pol, **base)
    model = LanguageModel(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _parity_errors(model, params, prompts, max_len):
    b, p = prompts.shape
    logits_pf, cache_pf = model.prefill(params, prompts,
                                        model.init_cache(b, max_len=max_len))
    cache_sq = model.init_cache(b, max_len=max_len)
    logits_sq = None
    for t in range(p):
        logits_sq, cache_sq = model.decode_step(params, prompts[:, t], cache_sq)
    assert (jax.tree_util.tree_structure(cache_pf)
            == jax.tree_util.tree_structure(cache_sq))
    logit_err = float(jnp.max(jnp.abs(logits_pf[:, -1] - logits_sq)))
    state_err = max(
        float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                              - jnp.asarray(b_, jnp.float32))))
        for a, b_ in zip(jax.tree_util.tree_leaves(cache_pf),
                         jax.tree_util.tree_leaves(cache_sq)))
    return logit_err, state_err


# P=13 with chunk=min(128, 13): full-chunk path; P=13 also exercises the
# core-level padded-chunk path below (chunk=8 → 13 = 8 + 5).
@pytest.mark.parametrize("policy", [STAGE1, LINEAR_ELU1, None],
                         ids=["binary", "elu1", "dense_kv"])
@pytest.mark.parametrize("p", [13, 16])
def test_prefill_matches_sequential_decode(policy, p):
    model, params = _model(policy)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, p), 0, 64)
    logit_err, state_err = _parity_errors(model, params, prompts, p + 4)
    assert logit_err <= 1e-4, logit_err
    assert state_err <= 1e-4, state_err


def test_prefill_matches_sequential_decode_unscanned_rem_blocks():
    """Odd depth (rem blocks) + python-loop layer stack."""
    model, params = _model(STAGE1, n_layers=3, scan_layers=False)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 7), 0, 64)
    logit_err, state_err = _parity_errors(model, params, prompts, 16)
    assert logit_err <= 1e-4, logit_err
    assert state_err <= 1e-4, state_err


@pytest.mark.parametrize("feature", ["binary", "elu1"])
@pytest.mark.parametrize("n,chunk", [(13, 8), (37, 16), (64, 16)])
def test_chunked_state_matches_recurrent_steps(feature, n, chunk):
    """Core-level: the chunked pass's final carry == N recurrent updates,
    including causal chunk boundaries where N % chunk != 0."""
    b, h, dk, dv = 2, 2, 16, 12
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, h, n, dk))
    k = jax.random.normal(ks[1], (b, h, n, dk))
    v = jax.random.normal(ks[2], (b, h, n, dv))
    out, state = la.binary_linear_attention(
        q, k, v, causal=True, chunk=chunk, feature=feature, return_state=True)
    st = la.init_decode_state(b, h, dk, dv)
    o_t = None
    for t in range(n):
        o_t, st = la.binary_linear_attention_step(
            q[:, :, t], k[:, :, t], v[:, :, t], st, feature=feature)
    for key in ("kv", "ksum", "vsum", "count"):
        np.testing.assert_allclose(np.asarray(state[key]), np.asarray(st[key]),
                                   atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[:, :, -1]), np.asarray(o_t),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("scan_layers", [True, False])
def test_prefill_and_decode_match_training_forward(scan_layers):
    """Multi-block pattern with n_cycles > 1: prefill AND sequential decode
    must apply layers in the same cycle-major order as the training __call__
    (regression: the unscanned branch once ran block-major)."""
    model, params = _model(STAGE1, n_layers=4, scan_layers=scan_layers,
                           block_pattern=("attn", "attn"))
    p = 6
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, p), 0, 64)
    ref_logits, _ = model(params, prompts, train=False)
    logits_pf, _ = model.prefill(params, prompts,
                                 model.init_cache(2, max_len=p + 2))
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-5)
    cache = model.init_cache(2, max_len=p + 2)
    logits_sq = None
    for t in range(p):
        logits_sq, cache = model.decode_step(params, prompts[:, t], cache)
    np.testing.assert_allclose(np.asarray(logits_sq),
                               np.asarray(ref_logits[:, -1]),
                               atol=1e-4, rtol=1e-5)


def test_prefill_then_decode_continues_exactly():
    """Tokens generated after a prefill handoff must equal tokens generated
    after a purely sequential warmup (greedy, so exact)."""
    model, params = _model(STAGE1)
    p, new = 11, 6
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, p), 0, 64)
    max_len = p + new

    logits_pf, cache = model.prefill(params, prompts,
                                     model.init_cache(2, max_len=max_len))
    logits = logits_pf[:, -1]
    toks_a = []
    for _ in range(new):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks_a.append(tok)
        logits, cache = model.decode_step(params, tok, cache)

    cache = model.init_cache(2, max_len=max_len)
    logits = None
    for t in range(p):
        logits, cache = model.decode_step(params, prompts[:, t], cache)
    toks_b = []
    for _ in range(new):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks_b.append(tok)
        logits, cache = model.decode_step(params, tok, cache)

    np.testing.assert_array_equal(np.asarray(jnp.stack(toks_a)),
                                  np.asarray(jnp.stack(toks_b)))


def test_int8_kv_prefill_within_quantization_tolerance():
    """int8 caches can't be bit-identical (sequential decode reads quantized
    history; prefill attends in full precision) — but the dequantized rows
    must agree at quantization scale."""
    model, params = _model(kv_cache_dtype="int8")
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0, 64)
    logit_err, _ = _parity_errors(model, params, prompts, 13)
    assert logit_err < 0.1, logit_err


def test_generate_rng_validation():
    from repro.serve.decode import generate

    model, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 4), 0, 64)
    with pytest.raises(ValueError, match="rng"):
        generate(model, params, prompts, 4, temperature=0.7)
