"""Chunked RWKV6 (beyond-paper §Perf): must match the per-token scan exactly
across the whole admissible decay range (logw ∈ [-8, 0))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.nn.model import LanguageModel
from repro.nn.recurrent import rwkv6_chunked


def _scan_ref(r, k, v, w, u):
    hs = r.shape[-1]

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        out_t = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, out_t

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    S0 = jnp.zeros((r.shape[0], r.shape[2], hs, hs))
    _, out = jax.lax.scan(step, S0, xs)
    return out.transpose(1, 0, 2, 3)


@pytest.mark.parametrize("decay_shift", [-1.0, 0.8, 2.2])
@pytest.mark.parametrize("n", [16, 64, 128])
def test_chunked_matches_scan(decay_shift, n):
    b, h, hs = 2, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(int(decay_shift * 10) + n), 5)
    r = jax.random.normal(ks[0], (b, n, h, hs))
    k = jax.random.normal(ks[1], (b, n, h, hs))
    v = jax.random.normal(ks[2], (b, n, h, hs))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, n, h, hs)) + decay_shift)
    w = jnp.exp(jnp.clip(logw, -8.0, -1e-4))
    u = jax.random.normal(ks[4], (h, hs)) * 0.5
    ref = _scan_ref(r, k, v, w, u)
    out = rwkv6_chunked(r, k, v, w, u)
    err = float(jnp.max(jnp.abs(out - ref)) / (jnp.std(ref) + 1e-9))
    assert err < 2e-3, err


def test_model_level_chunked_equivalence():
    base = dict(name="t", family="ssm", n_layers=2, d_model=64, n_heads=2,
                n_kv_heads=2, d_ff=128, vocab_size=64,
                block_pattern=("rwkv6",), rope="none", norm="layernorm",
                dtype="float32", scan_layers=False, remat="none")
    m_scan = LanguageModel(ModelConfig(**base))
    m_chunk = LanguageModel(ModelConfig(rwkv_chunked=True, **base))
    params = m_scan.init(jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64)
    l1, _ = m_scan(params, x, train=False)
    l2, _ = m_chunk(params, x, train=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)
    # gradients flow through the chunked path
    g = jax.grad(lambda p: m_chunk.loss(p, {"inputs": x, "labels": x})[0])(params)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0
