"""Analyzer self-tests (ISSUE 6): each pass reports exactly the planted
violations in tests/analysis_fixtures/ and NOTHING on the clean tree, plus
regression tests pinning the pre-existing violations this PR fixed (weak
where-branches in core.quant / core.losses, the engine's unconsumable image
donation, the linear-attention prefill ignoring its donated cache).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jaxpr_audit, kernel_contracts, lint
from repro.analysis.findings import Finding, split_allowlisted
from repro.analysis.jaxpr_audit import audit_closed_jaxpr, check_donation

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# AST lint: planted fixtures
# ---------------------------------------------------------------------------

def test_lint_planted_violations_exactly():
    f = lint.lint_file(os.path.join(FIXTURES, "planted_lint.py"))
    # numpy_on_traced, item, float, rng-in-infer (param AND call), self-
    # mutation, missing donation — the waived LT004 must NOT appear.
    assert _rules(f) == ["LT001", "LT002", "LT002", "LT003", "LT003",
                         "LT004", "LT005"]


def test_lint_allow_comment_suppresses():
    f = lint.lint_file(os.path.join(FIXTURES, "planted_lint.py"))
    lt004 = [x for x in f if x.rule == "LT004"]
    assert len(lt004) == 1           # the un-waived one only
    assert "make_counted_step" not in lt004[0].message


def test_lint_clean_module_is_clean():
    assert lint.lint_file(os.path.join(FIXTURES, "clean_module.py")) == []


def test_lint_static_argnames_not_traced():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('n',))\n"
        "def f(x, n):\n"
        "    return x * int(n)\n"
    )
    assert lint.lint_source(src, "mod.py") == []


def test_lint_src_repro_is_clean():
    findings, n_files = lint.run()
    assert n_files > 50
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# jaxpr audit: planted fixtures
# ---------------------------------------------------------------------------

def _fixture_jaxpr(fn, *args):
    return jax.make_jaxpr(fn)(*args)


def test_jaxpr_planted_callback():
    from analysis_fixtures import planted_jaxpr as p
    closed = _fixture_jaxpr(jax.jit(p.callback_under_jit),
                            jnp.zeros((4,), jnp.float32))
    assert "JX001" in _rules(audit_closed_jaxpr(closed, "fixture"))


def test_jaxpr_planted_weak_boundary():
    from analysis_fixtures import planted_jaxpr as p
    closed = _fixture_jaxpr(p.weak_boundary, jnp.zeros((4,), jnp.float32))
    assert "JX003" in _rules(audit_closed_jaxpr(closed, "fixture"))


def test_jaxpr_planted_rng_in_infer():
    from analysis_fixtures import planted_jaxpr as p
    closed = _fixture_jaxpr(p.rng_in_infer, jnp.zeros((4,), jnp.float32))
    rules = _rules(audit_closed_jaxpr(closed, "fixture"))
    assert "JX006" in rules
    # the same program is legal on a sampling path:
    sampling = audit_closed_jaxpr(closed, "fixture", deterministic=False)
    assert "JX006" not in _rules(sampling)


def test_jaxpr_planted_float_scatter_add():
    from analysis_fixtures import planted_jaxpr as p
    closed = _fixture_jaxpr(p.float_scatter_add, jnp.zeros((4,), jnp.float32))
    assert "JX007" in _rules(audit_closed_jaxpr(closed, "fixture"))
    # integer scatter-adds are deterministic and must pass:
    closed_int = _fixture_jaxpr(p.float_scatter_add,
                                jnp.zeros((4,), jnp.int32))
    assert "JX007" not in _rules(audit_closed_jaxpr(closed_int, "fixture"))


def test_jaxpr_planted_f64():
    from analysis_fixtures import planted_jaxpr as p
    with jax.experimental.enable_x64():
        closed = _fixture_jaxpr(p.f64_promotion, jnp.zeros((4,), jnp.float32))
    assert "JX002" in _rules(audit_closed_jaxpr(closed, "fixture"))


def test_jaxpr_dtype_signature_drift_detected():
    def bucket_small(x):
        return x * 2.0

    def bucket_big(x):          # shape-dependent dtype: the recompile hazard
        return (x.astype(jnp.bfloat16) * 2).astype(jnp.float32)

    sig_a = jaxpr_audit.dtype_signature(
        jax.make_jaxpr(bucket_small)(jnp.zeros((1, 4), jnp.float32)))
    sig_b = jaxpr_audit.dtype_signature(
        jax.make_jaxpr(bucket_big)(jnp.zeros((32, 4), jnp.float32)))
    assert sig_a != sig_b
    # and batch-only variation is signature-identical:
    sig_c = jaxpr_audit.dtype_signature(
        jax.make_jaxpr(bucket_small)(jnp.zeros((32, 4), jnp.float32)))
    assert sig_a == sig_c


def test_donation_unconsumed_flagged_and_consumed_passes():
    def no_alias(x):             # (4,) in → (2,) out: nothing to alias
        return x[:2]

    f = check_donation(no_alias, (0,),
                       (jax.ShapeDtypeStruct((4,), jnp.float32),), "fx")
    assert _rules(f) == ["JX005"]

    def in_place(x):             # same shape/dtype: donation consumable
        return x * 2.0

    assert check_donation(in_place, (0,),
                          (jax.ShapeDtypeStruct((4,), jnp.float32),),
                          "fx") == []


# ---------------------------------------------------------------------------
# kernel contracts: coverage + planted geometries
# ---------------------------------------------------------------------------

def test_contract_table_covers_every_kernel_and_bucket():
    from repro.serve.vision import DEFAULT_BUCKETS
    _, rows = kernel_contracts.run()
    covered = {(c.kernel, c.bucket) for c in rows}
    for name in kernel_contracts.pallas_kernel_names():
        for b in DEFAULT_BUCKETS:
            assert (name, b) in covered, (name, b)
    assert {c.classification for c in rows} <= {
        "tile_aligned", "pad_and_slice", "vmem_overflow"}


def test_contract_table_clean_at_serving_geometry():
    findings, rows = kernel_contracts.run()
    assert findings == [], [f.format() for f in findings]
    # CIFAR-scale geometry rides the pad-and-slice path (K 128 → 512 pad on
    # the matmuls, head-dim 32 → 128 lane pad on the attention kernels):
    assert all(c.classification == "pad_and_slice" for c in rows)
    qkvo = next(c for c in rows if c.site == "qkvo_proj" and c.bucket == 8)
    assert qkvo.padded["k"] == 512 and qkvo.geometry["k"] == 128
    assert qkvo.pad_mac_waste == pytest.approx(0.75)


def test_planted_misaligned_tile_geometry():
    # DeiT's 197-token sequence: M=197 → bm=128 cover pads M to 256.
    cell = kernel_contracts.matmul_cell(
        "shift_matmul", "deit_tokens", 1, 1, 197, 512, 512,
        w_bytes=1, adapt_bn=False)
    assert cell.classification == "pad_and_slice"
    assert cell.padded["m"] == 256 and cell.pad_mac_waste > 0.2


def test_planted_vmem_overflow_geometry():
    # A sequence past MAX_FUSED_N cannot keep q/k/v/out resident: the fused
    # bidirectional kernel must be classified vmem_overflow, and run() must
    # surface it as a KC001 finding.
    cell = kernel_contracts.bidir_attention_cell(1, 4, 8192, 128, 128)
    assert cell.classification == "vmem_overflow"

    from repro.nn.vit import ViTConfig
    big = ViTConfig(image_size=512, patch_size=2)    # 65536 patches
    findings, _ = kernel_contracts.run(base_cfg=big, buckets=(1,))
    assert "KC001" in _rules(findings)


def test_tile_aligned_geometry_exists():
    # A fully tile-shaped problem must classify clean — the autotune layer's
    # target state.
    cell = kernel_contracts.matmul_cell(
        "shift_matmul", "aligned", 1, 1, 256, 512, 256,
        w_bytes=1, adapt_bn=False)
    assert cell.classification == "tile_aligned"
    assert cell.pad_mac_waste == 0.0


# ---------------------------------------------------------------------------
# clean tree end-to-end + allowlist
# ---------------------------------------------------------------------------

def test_allowlist_partitions():
    f1 = Finding("JX005", "vit/x/donation", "m", "jaxpr")
    f2 = Finding("LT004", "serve/vision.py:1", "m", "lint")
    active, waived = split_allowlisted(
        [f1, f2], allowlist=(("JX005", "vit/", "reason"),))
    assert active == [f2] and waived == [f1]


@pytest.mark.slow
def test_cli_clean_tree_passes(tmp_path):
    from repro.analysis import check
    rc = check.main(["--fail-on-findings",
                     "--table", str(tmp_path / "contracts.json")])
    assert rc == 0
    assert (tmp_path / "contracts.json").exists()


# ---------------------------------------------------------------------------
# regressions for the violations this PR fixed
# ---------------------------------------------------------------------------

def test_regression_quant_weak_types():
    from repro.core.quant import binarize, po2_quantize
    x = jnp.zeros((4, 4), jnp.float32)
    b, scale = jax.eval_shape(binarize, x)
    sign, p = jax.eval_shape(po2_quantize, x)
    assert not b.weak_type and not scale.weak_type
    assert not sign.weak_type
    closed = jax.make_jaxpr(lambda v: jax.jit(binarize)(v)[0])(x)
    assert audit_closed_jaxpr(closed, "quant.binarize") == []


def test_regression_losses_weak_types():
    from repro.core.losses import smooth_top1_prob
    logits = jnp.zeros((2, 8, 4), jnp.float32)
    out = jax.eval_shape(smooth_top1_prob, logits)
    assert not out.weak_type
    closed = jax.make_jaxpr(lambda v: jax.jit(smooth_top1_prob)(v))(logits)
    assert audit_closed_jaxpr(closed, "losses.smooth_top1_prob") == []


def test_regression_engine_never_donates_images():
    from repro.nn.vit import ShiftAddViT, ViTConfig
    cfg = ViTConfig(n_layers=1, d_model=32, n_heads=2, d_ff=64)
    model = ShiftAddViT(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve.vision import BucketedViTEngine
    engine = BucketedViTEngine(model, params, buckets=(2,), freeze=True)
    # The fixed contract: no declared donation on the image buffer...
    assert engine.donate_argnums == ()
    # ...and the analyzer WOULD catch the removed hazard (images can never
    # alias logits, so a donation there is dead weight):
    spec = jax.ShapeDtypeStruct(
        (2, cfg.image_size, cfg.image_size, cfg.in_channels), jnp.float32)
    f = check_donation(engine._fwd, (0,), (spec,), "vit/regression")
    assert _rules(f) == ["JX005"]


@pytest.mark.parametrize("policy_name", ["dense", "stage1"])
def test_regression_lm_prefill_consumes_donated_cache(policy_name):
    # Pre-fix, the stage1 (linear-attention) prefill rebuilt the recurrent
    # carry from scratch and the donated cache aliased NOTHING; the additive
    # carry fix makes prefill accumulate into the donated buffers.
    from repro.core.policy import STAGE1
    from repro.serve.decode import make_prefill
    model = jaxpr_audit._tiny_lm(None if policy_name == "dense" else STAGE1)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: model.init_cache(2, max_len=21))
    prompts = jax.ShapeDtypeStruct((2, 13), jnp.int32)
    assert check_donation(make_prefill(model), (2,),
                          (params, prompts, cache),
                          f"lm/{policy_name}/prefill") == []


def test_regression_vit_serving_audit_clean():
    # The full ViT sweep audit (every policy × bucket, frozen + live) must
    # stay clean — this is where the quant weak-type fix is load-bearing
    # (the live arm runs the per-call po2 decode through core.quant).
    findings, audited = jaxpr_audit.audit_vit_serving()
    assert findings == [], [f.format() for f in findings]
    names = {a.where for a in audited}
    from repro.serve.vision import DEFAULT_BUCKETS, SWEEP_POLICIES
    for pol in SWEEP_POLICIES:
        for b in DEFAULT_BUCKETS:
            assert f"vit/{pol}/frozen/bucket={b}" in names


def test_regression_elastic_warm_pool_audit_clean_and_exact():
    # The elastic audit must cover EXACTLY the surface the zero-recompile
    # invariant counts: every reserve engine (parked spares included) ×
    # every bucket, on both the dense primary and the shiftadd degrade arm
    # — and every reserve engine must be a drop-in replica of engine 0
    # (JX008), or warm-pool replacement would break bit-identical replay.
    findings, audited = jaxpr_audit.audit_elastic_serving(
        max_replicas=2, spares=1)
    assert findings == [], [f.format() for f in findings]
    from repro.serve.vision import DEFAULT_BUCKETS
    names = {a.where for a in audited}
    expected = {f"elastic/primary/engine={e}/bucket={b}"
                for e in range(3) for b in DEFAULT_BUCKETS}
    expected |= {f"elastic/degrade/engine=0/bucket={b}"
                 for b in DEFAULT_BUCKETS}
    assert names == expected
    assert len(audited) == len(expected)        # counts exact, no dupes
    # Engines of one arm trace byte-for-byte comparable programs: the
    # inventory's equation counts must agree per (arm, bucket).
    by_key = {}
    for a in audited:
        arm, _, bucket = a.where.split("/")[1:]
        by_key.setdefault((arm, bucket), set()).add(a.n_eqns)
    assert all(len(v) == 1 for v in by_key.values()), by_key
