"""Per-architecture smoke tests (assignment: reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs) plus
decode-vs-prefill equivalence for every causal family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MLAConfig, MoEConfig
from repro.configs.registry import get_config, list_archs
from repro.core.policy import SHIFTADD
from repro.nn.model import LanguageModel


def _batch(cfg, b=2, n=16, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(k1, (b, n), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(k1, (b, n, cfg.d_model))
    labels = jax.random.randint(k2, (b, n), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model(params, batch["inputs"], train=False)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a, reduced=True).causal])
def test_arch_decode_matches_prefill(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=MoEConfig(
            n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            d_expert=cfg.moe.d_expert, capacity_factor=16.0))
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = model(params, batch["inputs"], train=False)
    cache = model.init_cache(2, max_len=16)
    outs = []
    for t in range(16):
        lg, cache = model.decode_step(params, batch["inputs"][:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - logits)))
    scale = float(jnp.std(logits)) + 1e-6
    assert err < 0.05 * max(scale, 1.0) + 0.02, f"{arch}: decode err {err}"


@pytest.mark.parametrize("arch", ["yi-9b", "rwkv6-3b", "minicpm3-4b",
                                  "qwen3-moe-30b-a3b", "hubert-xlarge"])
def test_arch_shiftadd_policy_applies(arch):
    """The paper's policy must produce a working model on every family it
    applies to (attention-free archs keep shift/MoE only — DESIGN.md §5)."""
    cfg = get_config(arch, reduced=True, policy="shiftadd").replace(
        moe_primitives_capacity=4.0)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss))
    # Shift-reparameterized params exist (w_latent leaves).
    from repro.core.reparam import count_reparameterized
    counts = count_reparameterized(params)
    assert counts["shift_latent"] > 0


def test_scan_vs_unrolled_equivalence():
    base = dict(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=31, dtype="float32", remat="none")
    cfg_s = ModelConfig(name="t", family="dense", scan_layers=True, **base)
    cfg_u = ModelConfig(name="t", family="dense", scan_layers=False, **base)
    m_s, m_u = LanguageModel(cfg_s), LanguageModel(cfg_u)
    params = m_s.init(jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 31)
    l_s, _ = m_s(params, x, train=False)
    l_u, _ = m_u(params, x, train=False)
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_u),
                               rtol=1e-5, atol=1e-5)


def test_remat_preserves_values_and_grads():
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=31, dtype="float32", scan_layers=True)
    cfg_n = ModelConfig(name="t", family="dense", remat="none", **base)
    cfg_r = ModelConfig(name="t", family="dense", remat="full", **base)
    m_n, m_r = LanguageModel(cfg_n), LanguageModel(cfg_r)
    params = m_n.init(jax.random.PRNGKey(0))
    batch = _batch(cfg_n, n=8)
    (l_n, _), g_n = jax.value_and_grad(m_n.loss, has_aux=True)(params, batch)
    (l_r, _), g_r = jax.value_and_grad(m_r.loss, has_aux=True)(params, batch)
    assert float(abs(l_n - l_r)) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(g_n),
                    jax.tree_util.tree_leaves(g_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_sliding_window_masks_long_range():
    """A token outside the window must not influence attention output."""
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=31,
                      block_pattern=("local_attn",), window=4,
                      dtype="float32", scan_layers=False, remat="none")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 31)
    x2 = x1.at[0, 0].set((x1[0, 0] + 7) % 31)  # mutate a distant token
    l1, _ = model(params, x1, train=False)
    l2, _ = model(params, x2, train=False)
    # positions ≥ 5 can't see position 0 (window 4)
    np.testing.assert_allclose(np.asarray(l1[0, 6:]), np.asarray(l2[0, 6:]),
                               rtol=1e-5, atol=1e-5)
