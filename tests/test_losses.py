"""Latency-aware load-balancing loss (paper Eq. 4) properties."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.core import losses


def test_scv_zero_for_uniform():
    x = jnp.full((4,), 3.0)
    assert float(losses.squared_coeff_variation(x)) < 1e-9


def test_scv_scale_invariant():
    x = jnp.asarray([1.0, 2.0, 3.0])
    a = float(losses.squared_coeff_variation(x))
    b = float(losses.squared_coeff_variation(10 * x))
    assert a == pytest.approx(b, rel=1e-4)


def test_latency_coefficients_normalized():
    a = losses.latency_coefficients([1.0, 3.0])
    np.testing.assert_allclose(np.asarray(a), [0.25, 0.75])


def test_importance_loss_minimized_at_inverse_latency_split():
    """α_i · Σp_i is uniform ⇔ gate mass ∝ 1/Lat — the paper's objective."""
    lat = jnp.asarray([3.0, 1.0])
    alpha = losses.latency_coefficients(lat)

    def imp(frac_fast):
        probs = jnp.stack([jnp.full((100,), 1 - frac_fast),
                           jnp.full((100,), frac_fast)], -1)
        return float(losses.importance_loss(probs, alpha))

    # optimum: fast expert gets lat_slow/(lat_slow+lat_fast) = 0.75
    assert imp(0.75) < imp(0.5) < imp(0.25)
    assert imp(0.75) < 1e-9


def test_smooth_top1_prob_bounds_and_direction():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0], [1.0, 1.0]])
    q = np.asarray(losses.smooth_top1_prob(logits, noise_std=1.0))
    assert np.all(q >= 0) and np.all(q <= 1)
    assert q[0, 0] > q[0, 1]
    assert q[1, 1] > q[1, 0]


def test_smooth_top1_prob_tied_logits_values():
    """Exact two-way tie: both tied experts sit at the decision boundary
    (Φ(0) = 0.5); the clearly-losing expert keeps its margin vs the winner."""
    logits = jnp.asarray([[1.5, 1.5, 0.0]])
    q = np.asarray(losses.smooth_top1_prob(logits, noise_std=0.75))
    assert q[0, 0] == pytest.approx(0.5, abs=1e-6)
    assert q[0, 1] == pytest.approx(0.5, abs=1e-6)
    # loser's margin is vs the winning value: Φ((0.0 − 1.5) / 0.75) = Φ(−2)
    phi_m2 = 0.5 * (1.0 + math.erf(-2.0 / math.sqrt(2.0)))
    assert q[0, 2] == pytest.approx(phi_m2, abs=1e-4)


def test_smooth_top1_prob_tie_gradient_nonzero():
    """Regression (PR-10 bugfix): with exactly tied logits the pre-fix
    margin for a tied non-argmax expert was self-referential
    (logit_i − max(logits) with logit_i == max) — d(margin)/d(logit_i)
    = 1 − 1 = 0, so the load estimator had ZERO gradient exactly where the
    router most needs one (the decision boundary a zero-init router starts
    on). Post-fix the margin for non-argmax experts is vs the winning
    value, giving the tied runner-up a real positive gradient."""
    logits = jnp.asarray([1.5, 1.5, 0.0])

    def q1(l):
        return losses.smooth_top1_prob(l[None], noise_std=1.0)[0, 1]

    g = jax.grad(q1)(logits)
    assert float(g[1]) > 0.1, np.asarray(g)  # pre-fix: exactly 0.0
    assert np.all(np.isfinite(np.asarray(g)))


def test_smooth_top1_prob_tie_deterministic_winner():
    """Ties break to the lowest index (argmax convention) — the winner's
    margin is vs the runner-up, so expert 0 of an all-tied row gets the
    same q as expert 1 but routing (clean argmax) deterministically picks
    index 0; q must not depend on evaluation order."""
    logits = jnp.asarray([[2.0, 2.0, 2.0]])
    q = np.asarray(losses.smooth_top1_prob(logits, noise_std=1.0))
    np.testing.assert_allclose(q, 0.5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(4, 64))
def test_losses_nonnegative_and_finite(n_exp, n_tok):
    key = jax.random.PRNGKey(n_exp * 100 + n_tok)
    logits = jax.random.normal(key, (n_tok, n_exp))
    probs = jax.nn.softmax(logits, -1)
    lat = jnp.abs(jax.random.normal(key, (n_exp,))) + 0.1
    val = float(losses.latency_aware_moe_loss(logits, probs, lat))
    assert np.isfinite(val) and val >= 0


def test_loss_gradient_shifts_router_toward_fast_expert():
    """Minimizing LL-loss from a uniform router must increase the fast
    expert's gate mass (directional sanity of the whole mechanism)."""
    lat = jnp.asarray([4.0, 1.0])
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 8))
    w = jnp.zeros((8, 2))

    def loss(w):
        logits = x @ w
        probs = jax.nn.softmax(logits, -1)
        return losses.latency_aware_moe_loss(logits, probs, lat)

    for _ in range(50):
        w = w - 0.5 * jax.grad(loss)(w)
    probs = jax.nn.softmax(x @ w, -1)
    mass = np.asarray(jnp.mean(probs, 0))
    assert mass[1] > mass[0], mass  # fast expert favored
