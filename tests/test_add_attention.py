"""Binary linear attention math: chunked == naive oracle == decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.core import add_attention as la
from repro.kernels import ref


def _data(b=2, h=3, n=64, dk=16, dv=20, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, n, dk)),
            jax.random.normal(ks[1], (b, h, n, dk)),
            jax.random.normal(ks[2], (b, h, n, dv)))


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_causal_chunked_matches_oracle(chunk):
    q, k, v = _data()
    out = la.binary_linear_attention(q, k, v, causal=True, chunk=chunk)
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


def test_bidirectional_matches_oracle():
    q, k, v = _data()
    out = la.binary_linear_attention(q, k, v, causal=False)
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


def test_decode_steps_match_chunked():
    q, k, v = _data(n=32)
    full = la.binary_linear_attention(q, k, v, causal=True, chunk=8)
    state = la.init_decode_state(2, 3, 16, 20)
    outs = []
    for t in range(32):
        o, state = la.binary_linear_attention_step(
            q[:, :, t], k[:, :, t], v[:, :, t], state)
        outs.append(o)
    dec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_elu1_feature_matches_quadratic():
    """The paper's plain linear-attention stage (elu+1 features)."""
    q, k, v = _data(n=32)
    out = la.binary_linear_attention(q, k, v, causal=True, chunk=8,
                                     feature="elu1")
    fq = jax.nn.elu(q) + 1
    fk = jax.nn.elu(k) + 1
    scores = jnp.einsum("bhnd,bhmd->bhnm", fq, fk) * jnp.tril(jnp.ones((32, 32)))
    expect = jnp.einsum("bhnm,bhme->bhne", scores, v) / (
        jnp.sum(scores, -1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-3, atol=1e-3)


def test_attention_weights_nonnegative_normalized():
    """Hamming-kernel property: implicit attention weights in [0,1], rows sum
    to 1 ⇒ outputs are convex combinations of values (bounded by v extremes)."""
    q, k, v = _data(n=48)
    out = np.asarray(la.binary_linear_attention(q, k, v, causal=True, chunk=16))
    vmax = np.asarray(v).max() + 1e-4
    vmin = np.asarray(v).min() - 1e-4
    assert out.max() <= vmax and out.min() >= vmin


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([8, 24, 40]), st.sampled_from([4, 8, 12]))
def test_chunked_oracle_property(b, h, n, d):
    q, k, v = _data(b, h, n, d, d, seed=n * 7 + d)
    out = la.binary_linear_attention(q, k, v, causal=True, chunk=8)
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-3, atol=1e-3)


def test_ste_gradients_flow_to_qk():
    q, k, v = _data(n=16)
    gq, gk = jax.grad(
        lambda q, k: jnp.sum(la.binary_linear_attention(q, k, v, causal=True,
                                                        chunk=8) ** 2),
        argnums=(0, 1))(q, k)
    assert float(jnp.sum(jnp.abs(gq))) > 0
    assert float(jnp.sum(jnp.abs(gk))) > 0
