"""Serving-telemetry → router-training loop (ROADMAP item 3, PR 10).

Covers the ExpertTelemetry schema + fail-open contract, the measured-α
plumbing into MoEPrimitives (and the latency-regime bugfix it flushed
out), the warmup-discarding calibration convention (satellite bugfix),
router fine-tuning against a synthetic cost model, and batch invariance
of the retrained router under the deployment freeze.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, losses
from repro.core.policy import DENSE
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve import telemetry as tm
from repro.serve.metrics import service_median_warm
from repro.serve.vision import build_policy_model
from repro.train.router_tune import router_finetune, router_grad_mask

TINY = dict(image_size=16, patch_size=4, n_layers=2, d_model=32, n_heads=2,
            d_ff=64, n_classes=4)


def _tiny_shiftadd(seed=0, **over):
    base_cfg = ViTConfig(**{**TINY, **over})
    dense = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = dense.init(jax.random.PRNGKey(seed))
    return build_policy_model(base_cfg, "shiftadd", dense, dense_params)


def _moe_feeds(model):
    return [blk.feed for blk in model.blocks
            if hasattr(blk.feed, "expert_kinds")]


# -- schema + fail-open ------------------------------------------------------

def test_telemetry_schema_round_trip(tmp_path):
    t = tm.ExpertTelemetry.from_dicts(
        entries={"mult": {1: 2e-4, 8: 9e-4}, "shift": {1: 1e-4, 8: 5e-4}},
        alpha={"mult": 3e-5, "shift": 1e-5},
        service={1: 1e-3, 8: 4e-3},
        meta={"mode": "model", "backend": "cpu", "buckets": [1, 8]})
    path = tmp_path / "TELEMETRY_experts.json"
    t.save(str(path))
    back = tm.ExpertTelemetry.load(str(path))
    assert back == t                       # frozen dataclass, full equality
    assert back.expert_latencies(("mult", "shift")) == [3e-5, 1e-5]
    assert back.expert_latencies(("shift", "mult")) == [1e-5, 3e-5]
    assert back.bucket_seconds("shift") == {1: 1e-4, 8: 5e-4}
    assert back.mode == "model"
    assert back.meta_dict["buckets"] == (1, 8)


def test_telemetry_load_fail_open(tmp_path):
    assert tm.load_telemetry(str(tmp_path / "absent.json")) is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert tm.load_telemetry(str(bad)) is None
    wrong = tmp_path / "wrong_schema.json"
    wrong.write_text('{"schema": 999, "alpha_latencies": {}}')
    assert tm.load_telemetry(str(wrong)) is None
    with pytest.raises(AssertionError):
        tm.ExpertTelemetry.load(str(wrong))   # strict load still strict


# -- measured-α plumbing -----------------------------------------------------

def test_apply_latencies_reaches_loss_alpha_and_capacity():
    """apply_expert_latencies must change BOTH consumers of α: the balance
    loss coefficients surfaced in the feed aux, and the capacity split."""
    model, params = _tiny_shiftadd()
    feed = _moe_feeds(model)[0]
    n = model.cfg.n_patches
    caps_before, _ = feed.capacity_plan(n)

    telem = tm.ExpertTelemetry.from_dicts(
        alpha={"mult": 3e-5, "shift": 1e-5}, meta={"mode": "measured"})
    n_updated = tm.apply_expert_latencies(model, telem)
    assert n_updated == len(_moe_feeds(model))
    assert feed.latencies == [3e-5, 1e-5]

    x = jax.random.normal(jax.random.PRNGKey(1), (4, n, feed.d_model))
    _, aux = feed(params["blocks"][0]["feed"], x, train=False)
    np.testing.assert_allclose(
        np.asarray(aux["alpha"]),
        np.asarray(losses.latency_coefficients([3e-5, 1e-5])), rtol=1e-6)

    caps_after, _ = feed.capacity_plan(n)     # setter cleared the memo
    assert caps_after != caps_before
    # 3:1 latency ratio → inverse-latency weights (0.25, 0.75)
    assert caps_after[1] > caps_after[0]


def test_latencies_at_serving_token_count():
    """Regression (latency-regime bugfix): capacity weights must be
    evaluated at the ACTUAL per-image token count, not the training-nominal
    1024 — at 196 tokens/128d the mult:shift ratio differs enough to move
    the static caps."""
    model, _ = _tiny_shiftadd(image_size=56, d_model=128, n_heads=4,
                              d_ff=256)
    feed = _moe_feeds(model)[0]
    n = model.cfg.n_patches
    assert n == 196
    assert feed.latencies_at(n) == energy.expert_latencies(
        n, feed.d_model, feed.d_hidden, feed.expert_kinds)
    # Expected caps derived from the analytic α at n=196 through the
    # documented ceil/clamp/top-up schedule — NOT from NOMINAL_MOE_TOKENS.
    weights = energy.inverse_latency_weights(feed.latencies_at(n))
    expected = [min(int(math.ceil(feed.capacity_factor * n * w)), n)
                for w in weights]
    deficit = n - sum(expected)
    for i in sorted(range(len(weights)), key=lambda j: -weights[j]):
        if deficit <= 0:
            break
        bump = min(deficit, n - expected[i])
        expected[i] += bump
        deficit -= bump
    caps, _ = feed.capacity_plan(n)
    assert list(caps) == expected
    # and the 1024-token regime really is a different split (the bug was
    # silent precisely because both look plausible)
    w_nominal = energy.inverse_latency_weights(energy.expert_latencies(
        1024, feed.d_model, feed.d_hidden, feed.expert_kinds))
    caps_nominal = [min(int(math.ceil(feed.capacity_factor * n * w)), n)
                    for w in w_nominal]
    assert caps_nominal != expected


def test_model_mode_alpha_ordering_matches_analytic():
    """Off-TPU extraction (mode=model) must rank experts exactly as the
    analytic model at serving geometry does — telemetry and analytic arms
    then disagree only in magnitude, never in routing direction."""
    model, params = _tiny_shiftadd()
    telem = tm.extract_expert_telemetry(model, params, buckets=(1, 2),
                                        iters=1)
    assert telem.mode == "model"
    meta = telem.meta_dict
    assert meta["measured"] is False
    assert meta["n_patches"] == model.cfg.n_patches
    feed = _moe_feeds(model)[0]
    analytic = energy.expert_latencies(model.cfg.n_patches, feed.d_model,
                                       feed.d_hidden, feed.expert_kinds)
    telem_lat = telem.expert_latencies(feed.expert_kinds)
    assert np.argsort(telem_lat).tolist() == np.argsort(analytic).tolist()
    # wall probes still recorded for visibility, every bucket
    for kind in feed.expert_kinds:
        assert set(telem.bucket_seconds(kind)) == {1, 2}
        assert all(s > 0 for s in telem.bucket_seconds(kind).values())


# -- calibration warmup convention (satellite bugfix) ------------------------

def test_service_median_warm_drops_warmup():
    assert service_median_warm([10.0, 1.0, 2.0, 3.0], warmup=1) == 2.0
    assert service_median_warm([10.0, 5.0, 1.0, 2.0, 3.0], warmup=2) == 2.0
    # degenerate: everything discarded → fall back to the full series
    assert service_median_warm([4.0], warmup=1) == 4.0


def test_vit_calibrator_discards_first_round(monkeypatch):
    """Regression: the ViT calibrator used to keep its first timed sample
    (the LM calibrator discarded it), so a compile/cache-warm spike landed
    in the service model. Scripted clock: round 0 measures 10.0 s, round 1
    measures 0.5 s — the calibrated median must be the post-warmup 0.5."""
    from repro.serve import frontend

    class _Engine:
        def infer(self, imgs):
            return jnp.zeros(())

    class _Pool:
        buckets = (1,)
        engines = [_Engine()]

    script = iter([0.0, 10.0, 100.0, 100.5])
    real = frontend.time.perf_counter

    def fake_clock():
        return next(script, real())

    monkeypatch.setattr(frontend.time, "perf_counter", fake_clock)
    svc = frontend.calibrate_service_models([_Pool()], (2, 2, 3), iters=1)[0]
    assert svc[1] == pytest.approx(0.5)       # pre-fix: 10.0


def test_bench_llloss_latency_source(tmp_path, monkeypatch):
    """Regression: bench_llloss.py hardcoded [2.0e-5, 1.0e-5] expert
    latencies — its α must come from the telemetry table when one exists
    (fail-open) and from the analytic t=1 model otherwise, with the source
    recorded."""
    import importlib.util
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "bench_llloss.py")
    spec = importlib.util.spec_from_file_location("bench_llloss", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    from repro.core.policy import ShiftAddPolicy
    policy = ShiftAddPolicy(mlp="moe_primitives", latency_aware=True,
                            balance_loss_weight=0.01)
    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=2,
                    d_model=48, n_heads=2, d_ff=96, policy=policy,
                    moe_capacity=4.0)

    monkeypatch.setattr(mod, "TELEMETRY_PATH",
                        str(tmp_path / "absent.json"))
    lat, src = mod._expert_latencies(cfg)
    assert src == "analytic"
    assert lat == energy.expert_latencies(1, cfg.d_model, cfg.d_ff,
                                          policy.moe_experts)
    assert lat != [2.0e-5, 1.0e-5]          # the old hardcode

    table = tmp_path / "TELEMETRY_experts.json"
    tm.ExpertTelemetry.from_dicts(
        alpha={"mult": 3e-5, "shift": 1e-5},
        meta={"mode": "measured"}).save(str(table))
    monkeypatch.setattr(mod, "TELEMETRY_PATH", str(table))
    lat, src = mod._expert_latencies(cfg)
    assert src == "telemetry:measured"
    assert lat == [3e-5, 1e-5]


# -- router fine-tune --------------------------------------------------------

def test_router_grad_mask_selects_only_router_leaves():
    model, params = _tiny_shiftadd()
    mask = router_grad_mask(params)
    ones = [p for p, m in jax.tree_util.tree_leaves_with_path(mask)
            if float(m) == 1.0]
    assert ones and all(
        any(getattr(k, "key", None) == "router" for k in p) for p in ones)


def test_router_finetune_decreases_loss_and_moves_share():
    """Synthetic cost model (4:1 latency gap): fine-tuning only the router
    must drive the balance loss down and move token share from the
    zero-init all-on-mult routing toward the cheap shift expert, while
    leaving every non-router parameter bit-identical."""
    model, params = _tiny_shiftadd()
    telem = tm.ExpertTelemetry.from_dicts(
        alpha={"mult": 4e-5, "shift": 1e-5}, meta={"mode": "measured"})
    tm.apply_expert_latencies(model, telem)

    shape = (model.cfg.image_size, model.cfg.image_size,
             model.cfg.in_channels)
    imgs = jax.random.normal(jax.random.PRNGKey(2), (8,) + shape)

    share0 = tm.measure_token_share(model, params, imgs)
    assert share0["shift"] == 0.0             # zero-init router: all mult

    tuned, history = router_finetune(model, params, imgs, steps=12, lr=0.05)
    assert history[-1] < history[0]

    share1 = tm.measure_token_share(model, tuned, imgs)
    assert share1["shift"] > share0["shift"]

    mask = jax.tree_util.tree_map(lambda m: float(m) == 0.0,
                                  router_grad_mask(params))
    frozen_same = jax.tree_util.tree_map(
        lambda frozen, a, b: (not frozen) or bool(jnp.array_equal(a, b)),
        mask, params, tuned)
    assert all(jax.tree_util.tree_leaves(frozen_same))


def test_retrained_router_is_batch_invariant():
    """The tuned router rides the same per-image capacity dispatch, so a
    request's logits must be bit-identical whether served solo or
    co-batched — the determinism gate check_traffic enforces on the
    router arm, reproduced at unit scale."""
    model, params = _tiny_shiftadd()
    telem = tm.ExpertTelemetry.from_dicts(
        alpha={"mult": 4e-5, "shift": 1e-5}, meta={"mode": "measured"})
    tm.apply_expert_latencies(model, telem)
    shape = (model.cfg.image_size, model.cfg.image_size,
             model.cfg.in_channels)
    imgs = jax.random.normal(jax.random.PRNGKey(3), (6,) + shape)
    tuned, _ = router_finetune(model, params, imgs, steps=8, lr=0.05)

    plan = model.prepare_inference(tuned,
                                   token_counts=(model.cfg.n_patches,))
    full = np.asarray(model.infer(plan.params, imgs))
    solo = np.concatenate([np.asarray(model.infer(plan.params, imgs[i:i + 1]))
                           for i in range(imgs.shape[0])])
    np.testing.assert_array_equal(full, solo)
    pair = np.asarray(model.infer(plan.params, imgs[2:4]))
    np.testing.assert_array_equal(full[2:4], pair)
