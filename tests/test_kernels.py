"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles.

Tolerances follow the bf16 reality of the MXU path: the kernels cast inputs
to bf16 before the dot, so comparisons are made against a bf16-cast oracle
with rtol≈2e-2 on output-scale-normalized error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops, ref


def _close(a, b, tol=2e-2):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = max(np.std(b), 1e-3)
    err = np.max(np.abs(a - b)) / scale
    assert err < tol, f"scaled err {err}"


SHIFT_SHAPES = [(8, 32, 16), (70, 300, 200), (128, 512, 128), (1, 64, 640)]


@pytest.mark.parametrize("m,k,n", SHIFT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shift_matmul_sweep(m, k, n, dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    wp = quant.pack_from_dense(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k)).astype(dtype)
    out_ref = ref.shift_matmul_ref(x.astype(jnp.float32), wp)
    out_pal = ops.shift_matmul(x, wp, "interpret")
    out_xla = ops.shift_matmul(x, wp, "xla")
    _close(out_pal, out_ref)
    _close(out_xla, out_ref, tol=1e-2 if dtype == jnp.float32 else 2e-2)


def test_shift_matmul_grad_matches_dense():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.1
    wp = quant.pack_from_dense(w)
    wq = quant.po2_weight_from_packed(wp, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    g1 = jax.grad(lambda xx: ops.shift_matmul(xx, wp, "xla").sum())(x)
    g2 = jax.grad(lambda xx: (xx @ wq).sum())(x)
    _close(g1, g2, tol=1e-3)


ADD_SHAPES = [(2, 8, 32, 16), (6, 50, 100, 60), (1, 128, 512, 128)]


@pytest.mark.parametrize("g,m,k,n", ADD_SHAPES)
def test_add_matmul_sweep(g, m, k, n):
    b = (jax.random.randint(jax.random.PRNGKey(2), (g, k, n), 0, 2, jnp.int8)
         * 2 - 1).astype(jnp.int8)
    x = jax.random.normal(jax.random.PRNGKey(3), (g, m, k))
    out_ref = ref.add_matmul_ref(x, b)
    _close(ops.add_matmul(x, b, "interpret"), out_ref)
    _close(ops.add_matmul(x, b, "xla"), out_ref, tol=1e-3)


def test_add_matmul_zero_entries_skip():
    """b=0 encodes skipped weights — they must contribute nothing."""
    b = jnp.zeros((1, 16, 8), jnp.int8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 16))
    out = ops.add_matmul(x, b, "interpret")
    np.testing.assert_allclose(np.asarray(out), 0.0)


LINATTN_SHAPES = [
    (1, 1, 128, 16, 16), (2, 2, 256, 64, 64), (1, 3, 512, 80, 80),
    (2, 1, 384, 128, 96),
]


@pytest.mark.parametrize("b,h,n,dk,dv", LINATTN_SHAPES)
def test_binary_linear_attention_kernel_sweep(b, h, n, dk, dv):
    q = jax.random.normal(jax.random.PRNGKey(4), (b, h, n, dk))
    k = jax.random.normal(jax.random.PRNGKey(5), (b, h, n, dk))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, h, n, dv))
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=True)
    out_pal = ops.binary_linear_attention_fused(q, k, v, chunk=128,
                                                impl="interpret")
    _close(out_pal, out_ref, tol=1e-3)


@pytest.mark.parametrize("g,m,k,n", [(2, 16, 64, 32), (1, 50, 128, 96),
                                     (3, 8, 256, 128)])
def test_add_matmul_bitpacked_sweep(g, m, k, n):
    """Beyond-paper 1-bit packed operand: 8× less traffic, same math."""
    from repro.kernels.add_matmul_packed import pack_bits, unpack_bits

    b = (jax.random.randint(jax.random.PRNGKey(g), (g, k, n), 0, 2, jnp.int8)
         * 2 - 1).astype(jnp.int8)
    packed = pack_bits(b)
    np.testing.assert_array_equal(np.asarray(unpack_bits(packed)),
                                  np.asarray(b, np.float32))
    x = jax.random.normal(jax.random.PRNGKey(g + 7), (g, m, k))
    out_ref = ref.add_matmul_ref(x, b)
    _close(ops.add_matmul_bitpacked(x, packed, "interpret"), out_ref)
    _close(ops.add_matmul_bitpacked(x, packed, "xla"), out_ref, tol=1e-3)


@pytest.mark.parametrize("b,h,n,dk,dv", [(1, 2, 256, 16, 16),
                                         (2, 1, 300, 24, 20)])
def test_linattn_kernel_returns_final_carry(b, h, n, dk, dv):
    """return_state must emit the exact recurrent carry (kv, ksum, vsum) the
    O(1) decode step resumes from — including when N is padded to the chunk."""
    q = jax.random.normal(jax.random.PRNGKey(10), (b, h, n, dk))
    k = jax.random.normal(jax.random.PRNGKey(11), (b, h, n, dk))
    v = jax.random.normal(jax.random.PRNGKey(12), (b, h, n, dv))
    state_ref = ref.binary_linear_attention_state_ref(q, k, v)
    for impl in ("interpret", "xla"):
        out, state = ops.binary_linear_attention_fused(
            q, k, v, chunk=128, impl=impl, return_state=True)
        _close(out, ref.binary_linear_attention_ref(q, k, v, causal=True),
               tol=1e-3)
        for key in ("kv", "ksum", "vsum", "count"):
            _close(state[key], state_ref[key], tol=1e-3)


BIDIR_SHAPES = [
    (1, 2, 64, 32, 32),      # aligned ViT bucket shape
    (2, 2, 197, 64, 48),     # DeiT token count: odd N, odd Dv
    (1, 3, 196, 80, 80),     # the benchmark's 56×56/4 geometry
    (2, 1, 8, 16, 16),       # tiny: N below one sublane tile
]


@pytest.mark.parametrize("b,h,n,dk,dv", BIDIR_SHAPES)
def test_bidir_binary_attention_kernel_sweep(b, h, n, dk, dv):
    """Fused encoder kernel (interpret) and sign-trick XLA twin vs the
    quadratic oracle with causal=False — the ViT serving attention."""
    q = jax.random.normal(jax.random.PRNGKey(20), (b, h, n, dk))
    k = jax.random.normal(jax.random.PRNGKey(21), (b, h, n, dk))
    v = jax.random.normal(jax.random.PRNGKey(22), (b, h, n, dv))
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=False)
    _close(ops.binary_linear_attention_bidir(q, k, v, impl="interpret"),
           out_ref, tol=1e-3)
    _close(ops.binary_linear_attention_bidir(q, k, v, impl="xla"),
           out_ref, tol=1e-3)


def test_bidir_matches_core_bidirectional():
    """The serving op must agree with the training-path `_bidirectional`
    (STE einsums) — same Hamming kernel, different machinery."""
    from repro.core.add_attention import binary_linear_attention

    b, h, n, dk = 2, 2, 50, 24
    q = jax.random.normal(jax.random.PRNGKey(23), (b, h, n, dk))
    k = jax.random.normal(jax.random.PRNGKey(24), (b, h, n, dk))
    v = jax.random.normal(jax.random.PRNGKey(25), (b, h, n, dk))
    want = binary_linear_attention(q, k, v, causal=False, train=False)
    for impl in ("xla", "interpret"):
        _close(ops.binary_linear_attention_bidir(q, k, v, impl=impl), want,
               tol=1e-4)


PAD_SHAPES = [(197, 100, 60),      # DeiT token count: the shape that used to
              (197, 192, 197),     # trip the m % bm hard-assert
              (5, 7, 3), (130, 513, 129)]


@pytest.mark.parametrize("m,k,n", PAD_SHAPES)
def test_shift_matmul_pallas_self_pads(m, k, n):
    """The Pallas entry point itself must pad-and-slice: direct calls with
    tile-indivisible shapes (197-token ViT batches) match the oracle."""
    from repro.kernels import shift_matmul as _shiftmm

    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    wp = quant.pack_from_dense(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    out = _shiftmm.shift_matmul_pallas(x, wp, interpret=True)
    assert out.shape == (m, n)
    _close(out, ref.shift_matmul_ref(x, wp))


@pytest.mark.parametrize("g,m,k,n", [(1, 197, 64, 48), (2, 197, 100, 60),
                                     (1, 3, 5, 2)])
def test_add_matmul_pallas_self_pads(g, m, k, n):
    from repro.kernels import add_matmul as _addmm

    b = (jax.random.randint(jax.random.PRNGKey(2), (g, k, n), 0, 2, jnp.int8)
         * 2 - 1).astype(jnp.int8)
    x = jax.random.normal(jax.random.PRNGKey(3), (g, m, k))
    out = _addmm.add_matmul_pallas(x, b, interpret=True)
    assert out.shape == (g, m, n)
    _close(out, ref.add_matmul_ref(x, b))


@pytest.mark.parametrize("g,m,k,n", [(1, 197, 64, 48), (2, 33, 72, 60)])
def test_add_matmul_packed_pallas_self_pads(g, m, k, n):
    from repro.kernels import add_matmul_packed as _pk

    b = (jax.random.randint(jax.random.PRNGKey(4), (g, k, n), 0, 2, jnp.int8)
         * 2 - 1).astype(jnp.int8)
    x = jax.random.normal(jax.random.PRNGKey(5), (g, m, k))
    out = _pk.add_matmul_packed_pallas(x, _pk.pack_bits(b), interpret=True)
    assert out.shape == (g, m, n)
    _close(out, ref.add_matmul_ref(x, b))


@pytest.mark.parametrize("m,k,n", [(197, 100, 60), (197, 192, 197)])
def test_padded_vs_unpadded_parity(m, k, n):
    """Padding must be invisible: the wrapper's answer on an odd shape equals
    the answer computed on a manually pre-padded problem, sliced back."""
    w = jax.random.normal(jax.random.PRNGKey(6), (k, n)) * 0.05
    wp = quant.pack_from_dense(w)
    x = jax.random.normal(jax.random.PRNGKey(7), (m, k))
    out = ops.shift_matmul(x, wp, "interpret")
    x_pad = jnp.pad(x, ((0, 256 - m), (0, 512 - k)))
    wp_pad = jnp.pad(wp, ((0, 512 - k), (0, 256 - n)))
    out_pad = ops.shift_matmul(x_pad, wp_pad, "interpret")[:m, :n]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_pad),
                               rtol=1e-5, atol=1e-5)


def test_linattn_kernel_state_locality():
    """Chunked kernel must equal the oracle even when the sequence spans many
    chunks (state carried in VMEM scratch across grid steps)."""
    b, h, n, d = 1, 2, 1024, 32
    q = jax.random.normal(jax.random.PRNGKey(7), (b, h, n, d))
    k = jax.random.normal(jax.random.PRNGKey(8), (b, h, n, d))
    v = jax.random.normal(jax.random.PRNGKey(9), (b, h, n, d))
    out_ref = ref.binary_linear_attention_ref(q, k, v, causal=True)
    out_pal = ops.binary_linear_attention_fused(q, k, v, chunk=128,
                                                impl="interpret")
    _close(out_pal, out_ref, tol=1e-3)
