"""Unit + property tests for the multiplication-primitive quantizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.core import quant


def test_po2_pack_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.1
    s, p = quant.po2_quantize(w)
    s2, p2 = quant.unpack_po2(quant.pack_po2(s, p))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))


def test_exponent_assembly_bitexact():
    """The bf16 exponent-bit construction must equal sign * 2^P exactly."""
    p = jnp.arange(quant.P_MIN, quant.P_MAX + 1, dtype=jnp.int32)
    for sign_val in (1.0, -1.0):
        s = jnp.full(p.shape, sign_val)
        packed = quant.pack_po2(s, p)
        w = quant.po2_weight_from_packed(packed, jnp.float32)
        ref = quant.po2_value(s, p, jnp.float32)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(ref))


def test_po2_nearest_power():
    w = jnp.asarray([0.9, 1.1, 2.7, 3.1, -0.26, -0.24])
    s, p = quant.po2_quantize(w)
    v = np.asarray(quant.po2_value(s, p))
    # log-domain rounding: |w| -> 2^round(log2|w|)
    assert v[0] == 1.0 and v[1] == 1.0
    assert v[2] == 2.0   # log2(2.7)=1.43 -> 1
    assert v[3] == 4.0   # log2(3.1)=1.63 -> 2
    assert v[4] == -0.25 and v[5] == -0.25


def test_ste_gradient_passthrough():
    w = jnp.asarray([0.3, -0.7, 1.9])
    g = jax.grad(lambda x: jnp.sum(quant.po2_quantize_ste(x) * 2.0))(w)
    np.testing.assert_allclose(np.asarray(g), 2.0)
    gb = jax.grad(lambda x: jnp.sum(quant.binarize_ste(x) * 3.0))(w)
    assert np.all(np.isfinite(np.asarray(gb)))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False, allow_infinity=False,
                          # XLA:CPU flushes subnormals in comparisons while
                          # numpy doesn't; subnormal weights clamp to ±2^-64
                          # anyway, so they're out of scope for the property.
                          allow_subnormal=False,
                          width=32), min_size=1, max_size=64))
def test_po2_quantize_within_factor_sqrt2(vals):
    """Property: po2 quantization error is bounded by a factor of sqrt(2)
    in magnitude (for values inside the representable P range)."""
    w = jnp.asarray(vals, jnp.float32)
    s, p = quant.po2_quantize(w)
    v = np.asarray(quant.po2_value(s, p), np.float64)
    aw = np.abs(np.asarray(w, np.float64))
    mask = (aw > 2.0 ** quant.P_MIN) & (aw < 2.0 ** quant.P_MAX)
    ratio = np.abs(v[mask]) / aw[mask]
    assert np.all(ratio <= np.sqrt(2) + 1e-3)
    assert np.all(ratio >= 1 / np.sqrt(2) - 1e-3)
    # sign always preserved
    nz = np.asarray(w) != 0
    assert np.all(np.sign(v[nz]) == np.sign(np.asarray(w)[nz]))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=8), st.integers(min_value=1, max_value=8))
def test_pack_shape_preserved(m, n):
    w = jax.random.normal(jax.random.PRNGKey(m * 31 + n), (m, n))
    packed = quant.pack_from_dense(w)
    assert packed.shape == (m, n)
    assert packed.dtype == jnp.int8


def test_binarize_scales():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 3.0
    b, scale = quant.binarize(x)
    assert np.allclose(float(scale), float(jnp.mean(jnp.abs(x))))
    bb = np.asarray(b)
    assert set(np.unique(bb)).issubset({-1.0, 1.0})
