"""Kernel-autotune layer tests.

Covers the tentpole and the three bugfix satellites end to end:

- TuneTable round-trip / hashability / lookup, and load_table's fail-open
  contract (a missing or corrupt table serves defaults, never crashes boot).
- Search-space legality: EVERY candidate cap combination resolves, at every
  serving site × bucket, to lane/sublane-legal blocks and an integral grid —
  the "a table entry can never produce an illegal shape" invariant.
- Model-only autotune: at the serving geometry the tuner's winner must beat
  the untuned defaults on its own cost model (the headline bk=512→128
  pad-waste fix), and packed entries are keyed at the 8-aligned K the ops
  wrapper actually looks up.
- Tile-config parity: every SEARCH_SPACE candidate, forced through the real
  `kernels.ops` wrappers by a one-entry table, matches the ref.py oracle in
  interpret mode at non-aligned (197-token) and batch-1 edge shapes. A
  hypothesis tier (active when the [test] extra is installed) fuzzes shapes.
- Pad-waste accounting parity: the MACs the launched Pallas grid actually
  executes (captured by stubbing pl.pallas_call) equal the contract table's
  padded-MAC prediction at every serving site × DEFAULT_BUCKETS geometry,
  untuned and tuned — the drift this PR's second bugfix closes.
- Impl-selection threading: a frozen impl="pallas" engine program contains
  pallas_call; an impl="xla" engine stays pallas-free even under a hostile
  process-global override (the state-leak regression).
- Nearest-rank percentiles, gate_percentile thresholds, and the
  check_vit_pallas gate picking p50 at tiny n (single-sample p99 spikes must
  not flap the gate).
"""
import importlib.util
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propshim import given, settings, st
from repro.analysis import kernel_contracts as kc
from repro.core import quant
from repro.core.policy import DENSE
from repro.kernels import add_matmul as _addmm
from repro.kernels import add_matmul_packed as _pk
from repro.kernels import autotune as at
from repro.kernels import bidir_linear_attention as _bidir
from repro.kernels import linear_attention as _linattn
from repro.kernels import ops, ref
from repro.kernels import shift_matmul as _shiftmm
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve import metrics
from repro.serve.vision import (DEFAULT_BUCKETS, BucketedViTEngine,
                                build_policy_model)

# The serving-benchmark geometry (56 px / patch 4 → 196 tokens, DeiT-T-like).
SERVE_CFG = ViTConfig(image_size=56, patch_size=4, n_layers=1, d_model=128,
                      n_heads=4, d_ff=256)

TUNABLE_KERNELS = sorted(k for k, v in at.SEARCH_SPACE.items() if v)


def _close(a, b, tol=2e-2):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = max(np.std(b), 1e-3)
    err = np.max(np.abs(a - b)) / scale
    assert err < tol, f"scaled err {err}"


def _one_entry(kernel, caps, **geom):
    return at.TuneTable.from_dicts({at.geometry_key(kernel, **geom): caps})


def _signs(key, shape):
    return (jax.random.randint(key, shape, 0, 2, jnp.int8) * 2 - 1
            ).astype(jnp.int8)


# ---------------------------------------------------------------------------
# TuneTable: round-trip, hashability, fail-open loading
# ---------------------------------------------------------------------------

def test_table_roundtrip_and_lookup(tmp_path):
    entries = {at.geometry_key("shift_matmul", g=1, m=1568, k=128, n=128):
               {"bm": 128, "bn": 128, "bk": 128}}
    table = at.TuneTable.from_dicts(entries, {"backend": "cpu",
                                              "buckets": [1, 8]})
    path = str(tmp_path / "TUNE.json")
    table.save(path, report=[{"kernel": "shift_matmul"}])
    loaded = at.TuneTable.load(path)
    assert loaded == table and hash(loaded) == hash(table)
    assert len(loaded) == 1
    assert loaded.meta_dict["buckets"] == (1, 8)
    assert loaded.lookup("shift_matmul", g=1, m=1568, k=128, n=128) == \
        {"bm": 128, "bn": 128, "bk": 128}
    # A different geometry (or kernel) is a miss → wrapper defaults.
    assert loaded.lookup("shift_matmul", g=1, m=1568, k=128, n=256) is None
    assert loaded.lookup("add_matmul", g=1, m=1568, k=128, n=128) is None


def test_table_is_a_usable_jit_cache_key():
    t1 = _one_entry("add_matmul", {"bk": 128}, g=4, m=32, k=196, n=32)
    t2 = _one_entry("add_matmul", {"bk": 256}, g=4, m=32, k=196, n=32)
    assert t1 != t2 and {t1: "a", t2: "b"}[t1] == "a"
    assert t1 == _one_entry("add_matmul", {"bk": 128}, g=4, m=32, k=196, n=32)


def test_load_table_fails_open(tmp_path):
    assert at.load_table(str(tmp_path / "absent.json")) is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert at.load_table(str(bad)) is None
    stale = tmp_path / "stale.json"
    stale.write_text('{"schema": 999, "entries": {}}')
    assert at.load_table(str(stale)) is None


# ---------------------------------------------------------------------------
# Search-space legality: any candidate is launchable at any serving geometry
# ---------------------------------------------------------------------------

def test_candidates_enumerate_the_search_space():
    assert len(at.candidates("shift_matmul")) == 18
    assert len(at.candidates("add_matmul")) == 18
    assert len(at.candidates("add_matmul_packed")) == 18
    assert len(at.candidates("linear_attention")) == 3
    assert at.candidates("bidir_linear_attention") == [{}]


@pytest.mark.parametrize("bucket", DEFAULT_BUCKETS)
def test_every_candidate_resolves_to_legal_blocks(bucket):
    for spec in kc.serving_sites(SERVE_CFG, bucket):
        for caps in at.candidates(spec["kernel"]):
            cell = kc.cell_for_site(spec, bucket, blocks=caps or None)
            assert all(g >= 1 for g in cell.grid), (spec["site"], caps)
            for dim, padded in cell.padded.items():
                assert padded >= cell.geometry[dim], (spec["site"], caps)
            if spec["kernel"] in kc.MATMUL_KERNELS:
                b = cell.blocks
                assert b["bm"] % 8 == 0, (spec["site"], caps)
                assert b["bn"] % 128 == 0, (spec["site"], caps)
                assert b["bk"] % 128 == 0, (spec["site"], caps)
                assert cell.padded["m"] % b["bm"] == 0
                assert cell.padded["n"] % b["bn"] == 0
                assert cell.padded["k"] % b["bk"] == 0
            elif spec["kernel"] == "linear_attention":
                assert cell.blocks["chunk"] <= cell.geometry["n"]
                assert cell.padded["n"] % cell.blocks["chunk"] == 0


def test_rank_candidates_sorted_feasible_deduped():
    spec = kc.serving_sites(SERVE_CFG, 8)[0]          # shift_matmul qkvo
    ranked = at.rank_candidates(spec, 8)
    assert ranked, "qkvo must have feasible candidates"
    costs = [max(c.t_compute_s, c.t_memory_s) for _, c in ranked]
    assert costs == sorted(costs)
    assert all(c.classification != "vmem_overflow" for _, c in ranked)
    resolved = [tuple(sorted(c.blocks.items())) for _, c in ranked]
    assert len(resolved) == len(set(resolved))


# ---------------------------------------------------------------------------
# Model-only autotune at the serving geometry
# ---------------------------------------------------------------------------

def test_autotune_model_only_beats_defaults():
    table, report = at.autotune(SERVE_CFG, buckets=(8,), measure=False)
    assert table.meta_dict["measured"] is False
    winners = [r for r in report if r["winner"] is not None]
    assert winners, "search produced no winners"
    for r in winners:
        # The tuner must never pick worse than the untuned defaults on its
        # own cost model (the defaults are inside the search space).
        assert r["t_model_s"] <= r["t_model_default_s"] + 1e-12, r
        assert r["pad_mac_waste"] <= r["pad_mac_waste_default"] + 1e-12, r
    qkvo = next(r for r in report
                if r["kernel"] == "shift_matmul" and r["site"] == "qkvo_proj")
    # The headline fix: the untuned K=512 panel pads d_model=128 4x.
    assert qkvo["pad_mac_waste_default"] > 0.5
    assert qkvo["pad_mac_waste"] < 0.1
    toks = 8 * SERVE_CFG.n_patches
    caps = table.lookup("shift_matmul", g=1, m=toks, k=128, n=128)
    assert caps is not None and at.geometry_key  # hit at the wrapper's key
    bidir = next(r for r in report if r["kernel"] == "bidir_linear_attention")
    assert bidir["winner"] is None and "feasibility" in bidir["note"]


def test_packed_entries_keyed_at_wrapper_visible_k():
    """pack_bits requires 8-aligned K, so at the 196-token site the packed
    wrapper looks up k=200 — the table must be keyed there, not at 196."""
    table, _ = at.autotune(SERVE_CFG, buckets=(8,), measure=False)
    g = 8 * SERVE_CFG.n_heads
    dh = SERVE_CFG.d_model // SERVE_CFG.n_heads
    assert SERVE_CFG.n_patches == 196 and 196 % 8 != 0
    assert table.lookup("add_matmul_packed", g=g, m=dh, k=200, n=dh) \
        is not None
    assert table.lookup("add_matmul_packed", g=g, m=dh, k=196, n=dh) is None


# ---------------------------------------------------------------------------
# Tile-config parity: every candidate vs the ref oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("caps", at.candidates("shift_matmul"), ids=str)
def test_shift_matmul_every_candidate_parity_197(caps):
    m, k, n = 197, 100, 130                      # nothing tile-aligned
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    wp = quant.pack_from_dense(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    table = _one_entry("shift_matmul", caps, g=1, m=m, k=k, n=n)
    _close(ops.shift_matmul(x, wp, "interpret", table),
           ref.shift_matmul_ref(x, wp))


@pytest.mark.parametrize("caps", at.candidates("add_matmul"), ids=str)
def test_add_matmul_every_candidate_parity_197(caps):
    g, m, k, n = 2, 197, 100, 60
    b = _signs(jax.random.PRNGKey(2), (g, k, n))
    x = jax.random.normal(jax.random.PRNGKey(3), (g, m, k))
    table = _one_entry("add_matmul", caps, g=g, m=m, k=k, n=n)
    _close(ops.add_matmul(x, b, "interpret", table),
           ref.add_matmul_ref(x, b))


@pytest.mark.parametrize("caps", at.candidates("add_matmul_packed"), ids=str)
def test_add_matmul_packed_every_candidate_parity(caps):
    g, m, k, n = 2, 99, 520, 60                  # 65 packed rows: off-panel
    b = _signs(jax.random.PRNGKey(4), (g, k, n))
    x = jax.random.normal(jax.random.PRNGKey(5), (g, m, k))
    table = _one_entry("add_matmul_packed", caps, g=g, m=m, k=k, n=n)
    _close(ops.add_matmul_bitpacked(x, _pk.pack_bits(b), "interpret", table),
           ref.add_matmul_ref(x, b))


@pytest.mark.parametrize("caps", at.candidates("linear_attention"), ids=str)
def test_linear_attention_every_candidate_parity_197(caps):
    b, h, n, d = 1, 2, 197, 24
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (jax.random.normal(kk, (b, h, n, d)) for kk in ks)
    table = _one_entry("linear_attention", caps, g=b * h, n=n, dk=d, dv=d)
    got = ops.binary_linear_attention_fused(q, k, v, impl="interpret",
                                            tune=table)
    _close(got, ref.binary_linear_attention_ref(q, k, v, causal=True))


@pytest.mark.parametrize("kernel", TUNABLE_KERNELS)
def test_batch1_edge_parity(kernel):
    """g=1 / m=1 single-request shapes through the extreme candidates."""
    for caps in (at.candidates(kernel)[0], at.candidates(kernel)[-1]):
        key = jax.random.PRNGKey(7)
        if kernel == "shift_matmul":
            w = jax.random.normal(key, (32, 16)) * 0.05
            wp = quant.pack_from_dense(w)
            x = jax.random.normal(key, (1, 32))
            table = _one_entry(kernel, caps, g=1, m=1, k=32, n=16)
            _close(ops.shift_matmul(x, wp, "interpret", table),
                   ref.shift_matmul_ref(x, wp))
        elif kernel == "add_matmul":
            b = _signs(key, (1, 32, 16))
            x = jax.random.normal(key, (1, 1, 32))
            table = _one_entry(kernel, caps, g=1, m=1, k=32, n=16)
            _close(ops.add_matmul(x, b, "interpret", table),
                   ref.add_matmul_ref(x, b))
        elif kernel == "add_matmul_packed":
            b = _signs(key, (1, 32, 16))
            x = jax.random.normal(key, (1, 1, 32))
            table = _one_entry(kernel, caps, g=1, m=1, k=32, n=16)
            _close(ops.add_matmul_bitpacked(x, _pk.pack_bits(b),
                                            "interpret", table),
                   ref.add_matmul_ref(x, b))
        else:
            assert kernel == "linear_attention"
            q, k, v = (jax.random.normal(kk, (1, 1, 5, 8))
                       for kk in jax.random.split(key, 3))
            table = _one_entry(kernel, caps, g=1, n=5, dk=8, dv=8)
            _close(ops.binary_linear_attention_fused(
                       q, k, v, impl="interpret", tune=table),
                   ref.binary_linear_attention_ref(q, k, v, causal=True))


# Property tier: active with the [test] extra installed, skips otherwise.

@settings(max_examples=15, deadline=None)
@given(ci=st.integers(0, 17), m=st.integers(1, 70), k=st.integers(1, 96),
       n=st.integers(1, 150))
def test_prop_shift_matmul_any_candidate_any_shape(ci, m, k, n):
    caps = at.candidates("shift_matmul")[ci]
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    wp = quant.pack_from_dense(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    table = _one_entry("shift_matmul", caps, g=1, m=m, k=k, n=n)
    _close(ops.shift_matmul(x, wp, "interpret", table),
           ref.shift_matmul_ref(x, wp))


@settings(max_examples=10, deadline=None)
@given(ci=st.integers(0, 2), g=st.integers(1, 3), n=st.integers(1, 80),
       d=st.integers(1, 48))
def test_prop_linear_attention_any_chunk_any_shape(ci, g, n, d):
    caps = at.candidates("linear_attention")[ci]
    q, k, v = (jax.random.normal(kk, (g, 1, n, d))
               for kk in jax.random.split(jax.random.PRNGKey(8), 3))
    table = _one_entry("linear_attention", caps, g=g, n=n, dk=d, dv=d)
    _close(ops.binary_linear_attention_fused(q, k, v, impl="interpret",
                                             tune=table),
           ref.binary_linear_attention_ref(q, k, v, causal=True))


# ---------------------------------------------------------------------------
# Pad-waste accounting parity: launched grid vs contract-table prediction
# ---------------------------------------------------------------------------

def _clear_kernel_caches():
    for fn in (_shiftmm.shift_matmul_pallas, _addmm.add_matmul_pallas,
               _pk.add_matmul_packed_pallas,
               _linattn.binary_linear_attention_pallas,
               _bidir.bidir_binary_attention_pallas):
        fn.clear_cache()


class _PallasCapture:
    """Stand-in for pl.pallas_call: records grid + block specs, returns a
    zeros-producing callable so the wrappers trace without running kernels."""

    def __init__(self):
        self.calls = []

    def __call__(self, kernel_fn, **kw):
        self.calls.append(kw)
        out_shape = kw["out_shape"]

        def run(*operands):
            if isinstance(out_shape, (list, tuple)):
                return tuple(jnp.zeros(s.shape, s.dtype) for s in out_shape)
            return jnp.zeros(out_shape.shape, out_shape.dtype)

        return run


@pytest.fixture
def pallas_capture(monkeypatch):
    import jax.experimental.pallas as plmod

    cap = _PallasCapture()
    monkeypatch.setattr(plmod, "pallas_call", cap)
    # jit caches compiled under the stub return zeros — flush on both sides
    # so neither direction leaks programs across tests.
    _clear_kernel_caches()
    yield cap
    _clear_kernel_caches()


def _drive_site(spec, table):
    """Call the exact ops wrapper the engine uses, at the site's geometry,
    down the impl="pallas" deployment path (the capture stub intercepts)."""
    kernel = spec["kernel"]
    if kernel == "shift_matmul":
        x = jnp.zeros((spec["m"], spec["k"]))
        wp = jnp.zeros((spec["k"], spec["n"]), jnp.int8)
        ops.shift_matmul(x, wp, "pallas", table)
    elif kernel == "add_matmul":
        x = jnp.zeros((spec["g"], spec["m"], spec["k"]))
        b = jnp.zeros((spec["g"], spec["k"], spec["n"]), jnp.int8)
        ops.add_matmul(x, b, "pallas", table)
    elif kernel == "add_matmul_packed":
        kp = -(-spec["k"] // 8) * 8          # callers pad K before pack_bits
        x = jnp.zeros((spec["g"], spec["m"], kp))
        packed = jnp.zeros((spec["g"], kp // 8, spec["n"]), jnp.uint8)
        ops.add_matmul_bitpacked(x, packed, "pallas", table)
    elif kernel == "linear_attention":
        q = jnp.zeros((spec["g"], 1, spec["n"], spec["dk"]))
        v = jnp.zeros((spec["g"], 1, spec["n"], spec["dv"]))
        ops.binary_linear_attention_fused(q, q, v, impl="pallas", tune=table)
    else:
        assert kernel == "bidir_linear_attention", kernel
        q = jnp.zeros((spec["g"], 1, spec["n"], spec["dk"]))
        v = jnp.zeros((spec["g"], 1, spec["n"], spec["dv"]))
        ops.binary_linear_attention_bidir(q, q, v, impl="pallas", tune=table)


def _executed_macs(kernel, call):
    """MACs the captured pallas_call actually executes: grid steps × the
    per-step contraction read off the operand block shapes."""
    grid = tuple(call["grid"])
    shapes = [tuple(s.block_shape) for s in call["in_specs"]]
    if kernel == "shift_matmul":
        (bm, bk), (_, bn) = shapes[0], shapes[1]
        return math.prod(grid) * bm * bn * bk
    if kernel in ("add_matmul", "add_matmul_packed"):
        # packed: the x block's lane dim is the LOGICAL K panel (8 * bk8).
        (_, bm, bk), (_, _, bn) = shapes[0], shapes[1]
        return math.prod(grid) * bm * bn * bk
    if kernel == "linear_attention":
        g, nchunks = grid
        _, chunk, dkp = shapes[0]
        _, _, dvp = shapes[2]
        # Per chunk: bq@KV + bk^T@v (chunk·dkp·dvp each) + the intra-chunk
        # causal pair s = bq@bk^T (chunk²·dkp) and s@v (chunk²·dvp).
        return g * nchunks * (2 * chunk * dkp * dvp
                              + chunk * chunk * (dkp + dvp))
    assert kernel == "bidir_linear_attention", kernel
    (g,) = grid
    _, np_, dkp = shapes[0]
    _, _, dvp = shapes[2]
    return 2 * g * np_ * dkp * dvp


def _cell_macs(cell):
    """The contract table's padded-MAC prediction (flops_padded / 2)."""
    g, p = cell.geometry["g"], cell.padded
    if cell.kernel in kc.MATMUL_KERNELS:
        return g * p["m"] * p["k"] * p["n"]
    if cell.kernel == "linear_attention":
        chunk = cell.blocks["chunk"]
        return g * (2 * p["n"] * p["dk"] * p["dv"]
                    + p["n"] * chunk * (p["dk"] + p["dv"]))
    return 2 * g * p["n"] * p["dk"] * p["dv"]


@pytest.mark.parametrize("tuned", [False, True], ids=["untuned", "tuned"])
@pytest.mark.parametrize("bucket", DEFAULT_BUCKETS)
def test_pad_waste_accounting_matches_launched_grid(pallas_capture, bucket,
                                                    tuned):
    """The second bugfix's pin: what the wrappers launch (pad-and-slice grid
    × blocks) is EXACTLY what kernel_contracts predicts — at every serving
    site, every bucket, untuned defaults and tuned winners alike. If either
    side drifts (a wrapper block law or a cell model edited alone), the MAC
    counts split and this fails naming the site."""
    for spec in kc.serving_sites(SERVE_CFG, bucket):
        if tuned:
            ranked = at.rank_candidates(spec, bucket)
            assert ranked, (spec["site"], bucket)
            caps = ranked[0][0]
            table = (_one_entry(spec["kernel"], caps,
                                **at._site_geometry(spec))
                     if caps and spec["kernel"] in at.GEOMETRY_KEYS else None)
        else:
            caps, table = None, None
        cell = kc.cell_for_site(spec, bucket, blocks=caps or None)
        before = len(pallas_capture.calls)
        _clear_kernel_caches()               # force a retrace per drive
        _drive_site(spec, table)
        assert len(pallas_capture.calls) == before + 1, spec["site"]
        call = pallas_capture.calls[-1]
        assert tuple(call["grid"]) == cell.grid, \
            (spec["site"], bucket, caps, call["grid"], cell.grid)
        got, want = _executed_macs(spec["kernel"], call), _cell_macs(cell)
        assert got == want, (spec["site"], bucket, caps, got, want,
                             cell.blocks)


# ---------------------------------------------------------------------------
# Impl-selection threading (the state-leak bugfix)
# ---------------------------------------------------------------------------

def _tiny_shiftadd_engine(impl):
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=1, d_model=32,
                    n_heads=2, d_ff=64, policy=DENSE)
    dense = ShiftAddViT(cfg)
    dense_params = dense.init(jax.random.PRNGKey(0))
    model, params = build_policy_model(cfg, "shiftadd", dense, dense_params)
    return BucketedViTEngine(model, params, buckets=(2,), freeze=True,
                             impl=impl)


def test_frozen_engine_program_impl_is_explicit():
    """The frozen impl="pallas" program must contain pallas_call; the
    impl="xla" program must not — even while a hostile process-global
    override is active (the leak this PR's first bugfix closes: engines key
    their kernels on the impl THEY were built with, never on ops state)."""
    imgs = jnp.zeros((2, 16, 16, 3))
    eng_pallas = _tiny_shiftadd_engine("pallas")
    assert "pallas_call" in str(jax.make_jaxpr(eng_pallas._fwd)(imgs))
    ops.set_default_impl("pallas")
    try:
        eng_xla = _tiny_shiftadd_engine("xla")
        assert "pallas_call" not in str(jax.make_jaxpr(eng_xla._fwd)(imgs))
    finally:
        ops.set_default_impl(None)


def test_default_impl_is_live_not_memoized():
    backend_default = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert ops.default_impl() == backend_default
    ops.set_default_impl("interpret")
    try:
        assert ops.default_impl() == "interpret"
    finally:
        ops.set_default_impl(None)
    assert ops.default_impl() == backend_default  # no stale first-call cache


# ---------------------------------------------------------------------------
# Percentile reporting (the small-n bugfix) + the pallas gate's key choice
# ---------------------------------------------------------------------------

def test_nearest_rank_is_an_observed_sample():
    xs = [1.0, 2.0, 3.0]
    assert metrics.nearest_rank(xs, 50) == 2.0
    assert metrics.nearest_rank(xs, 95) == 3.0
    assert metrics.nearest_rank(xs, 99) == 3.0   # p99 of 3 IS the max
    assert metrics.nearest_rank([5.0], 99) == 5.0
    assert metrics.nearest_rank([], 99) == 0.0
    xs100 = [float(i) for i in range(1, 101)]
    assert metrics.nearest_rank(xs100, 99) == 99.0
    assert metrics.nearest_rank(xs100, 50) == 50.0


def test_gate_percentile_thresholds():
    assert metrics.gate_percentile(1) == "p50_s"
    assert metrics.gate_percentile(19) == "p50_s"
    assert metrics.gate_percentile(20) == "p95_s"
    assert metrics.gate_percentile(99) == "p95_s"
    assert metrics.gate_percentile(100) == "p99_s"


def test_latency_summary_schema():
    s = metrics.latency_summary([0.3, 0.1, 0.2])
    assert s["n"] == 3 and s["method"] == "nearest-rank"
    assert s["p50_s"] == 0.2 and s["p95_s"] == 0.3 and s["p99_s"] == 0.3
    assert s["max_s"] == 0.3 and s["timer_resolution_s"] > 0.0
    empty = metrics.latency_summary([])
    assert empty["n"] == 0 and empty["p99_s"] == 0.0
    assert empty["method"] == "nearest-rank"


def _load_gate_module():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_vit_pallas.py")
    spec = importlib.util.spec_from_file_location(
        "check_vit_pallas_under_test", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _fake_arm(pallas_times, xla_times, mode="tpu"):
    def side(ts):
        lat = metrics.latency_summary(ts)
        return {"policies": {"shiftadd": {"recompiles_after_warmup": 0,
                                          "latency": lat,
                                          "bucket_latency": {"1": lat}}}}

    return {"mode": mode, "tuned": False,
            "skip_reason": None if mode == "tpu" else "no TPU backend",
            "pallas": side(pallas_times), "xla": side(xla_times)}


def test_pallas_gate_uses_p50_at_tiny_n(capsys):
    gate = _load_gate_module()
    fast = [0.010, 0.011, 0.012]
    assert gate.check_records(
        {"ok": {"pallas_arm": _fake_arm(fast, fast)}}) == 0
    # One spiked iteration: p99 == max at n=3 would flap the gate; the fix
    # gates on the median, which is within the noise margin.
    spiky = [0.010, 0.011, 0.900]
    assert gate.check_records(
        {"spiky": {"pallas_arm": _fake_arm(spiky, fast)}}) == 0
    # A genuinely slower pallas arm still fails at p50.
    slow = [0.013, 0.014, 0.015]
    assert gate.check_records(
        {"slow": {"pallas_arm": _fake_arm(slow, fast)}}) == 1
    # Off-TPU smoke arms skip the latency gate loudly but pass…
    assert gate.check_records(
        {"smoke": {"pallas_arm": _fake_arm(slow, fast,
                                           mode="interpret-smoke")}}) == 0
    assert "SKIP" in capsys.readouterr().out
    # …while a benchmark that dropped the arm entirely fails by omission.
    assert gate.check_records({"missing": {}}) == 1
