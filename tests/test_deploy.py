"""Deployment freeze (core.deploy): the one-time decode must be bit-exact
against the per-forward fake-quant/decode it hoists, the tree walk must catch
every shift subtree, and MoE capacity plans must be warmed for the buckets."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.deploy import freeze_params, prepare_inference
from repro.core.shift_linear import ShiftLinear


def _latent_leaf(key, k=16, n=8):
    w = jax.random.normal(key, (k, n)) * 0.1
    return {"w_latent": w, "bias": jnp.zeros((n,))}


def test_freeze_latent_decode_is_bit_exact():
    """w_deploy must equal the po2 STE forward value exactly — the whole
    frozen-vs-unfrozen exact-logit-parity guarantee rests on this."""
    leaf = _latent_leaf(jax.random.PRNGKey(0))
    frozen, count = freeze_params({"layer": leaf}, "xla")
    assert count == 1
    w_deploy = frozen["layer"]["w_deploy"]
    w_ste = quant.po2_quantize_ste(leaf["w_latent"])
    np.testing.assert_array_equal(np.asarray(w_deploy), np.asarray(w_ste))
    np.testing.assert_array_equal(np.asarray(frozen["layer"]["bias"]),
                                  np.asarray(leaf["bias"]))


def test_freeze_packed_decode_is_bit_exact():
    """Packed int8 → w_deploy must equal the per-forward exponent-bit decode
    (ref.shift_matmul_ref's po2_weight_from_packed) it hoists."""
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.05
    leaf = {"w_packed": quant.pack_from_dense(w)}
    frozen, count = freeze_params(leaf, "xla")
    assert count == 1
    np.testing.assert_array_equal(
        np.asarray(frozen["w_deploy"]),
        np.asarray(quant.po2_weight_from_packed(leaf["w_packed"], jnp.float32)))


def test_freeze_for_pallas_packs_once():
    """impl=pallas/interpret freezes to the int8 kernel format (the Pallas
    kernel decodes in VMEM — nothing to hoist beyond the packing itself)."""
    leaf = _latent_leaf(jax.random.PRNGKey(2))
    frozen, _ = freeze_params(leaf, "pallas")
    assert set(frozen) == {"w_packed", "bias"}
    np.testing.assert_array_equal(
        np.asarray(frozen["w_packed"]),
        np.asarray(quant.pack_from_dense(leaf["w_latent"])))


def test_frozen_shift_linear_forward_is_exact():
    """ShiftLinear(w_deploy) forward == ShiftLinear(w_latent) forward,
    bit-for-bit (same dot, same operand values)."""
    lin = ShiftLinear(16, 8, mode="latent")
    params = lin.init(jax.random.PRNGKey(3))
    frozen, _ = freeze_params(params, "xla")
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 16))
    np.testing.assert_array_equal(np.asarray(lin(params, x)),
                                  np.asarray(lin(frozen, x)))


def test_freeze_walk_counts_whole_model_tree():
    """On a stage-2 shiftadd ViT the walk must freeze every shift subtree:
    4 projections per layer + the Shift expert's up/down per MoE layer."""
    import dataclasses
    from repro.core.policy import DENSE
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve.vision import build_policy_model

    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64)
    dense_model = ShiftAddViT(dataclasses.replace(cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(0))
    model, params = build_policy_model(cfg, "shiftadd", dense_model,
                                       dense_params)
    plan = prepare_inference(model, params, impl="xla", token_counts=(64,))
    assert plan.frozen_linears == 2 * 4 + 2 * 2   # projections + shift expert
    assert plan.moe_layers == 2
    assert plan.token_counts == (64,)
    assert plan.impl == "xla"
    # Capacity plans were warmed on the live MoE modules.
    for blk in model.blocks:
        caps, offsets = blk.feed._capacity_plans[64]
        assert sum(caps) >= 64 and offsets[0] == 0


def test_freeze_dense_tree_is_identity():
    """A dense-policy tree has nothing to freeze; structure passes through."""
    tree = {"a": {"kernel": jnp.ones((4, 4)), "bias": jnp.zeros((4,))},
            "b": [{"kernel": jnp.ones((2, 2))}]}
    frozen, count = freeze_params(tree, "xla")
    assert count == 0
    np.testing.assert_array_equal(np.asarray(frozen["a"]["kernel"]),
                                  np.asarray(tree["a"]["kernel"]))
