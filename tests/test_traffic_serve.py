"""Traffic frontend with real engines in the loop: end-to-end report
invariants, replica-count logit parity, replay determinism, oversize
splitting parity with direct engine calls, recompile-freedom under a mixed
trace, and the data-parallel (batch → data sharded) arm."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.policy import DENSE, SHIFTADD, STAGE1
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve.frontend import (calibrate_service_model, serve_trace,
                                  traffic_sweep)
from repro.serve.replicas import ThreadPoolReplicas, make_replicas
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.traffic import Request, make_trace
from repro.serve.vision import build_policy_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BUDGETS = {"interactive": 2.0, "standard": 4.0, "relaxed": 10.0}


def _models(policy_name="shiftadd"):
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64)
    dense_model = ShiftAddViT(dataclasses.replace(cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(0))
    model, params = build_policy_model(cfg, policy_name, dense_model,
                                       dense_params)
    return model, params


def _pool(policy="shiftadd", n=1, buckets=(1, 4, 8), **kw):
    model, params = _models(policy)
    return ThreadPoolReplicas(model, params, n_replicas=n, buckets=buckets,
                              **kw).warmup()


# Synthetic service model: timing decisions in these tests must not depend
# on machine speed (logits still run through the real engine).
SVC = {1: 0.010, 4: 0.020, 8: 0.030}


def _sched(buckets=(1, 4, 8), **kw):
    kw.setdefault("max_queue_images", 64)
    return MicroBatchScheduler(buckets, SVC, **kw)


def _trace(scenario="poisson", n=40, seed=0, rate=400.0, max_size=8, **kw):
    return make_trace(scenario, n, seed, target_images_per_s=rate,
                      budgets_s=BUDGETS, max_size=max_size, **kw)


def test_end_to_end_report_invariants():
    pool = _pool("shiftadd", n=2)
    res = serve_trace(pool, _sched(), _trace(n=40))
    r = res.report
    assert r["requests"] == 40
    assert r["served_requests"] + r["shed_requests"] == 40
    assert r["recompiles_after_warmup"] == 0
    assert r["deadline_miss_rate"] == 0.0          # calibrated-feasible load
    assert r["buckets"] == [1, 4, 8]               # read off the engine
    assert 0.0 <= r["padding_waste"] < 1.0
    assert r["goodput_images_per_s"] > 0
    assert r["latency"]["p50_s"] <= r["latency"]["p99_s"]
    assert r["batches"] == len(res.batches) > 0
    assert sum(r["dispatch_reasons"].values()) == r["batches"]
    # every served request got logits with its own row count
    for req in res.requests:
        assert not req["shed"]
        assert res.logits[req["rid"]].shape == (req["size"], 10)
    pool.close()


def test_no_recompiles_under_mixed_trace():
    """The trace_count acceptance criterion at the frontend level: a mixed
    size/class/scenario stream over warm buckets never retraces."""
    pool = _pool("shiftadd", n=2)
    base = pool.trace_count
    assert base == len(pool.buckets)               # warmup: one per bucket
    for scenario, seed in (("poisson", 1), ("bursty", 2), ("diurnal", 3)):
        serve_trace(pool, _sched(), _trace(scenario, n=25, seed=seed))
    assert pool.trace_count == base, "frontend retraced after warmup"
    pool.close()


@pytest.mark.parametrize("policy", ["stage1", "shiftadd"])
def test_replay_same_seed_identical_routing_and_logits(policy):
    """Replaying the same seeded trace must reproduce the routing signature
    and the logits bit-identically — for shiftadd not merely because the
    batches replay identically, but because per-image capacity dispatch
    makes each image's logits independent of batching altogether."""
    pool = _pool(policy, n=2)
    trace = _trace(n=30, seed=7)
    a = serve_trace(pool, _sched(), trace)
    b = serve_trace(pool, _sched(), trace)
    assert a.routing_signature() == b.routing_signature()
    for rid in a.logits:
        np.testing.assert_array_equal(a.logits[rid], b.logits[rid])
    pool.close()


@pytest.mark.parametrize("policy", ["stage1", "shiftadd"])
def test_one_vs_n_replicas_bit_identical_logits_light_load(policy):
    """At a load where no dispatch ever waits on a busy replica, batch
    formation is replica-count-invariant — so 1 and 3 replicas form the
    SAME batches through the SAME bucket programs and per-request logits
    are bit-identical. Runs the shiftadd MoE arm too: per-image capacity
    dispatch removed the co-batching dependence (ISSUE 5)."""
    model, params = _models(policy)
    # Light enough that no dispatch instant ever finds the single replica
    # busy or more than one batch dispatchable (seed checked to be in that
    # regime; the composition assertion below keeps the test self-diagnosing).
    trace = _trace(n=30, seed=3, rate=5.0)
    outs = {}
    for n in (1, 3):
        pool = ThreadPoolReplicas(model, params, n_replicas=n,
                                  buckets=(1, 4, 8)).warmup()
        outs[n] = serve_trace(pool, _sched(), trace)
        pool.close()
    composition = lambda res: [(b["formed_s"], b["bucket"], b["parts"])
                               for b in res.batches]
    assert composition(outs[1]) == composition(outs[3])
    for rid in outs[1].logits:
        np.testing.assert_array_equal(outs[1].logits[rid],
                                      outs[3].logits[rid])


def test_one_vs_n_replicas_bit_identical_under_diverging_batches():
    """The strong form of the batch-invariance contract: at saturating load
    1 and 3 replicas form DIFFERENT batches (different buckets, different
    co-batching, different split points), yet per-request shiftadd logits
    are still bit-identical — an image's routing never reads its
    neighbors. Before the per-image capacity dispatch this held only at
    allclose level and the MoE arm was excluded from the 1-vs-N gate."""
    model, params = _models("shiftadd")
    trace = _trace(n=30, seed=7, rate=400.0)
    outs = {}
    for n in (1, 3):
        pool = ThreadPoolReplicas(model, params, n_replicas=n,
                                  buckets=(1, 4, 8)).warmup()
        outs[n] = serve_trace(pool, _sched(), trace)
        pool.close()
    composition = lambda res: [(b["bucket"], tuple(b["parts"]))
                               for b in res.batches]
    # Self-diagnosing: this seed/rate MUST diverge, or the test would be
    # silently re-checking the light-load case above.
    assert composition(outs[1]) != composition(outs[3])
    assert set(outs[1].logits) == set(outs[3].logits)
    for rid in outs[1].logits:
        np.testing.assert_array_equal(outs[1].logits[rid],
                                      outs[3].logits[rid])


def test_oversize_split_parity_with_direct_engine_call():
    """A lone oversize request must produce bit-identical logits through
    the scheduler's split path and through BucketedViTEngine.infer's own
    chunking — same chunk boundaries, same bucket programs, and (shiftadd
    included) each chunk batched alone in both paths."""
    pool = _pool("shiftadd", n=1)
    size = 20                                      # > max bucket 8 → 8+8+4
    req = Request(rid=0, arrival_s=0.01, size=size, klass="relaxed",
                  deadline_s=10.0, seed=123)
    trace_obj = make_trace("poisson", 1, 0, target_images_per_s=100.0,
                           budgets_s=BUDGETS)
    trace = dataclasses.replace(trace_obj, requests=(req,))
    res = serve_trace(pool, _sched(), trace)
    assert [b["n_images"] for b in res.batches] == [8, 8, 4]
    cfg = pool.engines[0].model.cfg
    imgs = jax.random.normal(
        jax.random.PRNGKey(req.seed),
        (size, cfg.image_size, cfg.image_size, cfg.in_channels))
    want = pool.engines[0].infer(imgs)
    np.testing.assert_array_equal(res.logits[0], np.asarray(want))
    pool.close()


def test_oversize_split_parity_under_co_traffic():
    """The oversize-split arm of the batch-invariance contract: when the
    split request shares the queue with other traffic (its tail part gets
    co-batched with neighbor requests), its reassembled shiftadd logits
    must STILL equal a direct engine call on its own images — neither the
    split points nor the co-batched neighbors may leak into them."""
    pool = _pool("shiftadd", n=1)
    oversize = Request(rid=0, arrival_s=0.01, size=18, klass="relaxed",
                       deadline_s=10.0, seed=123)     # → parts 8 + 8 + 2
    others = tuple(
        Request(rid=1 + i, arrival_s=0.01, size=2, klass="relaxed",
                deadline_s=10.0, seed=200 + i) for i in range(3))
    trace_obj = make_trace("poisson", 1, 0, target_images_per_s=100.0,
                           budgets_s=BUDGETS)
    trace = dataclasses.replace(trace_obj,
                                requests=(oversize,) + others)
    res = serve_trace(pool, _sched(), trace)
    # Self-diagnosing: some batch must actually mix the oversize tail with
    # neighbor requests, or this is just the lone-request test again.
    assert any(len({p[0] for p in b["parts"]}) > 1 for b in res.batches)
    cfg = pool.engines[0].model.cfg
    shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
    for req in (oversize,) + others:
        imgs = jax.random.normal(jax.random.PRNGKey(req.seed),
                                 (req.size,) + shape)
        want = pool.engines[0].infer(imgs)
        np.testing.assert_array_equal(res.logits[req.rid], np.asarray(want))
    pool.close()


def test_admission_control_sheds_under_overload():
    """Overload (tiny queue bound, high rate, one slow slot) must shed
    rather than grow the queue without bound, and shed requests count as
    deadline misses."""
    pool = _pool("shiftadd", n=1)
    sched = MicroBatchScheduler((1, 4, 8), {1: 1.0, 4: 1.0, 8: 1.0},
                                max_queue_images=8)
    res = serve_trace(pool, sched, _trace(n=30, rate=2000.0))
    r = res.report
    assert r["shed_requests"] > 0
    assert r["deadline_miss_rate"] > 0
    assert r["served_requests"] + r["shed_requests"] == 30
    shed_rids = {q["rid"] for q in res.requests if q["shed"]}
    assert shed_rids and all(rid not in res.logits for rid in shed_rids)
    pool.close()


def test_traffic_sweep_record_schema():
    """The BENCH_traffic.json record shape the CI gate consumes, including
    the replay and 1-vs-N verification fields (shiftadd arm included — the
    gate now fails on their absence) and the p99 crossover ratio."""
    cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                    n_heads=2, d_ff=64)
    rec = traffic_sweep(cfg, scenario="poisson",
                        policies=("dense", "shiftadd"), n_requests=25,
                        seed=0, replicas=2, arm="thread", buckets=(1, 4, 8),
                        verify_replay=True, verify_one_vs_n=True,
                        calibrate_iters=1)
    assert set(rec["policies"]) == {"dense", "shiftadd"}
    for r in rec["policies"].values():
        assert r["recompiles_after_warmup"] == 0
        assert r["deadline_miss_rate"] == 0.0
        assert r["replay_identical_routing"] is True
        assert r["replay_bit_identical_logits"] is True
        assert r["one_vs_n_bit_identical_logits"] is True
        assert r["one_vs_n_compared"] == 25      # full-coverage comparison
        assert r["one_vs_n_solo_shed"] == 0
        assert {"p50_s", "p95_s", "p99_s"} <= set(r["latency"])
    assert rec["shiftadd_vs_dense_p99"] > 0
    assert rec["trace"]["requests"] == 25


def _load_check_traffic():
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_traffic.py")
    spec = importlib.util.spec_from_file_location("check_traffic", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_traffic_gate_requires_shiftadd_verification(tmp_path):
    """The CI gate must FAIL when an MoE arm (shiftadd OR the
    telemetry-trained router) lacks the replay/1-vs-N verification fields
    (the old `if key in record` silently skipped the arms the determinism
    gates exist for), must fail when any present field is false, must
    enforce the router gates (arm present, p-latency at or below shiftadd,
    shift token share increased), and must pass a fully-verified record."""
    gate = _load_check_traffic()

    def arm(**extra):
        base = {"recompiles_after_warmup": 0, "deadline_miss_rate": 0.0,
                "shed_requests": 0,
                "latency": {"p50_s": 0.1, "p95_s": 0.1, "p99_s": 0.1,
                            "n": 10}}
        base.update(extra)
        return base

    verified = {k: True for k in gate.VERIFY_KEYS}
    verified.update(one_vs_n_compared=10, one_vs_n_solo_shed=0)
    share_lo = {"expert_token_share": {"mult": 1.0, "shift": 0.0}}
    share_hi = {"expert_token_share": {"mult": 0.5, "shift": 0.5}}

    def policies(**over):
        base = {"dense": arm(**verified),
                "shiftadd": arm(**verified, **share_lo),
                "router": arm(**verified, **share_hi)}
        base.update(over)
        return base

    def run(pols, ratio=0.9):
        rec = {"policies": pols, "shiftadd_vs_dense_p99": ratio,
               "trace": {"requests": 10}}
        p = tmp_path / "rec.json"
        p.write_text(__import__("json").dumps(rec))
        return gate.cli(["check_traffic", str(p)])

    # Fully verified: passes (router latency == shiftadd's, share up).
    assert run(policies()) == 0
    # An MoE arm missing the verification fields: fails (no silent skip) —
    # shiftadd and router alike.
    assert run(policies(shiftadd=arm(**share_lo))) == 1
    assert run(policies(router=arm(**share_hi))) == 1
    # A false verification field fails on any arm.
    bad = dict(verified, one_vs_n_bit_identical_logits=False)
    assert run(policies(dense=arm(**bad))) == 1
    # A partial 1-vs-N comparison fails even when every boolean is true —
    # whether the shortfall shows up as solo-pool sheds or as a compared
    # count below the trace's request count (logits-collection regression).
    partial = dict(verified, one_vs_n_solo_shed=3, one_vs_n_compared=2)
    assert run(policies(shiftadd=arm(**partial, **share_lo))) == 1
    short = dict(verified, one_vs_n_compared=7)
    assert run(policies(shiftadd=arm(**short, **share_lo))) == 1
    # Dense missing the fields is tolerated (custom sweeps may skip arms
    # the contract was never in question for).
    assert run(policies(dense=arm())) == 0
    # Router gates: missing arm, latency regression, or non-increasing
    # shift share each fail.
    no_router = policies()
    del no_router["router"]
    assert run(no_router) == 1
    slow = arm(**verified, **share_hi)
    slow["latency"] = {"p50_s": 0.2, "p95_s": 0.2, "p99_s": 0.2, "n": 10}
    assert run(policies(router=slow)) == 1
    assert run(policies(router=arm(**verified, **share_lo))) == 1
    no_share = policies(router=arm(**verified))
    assert run(no_share) == 1


def test_per_replica_engines_arm():
    """share_engine=False (one engine per slot) still serves identical
    logits — the compiled programs are deterministic clones."""
    model, params = _models("stage1")
    trace = _trace(n=15, seed=9)
    shared = ThreadPoolReplicas(model, params, n_replicas=2,
                                buckets=(1, 4, 8)).warmup()
    isolated = ThreadPoolReplicas(model, params, n_replicas=2,
                                  buckets=(1, 4, 8),
                                  share_engine=False).warmup()
    assert len(shared.engines) == 1 and len(isolated.engines) == 2
    assert isolated.trace_count == 2 * shared.trace_count
    a = serve_trace(shared, _sched(), trace)
    b = serve_trace(isolated, _sched(), trace)
    for rid in a.logits:
        np.testing.assert_array_equal(a.logits[rid], b.logits[rid])
    shared.close()
    isolated.close()


def test_data_parallel_arm_on_host_devices():
    """The sharded arm (8 simulated host devices): buckets round up to
    device-count multiples, the batch → data rule shards rows, logits are
    BIT-IDENTICAL to the single-device path — for the shiftadd MoE arm too
    (per-image dispatch is row-local, so row-sharding cannot move a logit)
    — and warm traffic never retraces."""
    code = """
        import dataclasses, jax, numpy as np
        from repro.core.policy import DENSE
        from repro.nn.vit import ShiftAddViT, ViTConfig
        from repro.serve.frontend import serve_trace
        from repro.serve.replicas import DataParallelReplicas, make_replicas
        from repro.serve.scheduler import MicroBatchScheduler
        from repro.serve.traffic import make_trace
        from repro.serve.vision import BucketedViTEngine, build_policy_model

        cfg = ViTConfig(image_size=16, patch_size=4, n_layers=2, d_model=32,
                        n_heads=2, d_ff=64)
        dense_model = ShiftAddViT(dataclasses.replace(cfg, policy=DENSE))
        dense_params = dense_model.init(jax.random.PRNGKey(0))
        for policy in ("stage1", "shiftadd"):
            model, params = build_policy_model(cfg, policy, dense_model,
                                               dense_params)
            pool = make_replicas(model, params, n_replicas=4, arm="auto",
                                 buckets=(1, 4, 8)).warmup()
            assert isinstance(pool, DataParallelReplicas), pool
            assert pool.buckets == (4, 8), pool.buckets   # rounded up to 4s
            assert pool.n_slots == 1
            base = pool.trace_count
            sched = MicroBatchScheduler(pool.buckets,
                                        {4: 0.02, 8: 0.03},
                                        max_queue_images=64)
            trace = make_trace("poisson", 20, 0, target_images_per_s=300.0,
                               budgets_s={"interactive": 2.0, "standard": 4.0,
                                          "relaxed": 10.0}, max_size=8)
            res = serve_trace(pool, sched, trace)
            assert pool.trace_count == base, "sharded arm retraced"
            assert res.report["deadline_miss_rate"] == 0.0
            eng = BucketedViTEngine(model, params, buckets=(4, 8))
            for req in trace.requests:
                imgs = jax.random.normal(
                    jax.random.PRNGKey(req.seed),
                    (req.size, 16, 16, 3))
                want = np.asarray(eng.infer(imgs))
                np.testing.assert_array_equal(res.logits[req.rid], want)
            print(policy, "sharded-arm OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "stage1 sharded-arm OK" in out.stdout
    assert "shiftadd sharded-arm OK" in out.stdout
