"""Property tests for the grouped capacity dispatcher (nn/dispatch.py) —
the component both MoE flavors (and their TPU sharding) rest on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.nn.dispatch import choose_groups, combine, dispatch


def _route(g, s, d, e, k, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    xg = jax.random.normal(ks[0], (g, s, d))
    idx = jax.random.randint(ks[1], (g, s, k), 0, e)
    gate = jax.nn.softmax(jax.random.normal(ks[2], (g, s, k)), -1)
    return xg, idx, gate


def test_identity_experts_reconstruct_gated_input():
    """With identity experts and no drops, combine(dispatch(x)) must equal
    sum_k gate_k * x for every token."""
    g, s, d, e, k = 2, 16, 8, 4, 2
    xg, idx, gate = _route(g, s, d, e, k)
    caps = [s * k] * e           # no drops possible
    buf, aux = dispatch(xg, idx, gate, caps)
    assert float(aux["drop_fraction"]) == 0.0
    y = combine(buf, aux, s, d)  # identity experts: out = buf
    expect = jnp.sum(gate[..., None] * xg[:, :, None, :], axis=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_excess_in_token_order():
    g, s, d, e = 1, 10, 4, 1
    xg = jnp.ones((g, s, d))
    idx = jnp.zeros((g, s, 1), jnp.int32)          # everyone → expert 0
    gate = jnp.ones((g, s, 1))
    buf, aux = dispatch(xg, idx, gate, [4])
    assert float(aux["drop_fraction"]) == pytest.approx(0.6)
    y = combine(buf, aux, s, d)
    # first 4 tokens kept (token-order priority), rest zero
    np.testing.assert_allclose(np.asarray(y[0, :4]), 1.0)
    np.testing.assert_allclose(np.asarray(y[0, 4:]), 0.0)


def test_heterogeneous_capacity_segments():
    """Experts own disjoint static row segments sized by their capacities."""
    g, s, d = 1, 8, 4
    xg = jnp.arange(g * s * d, dtype=jnp.float32).reshape(g, s, d)
    idx = jnp.asarray([[0, 0, 1, 1, 1, 1, 1, 1]], jnp.int32)[..., None]
    gate = jnp.ones((g, s, 1))
    caps = [2, 6]
    buf, aux = dispatch(xg, idx, gate, caps)
    np.testing.assert_allclose(np.asarray(buf[0, :2]), np.asarray(xg[0, :2]))
    np.testing.assert_allclose(np.asarray(buf[0, 2:8]), np.asarray(xg[0, 2:8]))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 32]),
       st.integers(2, 6), st.integers(1, 2), st.integers(0, 100))
def test_conservation_property(g, s, e, k, seed):
    """No token is double-processed; kept fraction matches capacity math."""
    d = 4
    xg, idx, gate = _route(g, s, d, e, k, seed)
    caps = [max(1, s // e)] * e
    buf, aux = dispatch(xg, idx, gate, caps)
    kept = (1 - float(aux["drop_fraction"])) * g * s * k
    per_expert = np.asarray(aux["tokens_per_expert"])
    expect_kept = sum(min(caps[i] * g, int(per_expert[i])) for i in range(e))
    # tokens_per_expert is summed over groups; per-group capping can only
    # reduce the kept count further:
    assert kept <= expect_kept + 1e-6
    assert np.isfinite(np.asarray(buf)).all()


@pytest.mark.parametrize("tokens,expect", [
    (4096 * 64, 64), (1_048_576, 256), (65536, 32), (128, 1), (2048, 32),
    (7, 1),
])
def test_choose_groups(tokens, expect):
    g = choose_groups(tokens)
    assert g == expect
    assert tokens % g == 0
