"""Property tests for the grouped capacity dispatcher (nn/dispatch.py) —
the component both MoE flavors (and their TPU sharding) rest on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, st  # optional-hypothesis shim

from repro.nn.dispatch import choose_groups, combine, dispatch


def _route(g, s, d, e, k, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    xg = jax.random.normal(ks[0], (g, s, d))
    idx = jax.random.randint(ks[1], (g, s, k), 0, e)
    gate = jax.nn.softmax(jax.random.normal(ks[2], (g, s, k)), -1)
    return xg, idx, gate


def test_identity_experts_reconstruct_gated_input():
    """With identity experts and no drops, combine(dispatch(x)) must equal
    sum_k gate_k * x for every token."""
    g, s, d, e, k = 2, 16, 8, 4, 2
    xg, idx, gate = _route(g, s, d, e, k)
    caps = [s * k] * e           # no drops possible
    buf, aux = dispatch(xg, idx, gate, caps)
    assert float(aux["drop_fraction"]) == 0.0
    y = combine(buf, aux, s, d)  # identity experts: out = buf
    expect = jnp.sum(gate[..., None] * xg[:, :, None, :], axis=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_excess_in_token_order():
    g, s, d, e = 1, 10, 4, 1
    xg = jnp.ones((g, s, d))
    idx = jnp.zeros((g, s, 1), jnp.int32)          # everyone → expert 0
    gate = jnp.ones((g, s, 1))
    buf, aux = dispatch(xg, idx, gate, [4])
    assert float(aux["drop_fraction"]) == pytest.approx(0.6)
    y = combine(buf, aux, s, d)
    # first 4 tokens kept (token-order priority), rest zero
    np.testing.assert_allclose(np.asarray(y[0, :4]), 1.0)
    np.testing.assert_allclose(np.asarray(y[0, 4:]), 0.0)


def test_heterogeneous_capacity_segments():
    """Experts own disjoint static row segments sized by their capacities."""
    g, s, d = 1, 8, 4
    xg = jnp.arange(g * s * d, dtype=jnp.float32).reshape(g, s, d)
    idx = jnp.asarray([[0, 0, 1, 1, 1, 1, 1, 1]], jnp.int32)[..., None]
    gate = jnp.ones((g, s, 1))
    caps = [2, 6]
    buf, aux = dispatch(xg, idx, gate, caps)
    np.testing.assert_allclose(np.asarray(buf[0, :2]), np.asarray(xg[0, :2]))
    np.testing.assert_allclose(np.asarray(buf[0, 2:8]), np.asarray(xg[0, 2:8]))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 32]),
       st.integers(2, 6), st.integers(1, 2), st.integers(0, 100))
def test_conservation_property(g, s, e, k, seed):
    """No token is double-processed; kept fraction matches capacity math."""
    d = 4
    xg, idx, gate = _route(g, s, d, e, k, seed)
    caps = [max(1, s // e)] * e
    buf, aux = dispatch(xg, idx, gate, caps)
    kept = (1 - float(aux["drop_fraction"])) * g * s * k
    per_expert = np.asarray(aux["tokens_per_expert"])
    expect_kept = sum(min(caps[i] * g, int(per_expert[i])) for i in range(e))
    # tokens_per_expert is summed over groups; per-group capping can only
    # reduce the kept count further:
    assert kept <= expect_kept + 1e-6
    assert np.isfinite(np.asarray(buf)).all()


@pytest.mark.parametrize("tokens,expect", [
    (4096 * 64, 64), (1_048_576, 256), (65536, 32), (128, 1), (2048, 32),
    (7, 1),
])
def test_choose_groups(tokens, expect):
    g = choose_groups(tokens)
    assert g == expect
    assert tokens % g == 0


# ---------------------------------------------------------------------------
# Numpy oracle + property tests (satellite: dispatch/combine coverage)
# ---------------------------------------------------------------------------

def _np_dispatch_oracle(idx, caps):
    """Reference bookkeeping: token-order keep mask + per-expert routed
    counts (pre-capping, summed over groups) — what dispatch() must report."""
    g, s, k = idx.shape
    kept = np.zeros((g, s, k), bool)
    counts = np.zeros(len(caps), np.int64)
    for gi in range(g):
        fill = [0] * len(caps)
        for t in range(s):           # token-order priority, k-major within t
            for kk in range(k):
                e = int(idx[gi, t, kk])
                counts[e] += 1
                if fill[e] < caps[e]:
                    kept[gi, t, kk] = True
                    fill[e] += 1
    return kept, counts


def _check_exact_reconstruction(g, s, e, k, seed):
    d = 4
    xg, idx, gate = _route(g, s, d, e, k, seed)
    caps = [s * k] * e               # capacities cover every token: no drops
    buf, aux = dispatch(xg, idx, gate, caps)
    assert float(aux["drop_fraction"]) == 0.0
    y = combine(buf, aux, s, d)      # identity experts
    expect = jnp.sum(gate[..., None] * xg[:, :, None, :], axis=2)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(expect))


def _check_bookkeeping_oracle(g, s, e, k, cap, seed):
    d = 4
    xg, idx, gate = _route(g, s, d, e, k, seed)
    caps = [cap] * e
    _, aux = dispatch(xg, idx, gate, caps)
    kept, counts = _np_dispatch_oracle(np.asarray(idx), caps)
    np.testing.assert_array_equal(np.asarray(aux["tokens_per_expert"]), counts)
    assert float(aux["drop_fraction"]) == pytest.approx(1.0 - kept.mean())


def test_exact_reconstruction_examples():
    """Deterministic arm of the property below (runs without hypothesis)."""
    for seed, (g, s, e, k) in enumerate([(1, 8, 2, 1), (2, 16, 4, 2),
                                         (3, 32, 3, 2)]):
        _check_exact_reconstruction(g, s, e, k, seed)


def test_bookkeeping_oracle_examples():
    for seed, (g, s, e, k, cap) in enumerate([(1, 10, 2, 1, 3),
                                              (2, 16, 3, 2, 4),
                                              (1, 32, 4, 1, 2)]):
        _check_bookkeeping_oracle(g, s, e, k, cap, seed)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(4, 24), st.integers(2, 5),
       st.integers(1, 2), st.integers(0, 10_000))
def test_exact_reconstruction_property(g, s, e, k, seed):
    """combine(dispatch(x)) == Σ_k gate_k · x EXACTLY whenever capacities
    cover all tokens (identity experts; no droppage ⇒ bit-exact scatter)."""
    _check_exact_reconstruction(g, s, e, k, seed)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(4, 24), st.integers(2, 5),
       st.integers(1, 2), st.integers(1, 6), st.integers(0, 10_000))
def test_bookkeeping_matches_numpy_oracle(g, s, e, k, cap, seed):
    """tokens_per_expert / drop_fraction under droppage must match the naive
    numpy re-implementation of token-order capacity filling."""
    _check_bookkeeping_oracle(g, s, e, k, cap, seed)


# ---------------------------------------------------------------------------
# Gather-ordered inference dispatch (ISSUE 3): parity with the scatter path
# ---------------------------------------------------------------------------

def _identity_expert_outs(buf, caps):
    """Per-expert static views of the segment buffer (identity experts)."""
    outs, off = [], 0
    for c in caps:
        outs.append(buf[:, off:off + c, :])
        off += c
    return outs


def _check_infer_matches_scatter(g, s, e, caps, seed):
    """combine_infer(dispatch_infer(x)) with identity experts must equal the
    training scatter path bit-for-bit (same token-order priority, same
    drops) — the gather rewrite may not change a single logit."""
    from repro.nn.dispatch import combine_infer, dispatch_infer

    d = 4
    xg, idx, gate = _route(g, s, d, e, 1, seed)
    buf_t, aux_t = dispatch(xg, idx, gate, caps, stats=False)
    y_t = combine(buf_t, aux_t, s, d)
    buf_i, info = dispatch_infer(xg, idx[..., 0], gate[..., 0], caps)
    y_i = combine_infer(_identity_expert_outs(buf_i, caps), info)
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_i))
    # Live buffer rows must agree too (dead rows are deliberately unmasked
    # in the gather path — nothing reads them back, so only live rows are
    # comparable).
    idx_np = np.asarray(idx[..., 0])
    bt, bi = np.asarray(buf_t), np.asarray(buf_i)
    off = 0
    for ei, cap in enumerate(caps):
        for gi in range(g):
            live = min(int((idx_np[gi] == ei).sum()), cap)
            np.testing.assert_array_equal(bt[gi, off:off + live],
                                          bi[gi, off:off + live])
        off += cap


def test_infer_dispatch_matches_scatter_examples():
    for seed, (g, s, e, caps) in enumerate([
            (1, 8, 2, [4, 4]),          # balanced, possible drops
            (2, 16, 2, [16, 16]),       # no drops possible
            (1, 10, 3, [2, 3, 5]),      # heterogeneous capacities
            (3, 12, 2, [1, 12]),        # starved expert 0
    ]):
        _check_infer_matches_scatter(g, s, e, caps, seed)


def test_infer_dispatch_all_tokens_one_expert():
    """Everyone routes to expert 0 and overflows its capacity: kept prefix in
    token order, dropped tokens contribute exactly zero."""
    from repro.nn.dispatch import combine_infer, dispatch_infer

    g, s, d = 1, 10, 4
    xg = jnp.arange(g * s * d, dtype=jnp.float32).reshape(g, s, d)
    idx = jnp.zeros((g, s), jnp.int32)
    gate = jnp.ones((g, s))
    caps = [4, 3]
    buf, info = dispatch_infer(xg, idx, gate, caps)
    np.testing.assert_array_equal(np.asarray(buf[0, :4]), np.asarray(xg[0, :4]))
    y = combine_infer(_identity_expert_outs(buf, caps), info)
    np.testing.assert_array_equal(np.asarray(y[0, :4]), np.asarray(xg[0, :4]))
    np.testing.assert_array_equal(np.asarray(y[0, 4:]), 0.0)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(4, 24), st.integers(2, 4),
       st.integers(1, 8), st.integers(0, 10_000))
def test_infer_dispatch_matches_scatter_property(g, s, e, cap, seed):
    _check_infer_matches_scatter(g, s, e, [cap] * e, seed)


def test_stats_false_skips_bookkeeping_but_combines_identically():
    """The inference dispatch path: same buffer and combine aux, no stats."""
    g, s, d, e, k = 2, 16, 8, 4, 1
    xg, idx, gate = _route(g, s, d, e, k)
    caps = [s] * e
    buf_t, aux_t = dispatch(xg, idx, gate, caps, stats=True)
    buf_i, aux_i = dispatch(xg, idx, gate, caps, stats=False)
    assert "tokens_per_expert" not in aux_i and "drop_fraction" not in aux_i
    np.testing.assert_array_equal(np.asarray(buf_t), np.asarray(buf_i))
    np.testing.assert_array_equal(np.asarray(combine(buf_t, aux_t, s, d)),
                                  np.asarray(combine(buf_i, aux_i, s, d)))
