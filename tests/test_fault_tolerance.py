"""Fault tolerance: checkpoint/restart, deterministic replay, elastic remesh,
straggler detection, checkpoint atomicity."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticLMData
from repro.distributed import sharding as sl
from repro.distributed.fault_tolerance import (
    FailureInjector,
    SimulatedFailure,
    StragglerMonitor,
    elastic_mesh_shape,
)
from repro.nn.model import LanguageModel
from repro.train import train_loop


def _tiny(total_steps=20, **kw):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", scan_layers=True, remat="none")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2,
                       total_steps=total_steps, global_batch=4, seq_len=16,
                       checkpoint_every=5, **kw)
    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    return model, tcfg, data


def test_failure_recovery_and_deterministic_replay(tmp_path):
    model, tcfg, data = _tiny()
    ckpt = Checkpointer(str(tmp_path), keep=2)
    inj = FailureInjector(fail_at_steps=(8,))
    state, hist = train_loop(model, tcfg, data, checkpointer=ckpt,
                             failure_injector=inj)
    assert int(state["step"]) == tcfg.total_steps
    replayed = [h["loss"] for h in hist if h["step"] == 6]
    assert len(replayed) == 2            # once before failure, once after
    assert abs(replayed[0] - replayed[1]) < 1e-4   # deterministic replay


def test_failure_without_checkpointer_restarts_current_state():
    model, tcfg, data = _tiny(total_steps=6)
    inj = FailureInjector(fail_at_steps=(3,))
    state, hist = train_loop(model, tcfg, data, failure_injector=inj)
    assert int(state["step"]) == 6


def test_too_many_failures_raises():
    model, tcfg, data = _tiny(total_steps=10)

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 3:
                raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        train_loop(model, tcfg, data, failure_injector=AlwaysFail(),
                   max_restarts=2)


def test_checkpoint_atomicity_fallback(tmp_path):
    """A corrupted newest checkpoint must fall back to the previous one."""
    model, tcfg, data = _tiny(total_steps=10)
    ckpt = Checkpointer(str(tmp_path), keep=3, async_save=False)
    state, _ = train_loop(model, tcfg, data, checkpointer=ckpt)
    steps = ckpt.all_steps()
    assert len(steps) >= 2
    # corrupt the newest shard
    newest = os.path.join(str(tmp_path), f"step_{steps[-1]:08d}",
                          "shard_00000.npz")
    with open(newest, "wb") as f:
        f.write(b"garbage")
    restored = ckpt.restore_latest(state)
    assert restored is not None
    step, _ = restored
    assert step == steps[-2]


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)
    assert mon.flagged[0][0] == 10


@pytest.mark.parametrize("n,expect", [
    (512, ((2, 16, 16), ("pod", "data", "model"))),
    (256, ((16, 16), ("data", "model"))),
    (480, ((2, 15, 16), ("pod", "data", "model"))),
    (8, ((1, 8), ("data", "model"))),
])
def test_elastic_mesh_shapes(n, expect):
    shape, axes = elastic_mesh_shape(n, model_parallel=16,
                                     multi_pod=(n >= 512 or n == 480))
    assert int(np.prod(shape)) <= n
    assert shape == expect[0] and axes == expect[1]


def test_elastic_restore_across_device_counts(tmp_path):
    """State saved under one sharding restores under another (fewer chips)."""
    model, tcfg, data = _tiny(total_steps=6)
    ckpt = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state, _ = train_loop(model, tcfg, data, checkpointer=ckpt)
    # restore with explicit (single-device) shardings
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = sl.make_mesh((1,), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state)
    step, restored = ckpt.restore_latest(state, shardings)
    leaves = jax.tree_util.tree_leaves(restored)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves
               if np.asarray(l).dtype.kind == "f")
