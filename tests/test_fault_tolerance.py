"""Fault tolerance: checkpoint/restart, deterministic replay, elastic remesh,
straggler detection, checkpoint atomicity."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticLMData
from repro.distributed import sharding as sl
from repro.distributed.fault_tolerance import (
    FailureInjector,
    SimulatedFailure,
    StragglerMonitor,
    elastic_mesh_shape,
)
from repro.nn.model import LanguageModel
from repro.train import train_loop


def _tiny(total_steps=20, **kw):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", scan_layers=True, remat="none")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2,
                       total_steps=total_steps, global_batch=4, seq_len=16,
                       checkpoint_every=5, **kw)
    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    return model, tcfg, data


def test_failure_recovery_and_deterministic_replay(tmp_path):
    model, tcfg, data = _tiny()
    ckpt = Checkpointer(str(tmp_path), keep=2)
    inj = FailureInjector(fail_at_steps=(8,))
    state, hist = train_loop(model, tcfg, data, checkpointer=ckpt,
                             failure_injector=inj)
    assert int(state["step"]) == tcfg.total_steps
    replayed = [h["loss"] for h in hist if h["step"] == 6]
    assert len(replayed) == 2            # once before failure, once after
    assert abs(replayed[0] - replayed[1]) < 1e-4   # deterministic replay


def test_failure_without_checkpointer_restarts_current_state():
    model, tcfg, data = _tiny(total_steps=6)
    inj = FailureInjector(fail_at_steps=(3,))
    state, hist = train_loop(model, tcfg, data, failure_injector=inj)
    assert int(state["step"]) == 6


def test_too_many_failures_raises():
    model, tcfg, data = _tiny(total_steps=10)

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 3:
                raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        train_loop(model, tcfg, data, failure_injector=AlwaysFail(),
                   max_restarts=2)


def test_checkpoint_atomicity_fallback(tmp_path):
    """A corrupted newest checkpoint must fall back to the previous one."""
    model, tcfg, data = _tiny(total_steps=10)
    ckpt = Checkpointer(str(tmp_path), keep=3, async_save=False)
    state, _ = train_loop(model, tcfg, data, checkpointer=ckpt)
    steps = ckpt.all_steps()
    assert len(steps) >= 2
    # corrupt the newest shard
    newest = os.path.join(str(tmp_path), f"step_{steps[-1]:08d}",
                          "shard_00000.npz")
    with open(newest, "wb") as f:
        f.write(b"garbage")
    restored = ckpt.restore_latest(state)
    assert restored is not None
    step, _ = restored
    assert step == steps[-2]


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)
    assert mon.flagged[0][0] == 10


@pytest.mark.parametrize("n,expect", [
    (512, ((2, 16, 16), ("pod", "data", "model"))),
    (256, ((16, 16), ("data", "model"))),
    (480, ((2, 15, 16), ("pod", "data", "model"))),
    (8, ((1, 8), ("data", "model"))),
])
def test_elastic_mesh_shapes(n, expect):
    shape, axes = elastic_mesh_shape(n, model_parallel=16,
                                     multi_pod=(n >= 512 or n == 480))
    assert int(np.prod(shape)) <= n
    assert shape == expect[0] and axes == expect[1]


def test_elastic_restore_across_device_counts(tmp_path):
    """State saved under one sharding restores under another (fewer chips)."""
    model, tcfg, data = _tiny(total_steps=6)
    ckpt = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state, _ = train_loop(model, tcfg, data, checkpointer=ckpt)
    # restore with explicit (single-device) shardings
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = sl.make_mesh((1,), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state)
    step, restored = ckpt.restore_latest(state, shardings)
    leaves = jax.tree_util.tree_leaves(restored)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves
               if np.asarray(l).dtype.kind == "f")


@pytest.mark.parametrize("n,expect", [
    # Non-power-of-two survivors: the data axis takes the integer quotient
    # (floor), so the mesh uses the largest expressible subset — product
    # must stay <= n and the model axis must stay fixed at 16.
    (100, ((6, 16), ("data", "model"))),
    (17, ((1, 16), ("data", "model"))),
    (33, ((2, 16), ("data", "model"))),
    # Below the model axis the model axis itself shrinks, to the largest
    # power of two that fits — including odd survivor counts.
    (13, ((1, 8), ("data", "model"))),
    (3, ((1, 2), ("data", "model"))),
    # Degenerate 1-chip survival: a valid (1, 1) mesh, never 0.
    (1, ((1, 1), ("data", "model"))),
])
def test_elastic_mesh_shape_non_pow2_and_degenerate(n, expect):
    shape, axes = elastic_mesh_shape(n, model_parallel=16)
    assert shape == expect[0] and axes == expect[1]
    assert int(np.prod(shape)) <= n
    assert all(d >= 1 for d in shape)


def test_elastic_mesh_shape_multi_pod_non_pow2():
    # 100 survivors multi-pod: 2 pods of floor(6/2)=3 data rows each.
    shape, axes = elastic_mesh_shape(100, model_parallel=16, multi_pod=True)
    assert shape == (2, 3, 16) and axes == ("pod", "data", "model")
    # 1 chip multi-pod collapses to the degenerate single-pod mesh.
    shape, axes = elastic_mesh_shape(1, model_parallel=16, multi_pod=True)
    assert shape == (1, 1, 1) and int(np.prod(shape)) == 1


def test_failure_injector_virtual_time_schedule():
    from repro.distributed.fault_tolerance import ReplicaFault

    faults = (ReplicaFault(at_s=2.0, kind="slowdown", slot=1, factor=3.0),
              ReplicaFault(at_s=0.5, kind="kill", slot=0),
              ReplicaFault(at_s=2.0, kind="kill", slot=0))
    inj = FailureInjector(faults=faults)
    # Sorted by (at_s, slot); next_fault_s sees the earliest unfired.
    assert inj.next_fault_s() == 0.5
    assert inj.due(0.4) == []
    fired = inj.due(0.5)
    assert [f.kind for f in fired] == ["kill"]
    # Both t=2.0 faults pop together, slot order.
    fired = inj.due(2.0)
    assert [(f.at_s, f.slot) for f in fired] == [(2.0, 0), (2.0, 1)]
    assert inj.next_fault_s() is None and inj.due(99.0) == []
    assert len(inj.fired) == 3
    # reset_faults rewinds for replay: the same schedule fires again.
    inj.reset_faults()
    assert inj.next_fault_s() == 0.5
    assert len(inj.due(99.0)) == 3


def test_replica_fault_rejects_unknown_kind():
    from repro.distributed.fault_tolerance import ReplicaFault

    with pytest.raises(AssertionError):
        ReplicaFault(at_s=1.0, kind="powercycle")


@pytest.fixture(scope="module")
def tiny_vit_pool_parts():
    from repro.nn.vit import ShiftAddViT, ViTConfig

    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=1,
                    d_model=32, n_heads=2, d_ff=64)
    model = ShiftAddViT(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_threadpool_replicas_close_is_idempotent(tiny_vit_pool_parts):
    from repro.serve.replicas import ThreadPoolReplicas

    model, params = tiny_vit_pool_parts
    pool = ThreadPoolReplicas(model, params, n_replicas=2,
                              buckets=(1, 2)).warmup()
    assert not pool.closed
    pool.close()
    assert pool.closed
    pool.close()                      # double close: a no-op, no raise
    assert pool.closed
    with pytest.raises(RuntimeError):
        pool.submit(0, np.zeros((1, 16, 16, 3), np.float32))


def test_threadpool_replicas_close_with_pending_future(tiny_vit_pool_parts):
    from repro.serve.replicas import ThreadPoolReplicas

    model, params = tiny_vit_pool_parts
    pool = ThreadPoolReplicas(model, params, n_replicas=1,
                              buckets=(1, 2)).warmup()
    fut = pool.submit(0, np.zeros((2, 16, 16, 3), np.float32))
    pool.close()                      # waits for the in-flight submission
    logits, wall_s = fut.result(timeout=0)   # already resolved by close()
    assert logits.shape == (2, 4) and wall_s > 0
    pool.close()                      # still idempotent after draining
