"""Heterogeneous MoE: latency-aware capacities, dispatch conservation, LL-loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.moe_primitives import MoEPrimitives


def _moe(**kw):
    kw.setdefault("capacity_factor", 8.0)
    return MoEPrimitives(16, 32, **kw)


def test_latency_aware_capacities_favor_fast_expert():
    moe = _moe(capacity_factor=1.0)
    caps = moe.capacities(100)
    # Shift is faster (1 B/weight) ⇒ larger capacity than Mult.
    assert moe.latencies[1] < moe.latencies[0]
    assert caps[1] > caps[0]


def test_uniform_capacities_when_not_latency_aware():
    moe = _moe(capacity_factor=1.0, latency_aware=False)
    caps = moe.capacities(100)
    assert caps[0] == caps[1]


def test_dispatch_conservation_no_drop():
    """With ample capacity every token is processed by exactly its top-1
    expert: output equals running the chosen expert per token."""
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    y, aux = moe(params, x, train=False)
    assert float(aux["drop_fraction"]) == 0.0
    top1 = np.asarray(aux["top1"])
    probs = np.asarray(aux["probs"])
    for t in range(30):
        e = int(top1[t])
        out_e = moe.experts[e](params["experts"][e], x[t][None])[0]
        expect = probs[t, e] * np.asarray(out_e)
        np.testing.assert_allclose(np.asarray(y[t]), expect, rtol=2e-3,
                                   atol=2e-3)


def test_drop_accounting_under_tight_capacity():
    moe = _moe(capacity_factor=0.2)
    params = moe.init(jax.random.PRNGKey(0))
    # 60 tokens: not a multiple of 32 ⇒ a single routing group, so the
    # global-capacity accounting below is exact.
    x = jax.random.normal(jax.random.PRNGKey(1), (60, 16))
    y, aux = moe(params, x, train=False)
    kept = sum(min(int(c), int(t)) for c, t in
               zip(aux["capacities"], aux["tokens_per_expert"]))
    assert float(aux["drop_fraction"]) == pytest.approx(1 - kept / 60, abs=1e-6)


def test_balance_loss_differentiable_and_orders():
    """LL-loss must be lower for a router matching the latency-aware target
    split than for one inverting it."""
    lat = jnp.asarray([3.0, 1.0])  # expert 1 is 3x faster
    alpha = losses.latency_coefficients(lat)
    n = 4000
    key = jax.random.PRNGKey(0)

    def loss_for(frac_to_fast):
        # logits strongly favoring expert 1 for frac of tokens
        r = jax.random.uniform(key, (n,))
        sel = (r < frac_to_fast).astype(jnp.float32)
        logits = jnp.stack([(1 - sel) * 4.0, sel * 4.0], -1)
        probs = jax.nn.softmax(logits, -1)
        return float(losses.latency_aware_moe_loss(logits, probs, lat))

    matched = loss_for(0.75)    # fast expert gets 3/4 — matches 1/Lat
    inverted = loss_for(0.25)
    assert matched < inverted


def test_moe_grads_reach_router_and_both_experts():
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (40, 16))

    def loss(p):
        y, aux = moe(p, x, train=False)
        return jnp.sum(y ** 2) * 1e-3 + aux["balance_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]["kernel"]))) > 0
    for ge in g["experts"]:
        total = sum(float(jnp.sum(jnp.abs(l))) for l in
                    jax.tree_util.tree_leaves(ge))
        assert total > 0


def test_custom_experts_and_latencies():
    from repro.nn.layers import MLP

    experts = [MLP(16, 32, "swiglu", "dense"), MLP(16, 32, "swiglu", "shift")]
    moe = MoEPrimitives(16, 32, experts=experts, latencies=[2e-5, 1e-5],
                        capacity_factor=4.0)
    params = moe.init(jax.random.PRNGKey(0))
    y, aux = moe(params, jax.random.normal(jax.random.PRNGKey(1), (12, 16)))
    assert y.shape == (12, 16)
    np.testing.assert_allclose(np.asarray(aux["alpha"]),
                               [2 / 3, 1 / 3], rtol=1e-5)
