"""Heterogeneous MoE: latency-aware capacities, dispatch conservation, LL-loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.moe_primitives import MoEPrimitives


def _moe(**kw):
    kw.setdefault("capacity_factor", 8.0)
    return MoEPrimitives(16, 32, **kw)


def test_latency_aware_capacities_favor_fast_expert():
    moe = _moe(capacity_factor=1.0)
    caps = moe.capacities(100)
    # Shift is faster (1 B/weight) ⇒ larger capacity than Mult.
    assert moe.latencies[1] < moe.latencies[0]
    assert caps[1] > caps[0]


def test_uniform_capacities_when_not_latency_aware():
    moe = _moe(capacity_factor=1.0, latency_aware=False)
    caps = moe.capacities(100)
    assert caps[0] == caps[1]


def test_dispatch_conservation_no_drop():
    """With ample capacity every token is processed by exactly its top-1
    expert: output equals running the chosen expert per token."""
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    y, aux = moe(params, x, train=False)
    assert float(aux["drop_fraction"]) == 0.0
    top1 = np.asarray(aux["top1"])
    probs = np.asarray(aux["probs"])
    for t in range(30):
        e = int(top1[t])
        out_e = moe.experts[e](params["experts"][e], x[t][None])[0]
        expect = probs[t, e] * np.asarray(out_e)
        np.testing.assert_allclose(np.asarray(y[t]), expect, rtol=2e-3,
                                   atol=2e-3)


def test_drop_accounting_under_tight_capacity():
    moe = _moe(capacity_factor=0.2)
    params = moe.init(jax.random.PRNGKey(0))
    # 60 tokens: not a multiple of 32 ⇒ a single routing group, so the
    # global-capacity accounting below is exact.
    x = jax.random.normal(jax.random.PRNGKey(1), (60, 16))
    y, aux = moe(params, x, train=False)
    kept = sum(min(int(c), int(t)) for c, t in
               zip(aux["capacities"], aux["tokens_per_expert"]))
    assert float(aux["drop_fraction"]) == pytest.approx(1 - kept / 60, abs=1e-6)


def test_balance_loss_differentiable_and_orders():
    """LL-loss must be lower for a router matching the latency-aware target
    split than for one inverting it."""
    lat = jnp.asarray([3.0, 1.0])  # expert 1 is 3x faster
    alpha = losses.latency_coefficients(lat)
    n = 4000
    key = jax.random.PRNGKey(0)

    def loss_for(frac_to_fast):
        # logits strongly favoring expert 1 for frac of tokens
        r = jax.random.uniform(key, (n,))
        sel = (r < frac_to_fast).astype(jnp.float32)
        logits = jnp.stack([(1 - sel) * 4.0, sel * 4.0], -1)
        probs = jax.nn.softmax(logits, -1)
        return float(losses.latency_aware_moe_loss(logits, probs, lat))

    matched = loss_for(0.75)    # fast expert gets 3/4 — matches 1/Lat
    inverted = loss_for(0.25)
    assert matched < inverted


def test_moe_grads_reach_router_and_both_experts():
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (40, 16))

    def loss(p):
        y, aux = moe(p, x, train=False)
        return jnp.sum(y ** 2) * 1e-3 + aux["balance_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]["kernel"]))) > 0
    for ge in g["experts"]:
        total = sum(float(jnp.sum(jnp.abs(l))) for l in
                    jax.tree_util.tree_leaves(ge))
        assert total > 0


def test_capacities_cover_tokens_whenever_cf_ge_1():
    """Structural guarantee: capacity_factor >= 1.0 ⇒ sum(caps) >= n_tokens,
    for any latency skew, expert count and (small) group size — the rounding
    + clamp regression surface."""
    latency_sets = [
        [1.0, 1.0], [3.0, 1.0], [1e-3, 1e-9], [1.0, 2.0, 40.0],
        [5.0, 1.0, 0.1, 0.1],
    ]
    for lats in latency_sets:
        kinds = tuple(["mult"] + ["shift"] * (len(lats) - 1))
        for cf in (1.0, 1.25, 2.0):
            moe = MoEPrimitives(8, 16, expert_kinds=kinds, latencies=lats,
                                capacity_factor=cf)
            for n in list(range(1, 65)) + [197, 1024]:
                caps = moe.capacities(n)
                assert sum(caps) >= n, (lats, cf, n, caps)
                assert all(0 <= c <= n for c in caps), (lats, cf, n, caps)


def test_no_drop_regression_at_capacity_factor_125():
    """drop_fraction == 0 at capacity_factor 1.25 when the routed load fits
    the capacity split — pins that small-group rounding never shrinks a cap
    below its share."""
    moe = MoEPrimitives(16, 32, capacity_factor=1.25, latency_aware=False)
    params = moe.init(jax.random.PRNGKey(0))
    # Steer routing deterministically: logits = x @ W with W sending tokens
    # with x[:,0] > 0 to expert 0 and the rest to expert 1 — an exact 4/4
    # split of 8 tokens against per-expert caps of ceil(1.25*8/2) = 5.
    w = jnp.zeros((16, 2)).at[0, 0].set(4.0).at[0, 1].set(-4.0)
    params = dict(params, router={"kernel": w})
    sign = jnp.repeat(jnp.asarray([1.0, -1.0]), 4)[:, None]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 0.1
    x = x.at[:, 0].set(sign[:, 0])
    caps = moe.capacities(8)
    assert sum(caps) >= 8 and min(caps) >= 4
    y, aux = moe(params, x, train=False)
    assert float(aux["drop_fraction"]) == 0.0


def test_infer_matches_call_and_is_deterministic():
    """The inference dispatch path must equal the train=False forward and be
    bit-stable across calls (no rng consumed anywhere)."""
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    y_call, _aux = moe(params, x, train=False)
    y_inf = moe.infer(params, x)
    np.testing.assert_allclose(np.asarray(y_inf), np.asarray(y_call),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(moe.infer(params, x)),
                                  np.asarray(y_inf))


def test_custom_experts_and_latencies():
    from repro.nn.layers import MLP

    experts = [MLP(16, 32, "swiglu", "dense"), MLP(16, 32, "swiglu", "shift")]
    moe = MoEPrimitives(16, 32, experts=experts, latencies=[2e-5, 1e-5],
                        capacity_factor=4.0)
    params = moe.init(jax.random.PRNGKey(0))
    y, aux = moe(params, jax.random.normal(jax.random.PRNGKey(1), (12, 16)))
    assert y.shape == (12, 16)
    np.testing.assert_allclose(np.asarray(aux["alpha"]),
                               [2 / 3, 1 / 3], rtol=1e-5)
