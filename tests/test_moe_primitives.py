"""Heterogeneous MoE: latency-aware capacities, dispatch conservation, LL-loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.moe_primitives import MoEPrimitives


def _moe(**kw):
    kw.setdefault("capacity_factor", 8.0)
    return MoEPrimitives(16, 32, **kw)


def test_latency_aware_capacities_favor_fast_expert():
    moe = _moe(capacity_factor=1.0)
    caps = moe.capacities(100)
    # Shift is faster (1 B/weight) ⇒ larger capacity than Mult.
    assert moe.latencies[1] < moe.latencies[0]
    assert caps[1] > caps[0]


def test_uniform_capacities_when_not_latency_aware():
    moe = _moe(capacity_factor=1.0, latency_aware=False)
    caps = moe.capacities(100)
    assert caps[0] == caps[1]


def test_dispatch_conservation_no_drop():
    """With ample capacity every token is processed by exactly its top-1
    expert: output equals running the chosen expert per token."""
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    y, aux = moe(params, x, train=False)
    assert float(aux["drop_fraction"]) == 0.0
    top1 = np.asarray(aux["top1"])
    probs = np.asarray(aux["probs"])
    for t in range(30):
        e = int(top1[t])
        out_e = moe.experts[e](params["experts"][e], x[t][None])[0]
        expect = probs[t, e] * np.asarray(out_e)
        np.testing.assert_allclose(np.asarray(y[t]), expect, rtol=2e-3,
                                   atol=2e-3)


def test_drop_accounting_under_tight_capacity():
    moe = _moe(capacity_factor=0.2)
    params = moe.init(jax.random.PRNGKey(0))
    # 60 tokens: not a multiple of 32 ⇒ a single routing group, so the
    # global-capacity accounting below is exact.
    x = jax.random.normal(jax.random.PRNGKey(1), (60, 16))
    y, aux = moe(params, x, train=False)
    kept = sum(min(int(c), int(t)) for c, t in
               zip(aux["capacities"], aux["tokens_per_expert"]))
    assert float(aux["drop_fraction"]) == pytest.approx(1 - kept / 60, abs=1e-6)


def test_balance_loss_differentiable_and_orders():
    """LL-loss must be lower for a router matching the latency-aware target
    split than for one inverting it."""
    lat = jnp.asarray([3.0, 1.0])  # expert 1 is 3x faster
    alpha = losses.latency_coefficients(lat)
    n = 4000
    key = jax.random.PRNGKey(0)

    def loss_for(frac_to_fast):
        # logits strongly favoring expert 1 for frac of tokens
        r = jax.random.uniform(key, (n,))
        sel = (r < frac_to_fast).astype(jnp.float32)
        logits = jnp.stack([(1 - sel) * 4.0, sel * 4.0], -1)
        probs = jax.nn.softmax(logits, -1)
        return float(losses.latency_aware_moe_loss(logits, probs, lat))

    matched = loss_for(0.75)    # fast expert gets 3/4 — matches 1/Lat
    inverted = loss_for(0.25)
    assert matched < inverted


def test_moe_grads_reach_router_and_both_experts():
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (40, 16))

    def loss(p):
        y, aux = moe(p, x, train=False)
        return jnp.sum(y ** 2) * 1e-3 + aux["balance_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]["kernel"]))) > 0
    for ge in g["experts"]:
        total = sum(float(jnp.sum(jnp.abs(l))) for l in
                    jax.tree_util.tree_leaves(ge))
        assert total > 0


def test_capacities_cover_tokens_whenever_cf_ge_1():
    """Structural guarantee: capacity_factor >= 1.0 ⇒ sum(caps) >= n_tokens,
    for any latency skew, expert count and (small) group size — the rounding
    + clamp regression surface."""
    latency_sets = [
        [1.0, 1.0], [3.0, 1.0], [1e-3, 1e-9], [1.0, 2.0, 40.0],
        [5.0, 1.0, 0.1, 0.1],
    ]
    for lats in latency_sets:
        kinds = tuple(["mult"] + ["shift"] * (len(lats) - 1))
        for cf in (1.0, 1.25, 2.0):
            moe = MoEPrimitives(8, 16, expert_kinds=kinds, latencies=lats,
                                capacity_factor=cf)
            for n in list(range(1, 65)) + [197, 1024]:
                caps = moe.capacities(n)
                assert sum(caps) >= n, (lats, cf, n, caps)
                assert all(0 <= c <= n for c in caps), (lats, cf, n, caps)


def test_no_drop_regression_at_capacity_factor_125():
    """drop_fraction == 0 at capacity_factor 1.25 when the routed load fits
    the capacity split — pins that small-group rounding never shrinks a cap
    below its share."""
    moe = MoEPrimitives(16, 32, capacity_factor=1.25, latency_aware=False)
    params = moe.init(jax.random.PRNGKey(0))
    # Steer routing deterministically: logits = x @ W with W sending tokens
    # with x[:,0] > 0 to expert 0 and the rest to expert 1 — an exact 4/4
    # split of 8 tokens against per-expert caps of ceil(1.25*8/2) = 5.
    w = jnp.zeros((16, 2)).at[0, 0].set(4.0).at[0, 1].set(-4.0)
    params = dict(params, router={"kernel": w})
    sign = jnp.repeat(jnp.asarray([1.0, -1.0]), 4)[:, None]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 0.1
    x = x.at[:, 0].set(sign[:, 0])
    caps = moe.capacities(8)
    assert sum(caps) >= 8 and min(caps) >= 4
    y, aux = moe(params, x, train=False)
    assert float(aux["drop_fraction"]) == 0.0


def test_per_image_capacity_plan_covers_tokens():
    """The serving capacity domain is one image row (ISSUE 5): for every
    plausible tokens-per-image count, sum(caps) >= tokens-per-image at
    capacity_factor >= 1.0, and the memoized plan's offsets are the running
    prefix sums the segment views slice by."""
    for cf in (1.0, 1.25, 2.0):
        moe = MoEPrimitives(16, 32, capacity_factor=cf)
        for n in (16, 49, 64, 196, 197):
            caps, offsets = moe.capacity_plan(n)
            assert sum(caps) >= n, (cf, n, caps)
            assert all(0 <= c <= n for c in caps), (cf, n, caps)
            assert offsets[0] == 0
            assert all(offsets[i + 1] - offsets[i] == caps[i]
                       for i in range(len(caps) - 1))
            # The memo returns the identical object on the hot path.
            assert moe.capacity_plan(n) is moe._capacity_plans[n]


def _steered(capacity_factor=1.25):
    """MoE whose router deterministically sends tokens with x[...,0] > 0 to
    expert 0 and the rest to expert 1 — the steering rig of the global
    regression above, reused for its per-image twin."""
    moe = MoEPrimitives(16, 32, capacity_factor=capacity_factor,
                        latency_aware=False)
    params = moe.init(jax.random.PRNGKey(0))
    w = jnp.zeros((16, 2)).at[0, 0].set(4.0).at[0, 1].set(-4.0)
    return moe, dict(params, router={"kernel": w})


def _routed(moe, params, signs):
    """signs: (B, S) ±1 routing steer → per-image keep mask of the serving
    dispatch (info["keep"]; 1 − mean(keep) is the drop fraction)."""
    b, s = signs.shape
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 16)) * 0.1
    x = x.at[:, :, 0].set(jnp.asarray(signs, jnp.float32))
    _, info, _, _ = moe._dispatch_tokens(params, x)
    return np.asarray(info["keep"])


def test_no_drop_regression_per_image_capacities_at_cf_125():
    """drop_fraction == 0 at capacity_factor 1.25 under PER-IMAGE
    capacities: every image routes an exact 4/4 split of its 8 tokens
    against per-image caps of ceil(1.25·8/2) = 5 — small per-image groups
    must never round a cap below an image's share."""
    moe, params = _steered(1.25)
    caps, _ = moe.capacity_plan(8)
    assert sum(caps) >= 8 and min(caps) >= 4
    signs = np.tile(np.repeat([1.0, -1.0], 4), (4, 1))       # 4 images, 4/4
    keep = _routed(moe, params, signs)
    assert keep.all(), "per-image dispatch dropped tokens at cf 1.25"


def test_per_image_drops_are_row_local():
    """Drops are accounted per image: an image overflowing its own expert
    capacity loses exactly its overflow, and a neighbor's overflow can
    never steal another image's slots (the capacity-competition confound
    the per-image refactor removes)."""
    moe, params = _steered(1.25)
    caps, _ = moe.capacity_plan(8)                 # uniform → (5, 5)
    hog = np.ones((1, 8))                          # all 8 → expert 0: keeps 5
    fair = np.tile(np.repeat([1.0, -1.0], 4), (1, 1))        # 4/4: keeps all
    alone_hog = _routed(moe, params, hog)
    alone_fair = _routed(moe, params, fair)
    together = _routed(moe, params, np.concatenate([hog, fair]))
    assert alone_hog.sum() == caps[0] == 5
    assert alone_fair.all()
    np.testing.assert_array_equal(together[0], alone_hog[0])
    np.testing.assert_array_equal(together[1], alone_fair[0])


def test_infer_matches_call_and_is_deterministic():
    """The inference dispatch path must equal the train=False forward and be
    bit-stable across calls (no rng consumed anywhere)."""
    moe = _moe()
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (30, 16))
    y_call, _aux = moe(params, x, train=False)
    y_inf = moe.infer(params, x)
    np.testing.assert_allclose(np.asarray(y_inf), np.asarray(y_call),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(moe.infer(params, x)),
                                  np.asarray(y_inf))


def test_custom_experts_and_latencies():
    from repro.nn.layers import MLP

    experts = [MLP(16, 32, "swiglu", "dense"), MLP(16, 32, "swiglu", "shift")]
    moe = MoEPrimitives(16, 32, experts=experts, latencies=[2e-5, 1e-5],
                        capacity_factor=4.0)
    params = moe.init(jax.random.PRNGKey(0))
    y, aux = moe(params, jax.random.normal(jax.random.PRNGKey(1), (12, 16)))
    assert y.shape == (12, 16)
    np.testing.assert_allclose(np.asarray(aux["alpha"]),
                               [2 / 3, 1 / 3], rtol=1e-5)
