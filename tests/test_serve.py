"""Serving: batched generation, greedy determinism, long-context linear state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import SHIFTADD, STAGE1
from repro.nn.model import LanguageModel
from repro.serve.decode import generate, make_prefill_step


def _model(policy=None, **kw):
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab_size=64, dtype="float32", scan_layers=True, remat="none")
    base.update(kw)
    cfg = ModelConfig(name="t", family="dense",
                      policy=policy or ModelConfig.__dataclass_fields__["policy"].default,
                      **base)
    model = LanguageModel(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def test_generate_greedy_deterministic():
    model, params, cfg = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 64)
    out1 = generate(model, params, prompts, max_new_tokens=6)
    out2 = generate(model, params, prompts, max_new_tokens=6)
    assert out1.shape == (3, 11)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert np.all(np.asarray(out1) < 64)


def test_generate_with_sampling():
    model, params, cfg = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, 64)
    out = generate(model, params, prompts, max_new_tokens=5, temperature=1.0,
                   rng=jax.random.PRNGKey(7))
    assert out.shape == (2, 9)


def test_linear_state_decode_is_constant_memory():
    """ShiftAdd policy decode state size must be independent of context
    length — the property that makes long_500k feasible."""
    model, params, cfg = _model(policy=STAGE1)
    c1 = model.init_cache(2, max_len=128)
    c2 = model.init_cache(2, max_len=1 << 19)
    s1 = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(c1))
    s2 = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(c2))
    assert s1 == s2


def test_dense_cache_grows_with_context():
    model, params, cfg = _model()
    c1 = model.init_cache(2, max_len=64)
    c2 = model.init_cache(2, max_len=128)
    s1 = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(c1))
    s2 = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(c2))
    assert s2 > s1


def test_int8_kv_cache_decode():
    """Quantized KV cache (per-token scales, factor-out dequant) must match
    the fp prefill within quantization tolerance and shrink the cache >2x."""
    import jax.tree_util as tu

    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=97, dtype="float32", scan_layers=True, remat="none")
    from repro.configs.base import ModelConfig

    m_fp = LanguageModel(ModelConfig(name="t", family="dense", **base))
    m_q8 = LanguageModel(ModelConfig(name="t", family="dense",
                                     kv_cache_dtype="int8", **base))
    params = m_fp.init(jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    ref, _ = m_fp(params, x, train=False)
    cache = m_q8.init_cache(2, max_len=24)
    outs = []
    for t in range(24):
        lg, cache = m_q8.decode_step(params, x[:, t], cache)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - ref)))
    assert err < 0.05 * max(float(jnp.std(ref)), 1.0) + 0.03, err
    b_fp = sum(np.asarray(l).nbytes for l in
               tu.tree_leaves(m_fp.init_cache(2, 1024)))
    b_q8 = sum(np.asarray(l).nbytes for l in
               tu.tree_leaves(m_q8.init_cache(2, 1024)))
    assert b_q8 < 0.45 * b_fp


def test_prefill_step_matches_model_forward():
    model, params, cfg = _model()
    x = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 64)
    logits = make_prefill_step(model)(params, {"inputs": x})
    direct, _ = model(params, x, train=False)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(direct))
