"""Quickstart: the paper's technique in 60 seconds on CPU.

Builds a tiny ViT, trains it dense, reparameterizes it into ShiftAddViT
(stage 1: binary-linear attention; stage 2: MoE of {Mult, Shift} experts),
finetunes, and prints the accuracy ladder.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DENSE, SHIFTADD, STAGE1
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw


def train(model, params, data, steps, lr, offset=0):
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (_, m), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, m

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(offset + i).items()
                 if k != "object_yx"}
        params, state, m = step(params, state, batch)
    return params


def accuracy(model, params, data, n=6):
    accs = []
    for i in range(n):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(9000 + i).items()
                 if k != "object_yx"}
        _, m = model.loss(params, batch, train=False)
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


def main():
    kw = dict(image_size=16, patch_size=4, n_classes=4, n_layers=2,
              d_model=48, n_heads=2, d_ff=96)
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32, seed=7)

    dense = ShiftAddViT(ViTConfig(**kw, policy=DENSE))
    params = dense.init(jax.random.PRNGKey(0))
    print("pretraining dense ViT ...")
    params = train(dense, params, data, 150, 3e-3)
    print(f"  dense acc            = {accuracy(dense, params, data):.3f}")

    stage1 = ShiftAddViT(ViTConfig(**kw, policy=STAGE1))
    p1 = stage1.convert_from(dense, params, stage=1)
    p1 = train(stage1, p1, data, 60, 3e-4, offset=300)
    print(f"  stage1 (LA+Add) acc  = {accuracy(stage1, p1, data):.3f}")

    full = ShiftAddViT(ViTConfig(**kw, policy=SHIFTADD))
    p2 = full.convert_from(dense, params, stage=2)
    p2 = train(full, p2, data, 60, 3e-4, offset=600)
    print(f"  stage2 (full ShiftAdd+MoE) acc = {accuracy(full, p2, data):.3f}")
    from repro.core.reparam import count_reparameterized
    print("  reparameterized leaves:", count_reparameterized(p2))


if __name__ == "__main__":
    main()
