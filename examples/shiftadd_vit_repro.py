"""Faithful-reproduction driver: the paper's two-stage pipeline end to end.

Mirrors App. E: (0) pretrain a dense "MSA" ViT; (1) stage 1 — convert
attention to binary-linear (Add) form and finetune; (2) stage 2 — convert
MLPs to Shift / MoE-of-primitives and finetune; report the sensitivity table
(paper Tab. 2 structure) + energy estimate per variant.

Run:  PYTHONPATH=src python examples/shiftadd_vit_repro.py [--steps 150]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ShiftAddPolicy, DENSE
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw

STAGES = [
    ("0_dense_msa", DENSE, 0),
    ("1_la_add", ShiftAddPolicy(attention="binary_linear"), 1),
    ("2a_mlp_shift", ShiftAddPolicy(attention="binary_linear", mlp="shift"), 2),
    ("2b_mlp_moe", ShiftAddPolicy(attention="binary_linear",
                                  mlp="moe_primitives"), 2),
    ("2c_full_shiftadd", ShiftAddPolicy(attention="binary_linear",
                                        projections="shift",
                                        mlp="moe_primitives"), 2),
]


def train(model, params, data, steps, lr, offset=0):
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (_, m), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, m

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(offset + i).items()
                 if k != "object_yx"}
        params, state, _ = step(params, state, batch)
    return params


def acc_of(model, params, data, n=8):
    accs = []
    for i in range(n):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(9000 + i).items()
                 if k != "object_yx"}
        _, m = model.loss(params, batch, train=False)
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--finetune", type=int, default=60)
    args = ap.parse_args()

    kw = dict(image_size=16, patch_size=4, n_classes=4, n_layers=2,
              d_model=48, n_heads=2, d_ff=96)
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32,
                              seed=7)
    dense = ShiftAddViT(ViTConfig(**kw, policy=DENSE))
    params = dense.init(jax.random.PRNGKey(0))
    print(f"[stage 0] pretraining dense ViT for {args.steps} steps ...")
    params = train(dense, params, data, args.steps, 3e-3)

    print(f"{'variant':22s} {'acc':>6s}  {'Δ vs dense':>10s}")
    base = None
    for name, policy, stage in STAGES:
        model = ShiftAddViT(ViTConfig(**kw, policy=policy))
        if stage == 0:
            p = params
        else:
            p = model.convert_from(dense, params, stage=stage)
            p = train(model, p, data, args.finetune, 3e-4, offset=500 * stage)
        a = acc_of(model, p, data)
        if base is None:
            base = a
        print(f"{name:22s} {a:6.3f}  {a - base:+10.3f}")


if __name__ == "__main__":
    main()
