"""Paper Fig. 6: visualize the MoE router's token dispatch.

Trains a ShiftAdd ViT (MoE-of-primitives MLP) on the synthetic
object-classification task, then prints an ASCII map per image: `M` = token
routed to the Mult expert, `.` = Shift expert, with the planted object's
bounding box. The paper's hypothesis: object tokens → powerful Mult expert,
background → cheap Shift expert.

Run:  PYTHONPATH=src python examples/moe_routing_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ShiftAddPolicy
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw


def main():
    policy = ShiftAddPolicy(mlp="moe_primitives", latency_aware=True)
    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=2,
                    d_model=48, n_heads=2, d_ff=96, policy=policy)
    model = ShiftAddViT(cfg)
    # Deployment-scale expert latency ratio (Mult ≈ 2× Shift, weight-bound
    # regime) so α_i gives the router a real cost signal; at demo dims the
    # analytic estimate degenerates to ~1:1.
    for blk in model.blocks:
        blk.feed.latencies = [2.0e-5, 1.0e-5]
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32,
                              seed=3)
    opt = adamw(3e-3, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (_, m), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, m

    print("training ViT-MoE on the object task ...")
    for i in range(400):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()
                 if k != "object_yx"}
        params, state, m = step(params, state, batch)
    print(f"  final acc {float(m['acc']):.3f}")

    # Dispatch map of the first block's MoE for a few validation images.
    raw = data.batch_at(7777)
    imgs = jnp.asarray(raw["images"][:4])
    x = model.patch_embed(params["patch_embed"], model.patchify(imgs))
    _, aux = model.blocks[0].feed(params["blocks"][0]["feed"], x, train=False)
    grid = cfg.image_size // cfg.patch_size
    top1 = np.asarray(aux["top1"]).reshape(4, grid, grid)
    obj_hits, bg_hits, obj_n, bg_n = 0, 0, 0, 0
    for i in range(4):
        y0, x0 = raw["object_yx"][i] // cfg.patch_size
        print(f"image {i} (object at patch ({y0},{x0})):")
        for r in range(grid):
            line = "  "
            for c in range(grid):
                mult = top1[i, r, c] == 0
                on_obj = (y0 <= r <= y0 + 1) and (x0 <= c <= x0 + 1)
                line += "M" if mult else "."
                if on_obj:
                    obj_hits += int(mult)
                    obj_n += 1
                else:
                    bg_hits += int(mult)
                    bg_n += 1
            print(line)
    print(f"Mult-expert rate: object tokens {obj_hits / max(obj_n,1):.2f} "
          f"vs background {bg_hits / max(bg_n,1):.2f}")
    print(f"tokens/expert: {np.asarray(aux['tokens_per_expert'])}, "
          f"alpha: {np.asarray(aux['alpha']).round(3)}")


if __name__ == "__main__":
    main()
