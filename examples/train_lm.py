"""End-to-end training driver: train an LM with the ShiftAdd policy, full
production loop (checkpointing, fault tolerance, microbatching, LL-loss).

Default size is CPU-friendly (~3M params, 200 steps, a couple of minutes);
pass --preset 100m for the ~100M-parameter configuration (same code path —
on real accelerators that's the few-hundred-step deliverable run).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--preset small]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.policy import SHIFTADD
from repro.data.pipeline import SyntheticLMData
from repro.nn.model import LanguageModel
from repro.train import train_loop

PRESETS = {
    # ~3M params — CPU demo
    "small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                  vocab_size=2048),
    # ~100M params — the deliverable-scale run (accelerator recommended)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", choices=["dense", "shiftadd"], default="shiftadd")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      mlp_kind="swiglu", dtype="float32", scan_layers=True,
                      remat="none", moe_primitives_capacity=2.0,
                      **PRESETS[args.preset])
    if args.policy == "shiftadd":
        cfg = cfg.with_policy(SHIFTADD)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, microbatch=2,
                       checkpoint_every=50, grad_compression="int8_ef")
    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"training {cfg.name} ({n_params / 1e6:.1f}M params, "
          f"policy={args.policy}) for {args.steps} steps")

    def hook(m):
        if m["step"] % 20 == 0:
            print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"ce {m['ce']:.4f}  balance {m['balance_loss']:.4f}  "
                  f"{m['seconds']:.2f}s")

    state, hist = train_loop(model, tcfg, data, checkpointer=ckpt,
                             metrics_hook=hook)
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
