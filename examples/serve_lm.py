"""End-to-end serving driver (the paper's kind is inference): token-level
CONTINUOUS batching of a ShiftAdd LM with O(1) linear-attention state.

A thin driver over the real serving stack — `serve.lm.BucketedLMEngine`
(packed slot array, jitted bucket-shaped prefill + admit/evict scatters +
one scan-fused decode-chunk program) fed by `serve.frontend.serve_lm_trace`
(seeded trace, SlotScheduler, virtual-clock timing) — so the example
exercises exactly what benchmarks/bench_lm_traffic.py gates: requests join
a RUNNING decode batch at chunk boundaries, nothing recompiles after
warmup, and per-request outputs are bit-identical to a batch=1 serial run.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch yi-9b] [--policy shiftadd]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import get_config
from repro.nn.model import LanguageModel
from repro.serve.frontend import calibrate_lm_service, serve_lm_trace
from repro.serve.replicas import make_lm_replicas
from repro.serve.scheduler import SlotScheduler
from repro.serve.traffic import default_budgets, make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--policy", default="shiftadd")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    # Generous MoE capacity = the no-drop regime decode's row-wise
    # batch-invariance contract requires (serve.decode's MoE note).
    cfg = get_config(args.arch, policy=args.policy, reduced=True).replace(
        moe_primitives_capacity=2.0)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    pool = make_lm_replicas(model, params, n_replicas=1, n_slots=args.slots,
                            prompt_buckets=(4, 8), chunk=8).warmup()
    svc = calibrate_lm_service(pool, iters=1)

    # A short poisson burst at roughly one request per decode chunk: slots
    # free at staggered times, so admissions land mid-decode — the
    # continuous-batching path, not the drain-and-refill one.
    budget = svc["prefill_s"][8] + 4 * svc["chunk_s"] * args.new_tokens
    trace = make_trace("poisson", args.requests, seed=0,
                       target_images_per_s=4.0 / max(svc["chunk_s"], 1e-6),
                       budgets_s=default_budgets(budget), max_size=8)
    res = serve_lm_trace(pool, SlotScheduler(), trace, svc,
                         mode="continuous",
                         new_token_range=(args.new_tokens, args.new_tokens))

    rep = res.report
    print(f"served {rep['served_requests']} requests, "
          f"{rep['generated_tokens']} tokens in "
          f"{rep['virtual_makespan_s']:.2f}s virtual  "
          f"({rep['tokens_per_s']:.1f} tok/s, occupancy "
          f"{rep['chunk_occupancy']:.2f}, slots={args.slots}, "
          f"arch={args.arch}, policy={args.policy}, "
          f"recompiles={rep['recompiles_after_warmup']})")
    for rid in sorted(res.tokens)[:4]:
        print(f"  req {rid}: {res.tokens[rid][:16].tolist()} ...")


if __name__ == "__main__":
    main()
