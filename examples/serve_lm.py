"""End-to-end serving driver (the paper's kind is inference): batched
autoregressive decode of a ShiftAdd LM with O(1) linear-attention state.

Serves a queue of requests in fixed-size batches (a minimal continuous-
batching scheduler: finished rows are refilled from the queue each slot),
reports tokens/s and per-request outputs.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch yi-9b] [--policy shiftadd]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.nn.model import LanguageModel
from repro.serve.decode import make_prefill, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--policy", default="shiftadd")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, policy=args.policy, reduced=True).replace(
        moe_primitives_capacity=2.0)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size, size=rng.integers(3, 8)).tolist()
             for _ in range(args.requests)]
    results = {}

    b = args.batch
    cache = model.init_cache(b, max_len=128)
    active = [None] * b          # request id per row
    buffers = [[] for _ in range(b)]
    remaining = [0] * b
    next_id = 0
    t0 = time.perf_counter()
    decoded = 0

    def refill(row, cache):
        nonlocal next_id
        if next_id >= len(queue):
            return cache, False
        # cold-start the row: feed the prompt through the decode path
        prompt = queue[next_id]
        active[row] = next_id
        buffers[row] = list(prompt)
        remaining[row] = args.new_tokens
        next_id += 1
        return cache, True

    for row in range(b):
        cache, _ = refill(row, cache)

    # consume prompts in ONE parallel chunked prefill pass (row-synchronous:
    # rows with shorter prompts re-feed their last token — fine for a demo
    # scheduler, and identical to what a per-token warmup loop would feed)
    max_prompt = max(len(q) for q in queue)
    prompt_mat = jnp.asarray(
        [[buffers[r][min(t, len(buffers[r]) - 1)] if buffers[r] else 0
          for t in range(max_prompt)]
         for r in range(b)], jnp.int32)
    prefill = jax.jit(make_prefill(model), donate_argnums=(2,))
    logits_all, cache = prefill(params, prompt_mat, cache)
    logits = logits_all[:, -1]

    while any(a is not None for a in active):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks = np.asarray(tok)
        for r in range(b):
            if active[r] is None:
                continue
            buffers[r].append(int(toks[r]))
            decoded += 1
            remaining[r] -= 1
            if remaining[r] <= 0:
                results[active[r]] = buffers[r]
                active[r] = None
                cache, ok = refill(r, cache)
        if all(a is None for a in active):
            break
        logits, cache = step(params, tok, cache)

    dt = time.perf_counter() - t0
    print(f"served {len(results)} requests, {decoded} tokens "
          f"in {dt:.2f}s  ({decoded / dt:.1f} tok/s, batch={b}, "
          f"arch={args.arch}, policy={args.policy})")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:16]} ...")


if __name__ == "__main__":
    main()
