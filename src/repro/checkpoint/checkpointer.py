"""Atomic step checkpoints with async save, keep-N GC and elastic restore.

Layout:  <dir>/step_00001234/shard_<process>.npz  + MANIFEST.json
Writes go to a `.tmp-` directory first and are renamed into place only after
every shard and the manifest are fsynced — a reader never sees a partial
checkpoint (the restart-side half of fault tolerance; the data side is the
deterministic pipeline). `restore_latest` walks backwards over steps until it
finds a complete checkpoint, so a crash mid-save degrades to the previous one.

Elastic restore: arrays are saved unsharded (gathered); on restore they are
device_put against whatever sharding the *new* mesh prescribes — a job that
comes back with fewer/more chips resumes from the same state (tested in
tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax

from repro.utils.logging import get_logger

log = get_logger("repro.checkpoint")


class Checkpointer:
    def __init__(self, directory, keep=3, async_save=True):
        self.dir = str(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread = None
        os.makedirs(self.dir, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state, blocking=False):
        """state: arbitrary pytree of arrays."""
        leaves = jax.tree_util.tree_leaves(state)
        host = [np.asarray(x) for x in leaves]
        if self.async_save and not blocking:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host_leaves):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp-{name}")
        final = os.path.join(self.dir, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        shard = os.path.join(tmp, f"shard_{jax.process_index():05d}.npz")
        np.savez(shard, *host_leaves)
        manifest = {"step": step, "n_leaves": len(host_leaves),
                    "processes": jax.process_count()}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        log.info("checkpoint saved: %s", final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, n, "MANIFEST.json")):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, template, shardings=None):
        """template: pytree with the target structure. shardings: optional
        matching tree of NamedShardings for elastic placement."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz"))
        leaves = [data[f"arr_{i}"] for i in range(manifest["n_leaves"])]
        treedef = jax.tree_util.tree_structure(template)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, template, shardings=None):
        """Returns (step, state) for the newest complete checkpoint, or None."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, template, shardings)
            except Exception as e:  # partial/corrupt → walk back
                log.warning("checkpoint step %d unreadable (%s); trying older",
                            step, e)
        return None
