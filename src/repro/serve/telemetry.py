"""Serving telemetry → router training: measured per-expert latencies.

The latency-aware load-balancing loss (core.losses, paper §4.2 Eq. 4) and
the static capacity split (core.moe_primitives) both consume per-expert
latencies α_i ∝ Lat_i. Until this module those came exclusively from the
analytic `core.energy` cost model; the serving stack, meanwhile, already
measures real per-component and per-bucket costs (`vision.component_breakdown`,
the BENCH_traffic service models). This closes the loop (ROADMAP item 3):

- `extract_expert_telemetry` probes each MoE expert STANDALONE on the exact
  per-expert dispatch segment shapes the frozen serving path feeds it
  (`MoEPrimitives._dispatch_tokens` static views), per bucket, interleaved
  round-robin with the warmup-discarding median every calibrator uses
  (`metrics.service_median_warm`) — `component_breakdown`'s discipline,
  one level deeper.
- The result persists as a schema-versioned TELEMETRY_experts.json
  (`ExpertTelemetry.save`/`load`, same frozen-tuple + fail-open pattern as
  `kernels.autotune.TuneTable`): per-expert per-bucket wall seconds, the
  derived per-expert α latencies, and optionally the engine-level service
  medians they rode alongside (provenance).
- `apply_expert_latencies` drops the α latencies into every MoE feed as a
  drop-in replacement for the analytic `energy.expert_latencies` constants —
  `MoEPrimitives.latencies` is a setter that invalidates the memoized
  capacity plans, so rebuilt engines serve the measured split and
  `train.router_tune` fine-tunes the router against it.

Mode discipline (the TuneTable precedent): wall-clock α only on a TPU
backend (`mode="measured"`). Elsewhere `mode="model"` derives α from the
analytic model AT SERVING GEOMETRY (the per-image token count — the same
regime fix `MoEPrimitives.latencies_at` applies), the wall probes are still
recorded for visibility, and the meta says why: CPU/interpret wall times do
not rank TPU experts, and a CI gate fed noisy measured α would flap.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.moe_primitives import MoEPrimitives
from repro.serve.metrics import service_median_warm

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ExpertTelemetry:
    """Immutable per-expert serving-latency table.

    entries:         ((kind, ((bucket, seconds), ...)), ...) — measured
                     wall seconds of one MoE layer's expert segment, per
                     serving bucket (batch size).
    alpha_latencies: ((kind, seconds), ...) — THE α source: per-expert
                     latency at the per-image serving token count, either
                     measured (TPU) or analytic-at-serving-geometry (model
                     mode). `MoEPrimitives` consumes these verbatim.
    service_s:       ((bucket, seconds), ...) — engine-level calibrated
                     service medians the probes rode alongside (provenance;
                     empty when extracted outside a traffic sweep).
    meta:            ((key, value), ...) — mode/backend/reason/geometry.
    """

    entries: tuple = ()
    alpha_latencies: tuple = ()
    service_s: tuple = ()
    meta: tuple = ()

    @property
    def meta_dict(self) -> dict:
        return dict(self.meta)

    @property
    def mode(self) -> str:
        return self.meta_dict.get("mode", "model")

    def expert_latencies(self, kinds) -> list:
        """α latencies ordered for a feed's `expert_kinds` — the drop-in
        replacement for `energy.expert_latencies(...)`."""
        table = dict(self.alpha_latencies)
        return [float(table[k]) for k in kinds]

    def bucket_seconds(self, kind: str) -> dict:
        """{bucket: measured seconds} for one expert kind."""
        return {b: s for b, s in dict(self.entries).get(kind, ())}

    @staticmethod
    def from_dicts(entries: dict = None, alpha: dict = None,
                   service: dict = None, meta: dict = None) -> "ExpertTelemetry":
        ent = tuple(sorted(
            (kind, tuple(sorted((int(b), float(s)) for b, s in per.items())))
            for kind, per in (entries or {}).items()))
        alp = tuple(sorted((k, float(v)) for k, v in (alpha or {}).items()))
        svc = tuple(sorted((int(b), float(s))
                           for b, s in (service or {}).items()))
        def _freeze(v):
            return tuple(v) if isinstance(v, list) else v
        mt = tuple(sorted((k, _freeze(v)) for k, v in (meta or {}).items()))
        return ExpertTelemetry(entries=ent, alpha_latencies=alp,
                               service_s=svc, meta=mt)

    def to_json_dict(self) -> dict:
        def _thaw(v):
            return list(v) if isinstance(v, tuple) else v
        return {"schema": SCHEMA_VERSION,
                "meta": {k: _thaw(v) for k, v in self.meta},
                "alpha_latencies": {k: v for k, v in self.alpha_latencies},
                "service_s": {str(b): s for b, s in self.service_s},
                "entries": {kind: {str(b): s for b, s in per}
                            for kind, per in self.entries}}

    def save(self, path: str, report=None):
        doc = self.to_json_dict()
        if report is not None:
            doc["report"] = report
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")

    @staticmethod
    def load(path: str) -> "ExpertTelemetry":
        with open(path) as fh:
            doc = json.load(fh)
        assert doc.get("schema") == SCHEMA_VERSION, doc.get("schema")
        return ExpertTelemetry.from_dicts(doc.get("entries", {}),
                                          doc.get("alpha_latencies", {}),
                                          doc.get("service_s", {}),
                                          doc.get("meta", {}))


def load_telemetry(path: str):
    """ExpertTelemetry from a TELEMETRY_experts.json path, or None if
    absent/invalid — callers fall back to the analytic latencies rather
    than failing to boot (the TuneTable fail-open contract)."""
    try:
        return ExpertTelemetry.load(path)
    except (OSError, ValueError, AssertionError):
        return None


def _moe_feeds(model):
    """[(layer_index, block, feed)] for every MoEPrimitives feed."""
    return [(i, blk, blk.feed) for i, blk in enumerate(model.blocks)
            if isinstance(blk.feed, MoEPrimitives)]


def _feed_inputs(model, run_params, images, impl=None, tune=None):
    """Yield (block, block_params, feed_input) at each block, running the
    serving forward eagerly up to every feed — the activation shapes the
    frozen engine really dispatches (component_breakdown's probe pattern)."""
    dt = model.mc.activation_dtype
    x = model.patch_embed(run_params["patch_embed"],
                          model.patchify(jnp.asarray(images)).astype(dt))
    for blk, p in zip(model.blocks, run_params["blocks"]):
        h = blk.norm1(p["norm1"], x)
        mix = blk._infer_mixer(p, h, None, impl=impl, tune=tune)
        if blk.parallel:
            feed_in = h
            x = x + mix + blk._infer_feed(p, h, impl=impl, tune=tune)
        else:
            x = x + mix
            feed_in = blk.norm2(p["norm2"], x)
            x = x + blk._infer_feed(p, feed_in, impl=impl, tune=tune)
        yield blk, p, feed_in


def measure_token_share(model, run_params, images, impl=None, tune=None):
    """Fraction of tokens each expert KIND wins under serving routing.

    Replays the deterministic serving route (`group_rows` + clean-logit
    argmax — exactly `MoEPrimitives._route_infer`) at every MoE layer and
    aggregates argmax counts per expert kind. This is the paper's headline
    router behavior made observable: a router trained on real latencies
    should shift share toward the cheap shift/add expert. Returns
    {kind: share} (empty for models without MoE feeds).
    """
    from repro.nn.dispatch import group_rows

    counts = {}
    total = 0
    for blk, p, feed_in in _feed_inputs(model, run_params, images,
                                        impl=impl, tune=tune):
        feed = blk.feed
        if not isinstance(feed, MoEPrimitives):
            continue
        xg, _ = group_rows(feed_in, feed.d_model)
        top1, _ = feed._route_infer(p["feed"], xg)
        won = np.asarray(jax.nn.one_hot(top1, feed.n_experts,
                                        dtype=jnp.float32).sum((0, 1)))
        for i, kind in enumerate(feed.expert_kinds):
            counts[kind] = counts.get(kind, 0.0) + float(won[i])
        total += int(top1.size)
    if total == 0:
        return {}
    return {kind: c / total for kind, c in sorted(counts.items())}


def _probe_expert_seconds(feed, feed_params, feed_in, iters, impl, tune):
    """Interleaved wall-clock of each expert on its static dispatch segment.

    Each expert is jitted standalone on the exact (G, cap_e, d) view the
    serving dispatch hands it; iters+1 rounds, round 0 discarded
    (`service_median_warm`). Returns [seconds] ordered like feed.experts.
    """
    _, _, segments, _ = feed._dispatch_tokens(feed_params, feed_in)
    probes = []
    for i, (expert, seg) in enumerate(zip(feed.experts, segments)):
        ep = feed_params["experts"][i]
        if getattr(expert, "accepts_impl", False):
            fn = jax.jit(lambda s, e=expert, pp=ep:
                         e(pp, s, impl=impl, tune=tune))
        else:
            fn = jax.jit(lambda s, e=expert, pp=ep: e(pp, s))
        probes.append((fn, seg))
    samples = [[] for _ in probes]
    for _ in range(max(int(iters), 1) + 1):
        for i, (fn, seg) in enumerate(probes):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(seg))
            samples[i].append(time.perf_counter() - t0)
    return [service_median_warm(xs, warmup=1) for xs in samples]


def extract_expert_telemetry(model, params, *, buckets=None, impl=None,
                             tune=None, iters=5, measure=None,
                             service_model_s=None):
    """Probe a model's MoE experts at serving geometry → ExpertTelemetry.

    Freezes a DeployPlan for the serving token count (the PR-3 deploy
    freeze, so probes run the exact frozen segment programs), then times
    each expert of the FIRST MoE layer per bucket (layers share geometry;
    meta records how many layers the number stands for).

    measure=None → auto: α from wall clock only on a TPU backend; elsewhere
    α comes from the analytic model at the per-image serving token count
    (`mode="model"`, reason recorded) while the wall probes are still
    persisted for visibility. service_model_s ({bucket: seconds}, e.g. the
    shiftadd arm's calibrated service model) rides along as provenance.
    """
    from repro.serve.vision import DEFAULT_BUCKETS

    buckets = tuple(sorted(buckets or DEFAULT_BUCKETS))
    backend = jax.default_backend()
    if measure is None:
        measure = backend == "tpu"
    n_patches = model.cfg.n_patches
    plan = model.prepare_inference(params, impl=impl,
                                   token_counts=(n_patches,), tune=tune)
    run_params = plan.params
    feeds = _moe_feeds(model)
    if not feeds:
        raise ValueError("model has no MoEPrimitives feeds to probe")
    _, probe_blk, probe_feed = feeds[0]
    kinds = probe_feed.expert_kinds
    caps, _ = probe_feed.capacity_plan(n_patches)

    entries = {k: {} for k in kinds}
    shape = (model.cfg.image_size, model.cfg.image_size,
             model.cfg.in_channels)
    for b in buckets:
        imgs = jax.random.normal(jax.random.PRNGKey(17 + b), (b,) + shape)
        for blk, p, feed_in in _feed_inputs(model, run_params, imgs,
                                            impl=impl, tune=tune):
            if blk is probe_blk:
                secs = _probe_expert_seconds(probe_feed, p["feed"], feed_in,
                                             iters, impl, tune)
                for kind, s in zip(kinds, secs):
                    entries[kind][b] = s
                break

    if measure:
        # Per-token normalize at the largest bucket (most signal), then
        # express at the per-image token count — the α regime every consumer
        # (loss, capacity split) evaluates in. cap_e tokens per group row,
        # G = batch rows per probe.
        bmax = buckets[-1]
        alpha = {kind: (entries[kind][bmax] / (bmax * caps[i])) * n_patches
                 for i, kind in enumerate(kinds)}
        mode, reason = "measured", ("wall-clock expert segments on TPU, "
                                    "per-token normalized")
    else:
        analytic = energy.expert_latencies(n_patches, probe_feed.d_model,
                                           probe_feed.d_hidden, kinds)
        alpha = dict(zip(kinds, analytic))
        mode = "model"
        reason = (f"analytic cost model at serving geometry (backend="
                  f"{backend}; CPU/interpret wall times do not rank TPU "
                  "experts — probes recorded for visibility only)")
    meta = {"mode": mode, "backend": backend, "measured": bool(measure),
            "reason": reason, "buckets": list(buckets),
            "n_patches": n_patches, "d_model": probe_feed.d_model,
            "d_hidden": probe_feed.d_hidden, "expert_kinds": list(kinds),
            "capacities_per_image": list(caps),
            "capacity_factor": probe_feed.capacity_factor,
            "iters": int(iters), "n_moe_layers": len(feeds),
            "layers_measured": 1}
    return ExpertTelemetry.from_dicts(entries, alpha, service_model_s, meta)


def apply_expert_latencies(model, telemetry: ExpertTelemetry) -> int:
    """Drop the telemetry α latencies into every MoE feed of `model` — the
    drop-in replacement for the analytic `energy.expert_latencies` defaults.

    Returns the number of feeds updated. The `MoEPrimitives.latencies`
    setter invalidates each feed's memoized capacity plans, so engines and
    DeployPlans built BEFORE this call keep serving their old split:
    (re)build them afterwards.
    """
    feeds = _moe_feeds(model)
    if not feeds:
        raise ValueError("model has no MoEPrimitives feeds to update")
    for _, _, feed in feeds:
        feed.latencies = telemetry.expert_latencies(feed.expert_kinds)
    return len(feeds)
