"""SLO-aware micro-batch scheduler for the bucketed ShiftAddViT engine.

Pure decision logic, deterministic by construction: no wall clock, no
randomness — every method takes the current (virtual) time as an argument,
so the same trace always produces the same dispatch sequence. The frontend
(`serve.frontend`) owns the clock and the engines; this module only decides
*what* to batch and *when*.

**Fill-or-deadline policy.** A batch is dispatched when

- the queue can fill the largest engine bucket (amortization is maximal —
  waiting longer cannot improve the images-per-program ratio), OR
- the oldest queued request's *slack* (time to deadline minus the max-bucket
  service estimate) hits the safety threshold `slack_s` — dispatch now,
  padded to the smallest covering bucket, or the deadline is lost, OR
- the oldest queued request has lingered `linger_s` — the padding-tradeoff
  threshold: once the wait exceeds the marginal service cost of a bigger
  bucket, waiting for more fill costs more latency than padding wastes
  compute. `linger_s` defaults to the measured max-bucket service time, so
  a faster policy (shiftadd vs dense) lingers proportionally less and its
  per-request latency scales with its service speed.

**Ordering.** One FIFO queue per deadline class; batch slots are filled by
earliest-absolute-deadline among the class *heads* (ties: class declaration
order). Within a class, requests therefore dispatch strictly in arrival
order — the FIFO-within-deadline-class invariant the tests pin.

**Admission control.** `offer` sheds an entire request (never a partial)
when accepting it would push the queue past `max_queue_images` — bounded
queues under overload instead of unbounded latency collapse.

**Oversize requests.** Requests larger than the biggest bucket are split at
admission into max-bucket parts that dispatch independently (the frontend
reassembles logits in part order), mirroring `BucketedViTEngine.infer`'s own
chunking, so a lone oversize request produces bit-identical logits through
the scheduler and through a direct engine call.

**Logit freedom.** None of these decisions can move a logit: the engine
forward is batch-invariant per image (per-image MoE capacity dispatch —
serve/vision.py's contract), so the scheduler may co-batch, split, reorder
across classes, pad and shed freely, for every policy arm including the
shiftadd MoE, with zero logit consequences. Scheduling chooses WHEN work
runs and WHAT shares a program launch — never what a request's answer is.
"""
from __future__ import annotations

import collections
import dataclasses
import math

from repro.serve.traffic import DEADLINE_CLASSES, Request


@dataclasses.dataclass(frozen=True)
class Part:
    """One schedulable unit: a request, or a max-bucket slice of one."""
    req: Request
    part_idx: int
    n_parts: int
    offset: int          # first image of this part within the request
    size: int            # images in this part
    enqueued_s: float

    @property
    def rid(self):
        return self.req.rid


@dataclasses.dataclass(frozen=True)
class Batch:
    parts: tuple            # Parts in dispatch order
    n_images: int
    bucket: int
    formed_s: float
    reason: str             # "fill" | "deadline" | "linger" | "drain"

    @property
    def padding(self) -> int:
        return self.bucket - self.n_images


class MicroBatchScheduler:
    """Queue + fill-or-deadline batch former over a fixed bucket set.

    buckets: ascending engine bucket sizes (read them off the engine —
    `BucketedViTEngine.buckets` is the effective, normalized set).
    service_model_s: bucket → calibrated service seconds (used only for
    slack estimates; the frontend uses it to advance the virtual clock).
    slack_s: deadline safety threshold. linger_s: padding-tradeoff wait cap.
    max_queue_images: admission bound (None = unbounded).
    """

    def __init__(self, buckets, service_model_s, *, slack_s=None,
                 linger_s=None, max_queue_images=None):
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        assert self.buckets and self.buckets[0] >= 1
        self.service_model_s = dict(service_model_s)
        svc_max = self.service_model_s[self.buckets[-1]]
        # Defaults: linger one max-bucket service time; keep half of one as
        # deadline safety margin (partial batch must still be served).
        self.linger_s = svc_max if linger_s is None else float(linger_s)
        self.slack_s = 0.5 * svc_max if slack_s is None else float(slack_s)
        self.max_queue_images = max_queue_images
        self._queues = {k: collections.deque() for k in DEADLINE_CLASSES}
        self.queued_images = 0
        self.shed_requests = 0
        self.shed_images = 0
        self.admitted_requests = 0

    # -- admission ----------------------------------------------------------

    def offer(self, req: Request, now: float) -> bool:
        """Admit (splitting oversize requests) or shed. Returns admitted."""
        if (self.max_queue_images is not None
                and self.queued_images + req.size > self.max_queue_images):
            self.shed_requests += 1
            self.shed_images += req.size
            return False
        bmax = self.buckets[-1]
        n_parts = max(1, math.ceil(req.size / bmax))
        off = 0
        for i in range(n_parts):
            size = min(bmax, req.size - off)
            self._queues[req.klass].append(Part(
                req=req, part_idx=i, n_parts=n_parts, offset=off, size=size,
                enqueued_s=now))
            off += size
        self.queued_images += req.size
        self.admitted_requests += 1
        return True

    def requeue(self, parts):
        """Return in-flight Parts (a killed replica's micro-batch) to the
        FRONT of their class queues — failure recovery, not admission.

        The parts were at their class heads when the batch was formed
        (dispatch pops heads only), so pushing them back in reverse order
        restores the exact pre-dispatch queue state: FIFO-within-class and
        the original `enqueued_s` stamps survive, and the retry dispatch is
        a pure function of virtual state like every other decision. No
        admission counters move — these requests were already admitted.
        """
        for p in reversed(tuple(parts)):
            self._queues[p.req.klass].appendleft(p)
            self.queued_images += p.size

    def has_queued(self) -> bool:
        return self.queued_images > 0

    # -- dispatch decision --------------------------------------------------

    def _forced_at(self, part: Part) -> float:
        """Earliest virtual time at which this part forces a dispatch."""
        svc_max = self.service_model_s[self.buckets[-1]]
        by_deadline = part.req.deadline_s - svc_max - self.slack_s
        by_linger = part.enqueued_s + self.linger_s
        return min(by_deadline, by_linger)

    def _forced_reason(self, part: Part, now: float) -> str:
        svc_max = self.service_model_s[self.buckets[-1]]
        if part.req.deadline_s - svc_max - self.slack_s <= now:
            return "deadline"
        return "linger"

    def next_forced_dispatch_s(self):
        """min forced-dispatch time over the queue (None if empty or if the
        thresholds are infinite — then only fill/drain dispatches)."""
        times = [self._forced_at(q[0]) for q in self._queues.values() if q]
        t = min(times) if times else None
        return t if t is not None and math.isfinite(t) else None

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _head_order(self):
        """Class heads by (deadline, class order) — the fill order."""
        heads = [(q[0].req.deadline_s, i, k)
                 for i, k in enumerate(DEADLINE_CLASSES)
                 if (q := self._queues[k])]
        return [k for _, _, k in sorted(heads)]

    def form_batch(self, now: float, drain: bool = False):
        """Return the next Batch to dispatch at `now`, or None to wait.

        drain=True (frontend end-of-trace) dispatches whatever is queued
        without waiting for fill/linger/deadline triggers.
        """
        if self.queued_images == 0:
            return None
        bmax = self.buckets[-1]
        forced = self.next_forced_dispatch_s()
        if not drain and self.queued_images < bmax and (
                forced is None or forced > now):
            return None
        # Reason: full bucket beats forced triggers in the log (it would
        # have dispatched regardless of deadlines).
        if self.queued_images >= bmax:
            reason = "fill"
        elif forced is not None and forced <= now:
            heads = [q[0] for q in self._queues.values() if q]
            part = min(heads, key=self._forced_at)
            reason = self._forced_reason(part, now)
        else:
            reason = "drain"
        parts, total = [], 0
        while total < bmax:
            order = self._head_order()
            took = False
            for k in order:
                head = self._queues[k][0]
                if total + head.size <= bmax:
                    parts.append(self._queues[k].popleft())
                    total += head.size
                    took = True
                    break
            # No class head fits the remaining space: ship what we have
            # (head-of-line order is preserved; we never reorder past a
            # head to backfill padding).
            if not took:
                break
        self.queued_images -= total
        return Batch(parts=tuple(parts), n_images=total,
                     bucket=self.bucket_for(total), formed_s=now,
                     reason=reason)


class SlotScheduler:
    """Token-level sibling of MicroBatchScheduler for continuous LM decode.

    Decode classes schedule *slots, not parts*: the unit of dispatch is one
    request claiming one engine slot for its whole lifetime (prefill + all
    its decode chunks), and the decision point is every chunk boundary, when
    the frontend asks which queued request a freed slot should get next.

    Same deterministic contracts as the batch former: one FIFO queue per
    deadline class, earliest-absolute-deadline among the class *heads*
    (ties: class declaration order) picks the next request — so dispatch is
    FIFO within a class and EDF across classes — and `offer` sheds whole
    requests past `max_queue_requests` (bounded queues under overload).

    Logit freedom holds at token level too: decode is row-wise per slot
    (serve.lm.BucketedLMEngine's contract), so co-residency and admission
    timing can never move a request's logits — only its latency. The
    property tier in tests/test_lm_continuous.py pins this (slot placement
    is deterministic and replay-gated; see lm_serial_oracle on why the
    oracle additionally pins the slot index).
    """

    def __init__(self, *, max_queue_requests=None):
        self.max_queue_requests = max_queue_requests
        self._queues = {k: collections.deque() for k in DEADLINE_CLASSES}
        self.queued_requests = 0
        self.shed_requests = 0
        self.admitted_requests = 0

    def offer(self, req: Request, now: float) -> bool:
        """Admit into the class queue or shed (whole requests only)."""
        if (self.max_queue_requests is not None
                and self.queued_requests + 1 > self.max_queue_requests):
            self.shed_requests += 1
            return False
        self._queues[req.klass].append((req, now))
        self.queued_requests += 1
        self.admitted_requests += 1
        return True

    def requeue(self, reqs_with_enq):
        """Failure recovery: push (Request, enqueued_s) pairs back to the
        FRONT of their class queues (reverse order restores the exact
        pre-dispatch state, as in MicroBatchScheduler.requeue). A killed
        engine's in-progress requests restart from prefill on another
        engine — greedy decode is deterministic, so the retry regenerates
        bit-identical tokens. Admission counters don't move."""
        for req, enq in reversed(tuple(reqs_with_enq)):
            self._queues[req.klass].appendleft((req, enq))
            self.queued_requests += 1

    def has_queued(self) -> bool:
        return self.queued_requests > 0

    def _head_order(self):
        heads = [(q[0][0].deadline_s, i, k)
                 for i, k in enumerate(DEADLINE_CLASSES)
                 if (q := self._queues[k])]
        return [k for _, _, k in sorted(heads)]

    def next_request(self, now: float):
        """Pop the request the next free slot should serve: earliest
        deadline among class heads (ties by class order), FIFO within a
        class. Returns (Request, enqueued_s) or None."""
        order = self._head_order()
        if not order:
            return None
        req, enq = self._queues[order[0]].popleft()
        self.queued_requests -= 1
        return req, enq
