"""Traffic frontend: virtual-clock event loop over queue → scheduler →
replicas, with per-request SLO metrics.

**Determinism model.** The subsystem separates *what runs* from *when it
ran*:

- Engine execution is REAL: every dispatched batch runs through a frozen
  `BucketedViTEngine` (thread-pool or data-parallel arm) and the logits are
  reassembled per request. Measured wall times are reported alongside.
- Scheduling TIME is VIRTUAL: queue waits, replica busy-until times,
  deadline checks and completion times advance a simulated clock whose
  service times come from a calibration pass (`calibrate_service_model`:
  median measured latency per bucket, frozen before the trace starts).

Every scheduling decision is therefore a pure function of (trace,
calibration, knobs): replaying the same seeded trace reproduces the exact
same per-request routing — same batches, same buckets, same replica slots —
and, because the engine itself is deterministic, the same logits. Logits
are moreover BATCH-INVARIANT per image for every policy arm, shiftadd
included (MoE capacity is planned per image row — serve/vision.py's
batch-invariance contract): a request's logits are bit-identical across
1 vs N replicas, oversize splits, co-batching and direct engine calls,
even when a different replica count or knob changes which requests share a
batch. `traffic_sweep(verify_one_vs_n=True)` re-serves each arm's trace on
a single replica and records that the per-request logits survived the
(generally different) batch compositions bit-for-bit — a gate that had to
exclude MoE policies before the per-image dispatch refactor.

The virtual clock also makes the CI gates noise-immune: deadline-miss rate
and goodput depend on machine speed only through the calibration, and since
arrival rates and deadline budgets are themselves derived from the
calibration, the whole timeline is scale-invariant across hosts.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.metrics import (latency_summary, padding_waste, rate_per_s,
                                 service_median, service_median_warm)
from repro.serve.scheduler import MicroBatchScheduler, SlotScheduler
from repro.serve.traffic import Trace, lm_new_tokens, lm_prompt_tokens

_INF = float("inf")


def calibrate_service_model(pool, image_shape, iters=3):
    """bucket → median measured service seconds, on a warm pool.

    Runs on engine 0 (all replicas serve the same compiled programs). The
    result is the frozen timing law of the virtual clock AND the basis for
    trace calibration (offered rate, deadline budgets, linger threshold) —
    median-of-iters so one noisy sample cannot skew a whole benchmark run.
    """
    return calibrate_service_models([pool], image_shape, iters=iters)[0]


def calibrate_service_models(pools, image_shape, iters=3):
    """Calibrate several pools' service models in INTERLEAVED rounds.

    Every (pool, bucket) pair is sampled once per round, round-robin, so
    machine-load drift over the calibration window hits every policy arm
    equally — the same trick `vision.freeze_ab` uses for its A/B. Two
    sequentially-calibrated arms on a busy host can otherwise disagree by
    more than the shiftadd-vs-dense effect the p99 gate checks, flipping
    the comparison. Returns one {bucket: median seconds} dict per pool.
    """
    shape = tuple(image_shape)
    work = [(i, pool.engines[0], b) for i, pool in enumerate(pools)
            for b in pool.buckets]
    # iters + 1 timed rounds; round 0 is the touch/cache-warm round and is
    # discarded by `service_median_warm` — the same warmup convention as the
    # LM calibrator, so neither service model absorbs first-round noise.
    samples = {(i, b): [] for i, _, b in work}
    for _ in range(max(int(iters), 1) + 1):
        for i, engine, b in work:
            imgs = jnp.zeros((b,) + shape, jnp.float32)
            t0 = time.perf_counter()
            jax.block_until_ready(engine.infer(imgs))
            samples[(i, b)].append(time.perf_counter() - t0)
    return [{b: service_median_warm(samples[(i, b)], warmup=1)
             for b in pool.buckets}
            for i, pool in enumerate(pools)]


def default_image_fn(cfg):
    """Deterministic synthetic payloads: request seed → images. The same
    request always carries the same pixels, so replays and the oversize
    parity test compare like for like. The full request is generated once
    and cached (keyed by seed/size) — a k-part oversize request slices the
    same array k times instead of regenerating it per part."""
    shape = (cfg.image_size, cfg.image_size, cfg.in_channels)

    @functools.lru_cache(maxsize=8)
    def full_payload(seed, size):
        return jax.random.normal(jax.random.PRNGKey(seed), (size,) + shape)

    def images_for(req, offset, size):
        return full_payload(req.seed, req.size)[offset:offset + size]

    return images_for


@dataclasses.dataclass
class TrafficResult:
    report: dict                 # the BENCH_traffic.json policy record
    requests: list               # per-request dicts (rid order, shed incl.)
    logits: dict                 # rid → np.ndarray (size, n_classes)
    batches: list                # dispatch log (the routing signature)

    def routing_signature(self):
        """Hashable view of the routing: what was batched where and when —
        identical across replays of the same seeded trace."""
        return tuple(
            (round(b["formed_s"], 9), b["slot"], b["bucket"], b["reason"],
             tuple(b["parts"]))
            for b in self.batches)


def serve_trace(pool, scheduler: MicroBatchScheduler, trace: Trace, *,
                image_fn=None, collect_logits=True) -> TrafficResult:
    """Run a trace through the scheduler and replica pool. See the module
    docstring for the virtual-clock semantics."""
    if image_fn is None:
        image_fn = default_image_fn(pool.engines[0].model.cfg)
    svc = scheduler.service_model_s
    n_slots = pool.n_slots
    free_at = [0.0] * n_slots
    arrivals = list(trace.requests)
    ai = 0
    traces_at_start = pool.trace_count
    inflight = []                # (done_s, slot, Batch, future)
    batches_log = []
    shed = {}
    now = 0.0

    def dispatch_ready(drain=False):
        """Dispatch onto idle slots while the policy says go."""
        while True:
            idle = [s for s in range(n_slots) if free_at[s] <= now]
            if not idle:
                return
            batch = scheduler.form_batch(now, drain=drain)
            if batch is None:
                return
            slot = min(idle)                     # deterministic tie-break
            images = jnp.concatenate(
                [jnp.asarray(image_fn(p.req, p.offset, p.size))
                 for p in batch.parts], axis=0) if len(batch.parts) > 1 \
                else jnp.asarray(image_fn(batch.parts[0].req,
                                          batch.parts[0].offset,
                                          batch.parts[0].size))
            fut = pool.submit(slot, images)
            done = now + svc[batch.bucket]
            free_at[slot] = done
            inflight.append((done, slot, batch, fut))
            batches_log.append({
                "formed_s": batch.formed_s, "slot": slot,
                "bucket": batch.bucket, "n_images": batch.n_images,
                "reason": batch.reason, "done_s": done,
                "parts": [(p.rid, p.part_idx, p.size) for p in batch.parts],
            })

    while True:
        while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
            req = arrivals[ai]
            if not scheduler.offer(req, req.arrival_s):
                shed[req.rid] = req
            ai += 1
        dispatch_ready()
        candidates = []
        if ai < len(arrivals):
            candidates.append(arrivals[ai].arrival_s)
        busy = [t for t in free_at if t > now]
        if busy:
            candidates.append(min(busy))
        # A forced-dispatch time is only an event if a slot is idle to act
        # on it (dispatch_ready above already consumed any forced <= now);
        # with every slot busy, the next event is a slot freeing.
        if scheduler.has_queued() and len(busy) < n_slots:
            forced = scheduler.next_forced_dispatch_s()
            if forced is not None and forced > now:
                candidates.append(forced)
        if not candidates:
            if scheduler.has_queued():   # only reachable with inf thresholds
                dispatch_ready(drain=True)
                continue
            break
        now = max(now, min(candidates))

    # -- resolve real execution, reassemble per-request ---------------------
    part_out = {}                # (rid, part_idx) → (record, logits)
    wall_samples = []
    for done_s, slot, batch, fut in inflight:
        logits, wall_s = fut.result()
        wall_samples.append(wall_s)
        logits = np.asarray(logits)
        off = 0
        for p in batch.parts:
            rec = {"dispatch_s": batch.formed_s, "done_s": done_s,
                   "slot": slot, "bucket": batch.bucket,
                   "n_parts": p.n_parts,
                   "wait_s": batch.formed_s - p.enqueued_s}
            part_out[(p.rid, p.part_idx)] = (
                rec, logits[off:off + p.size] if collect_logits else None)
            off += p.size

    requests_out, logits_out = [], {}
    latencies, waits = [], []
    met_requests = met_images = late_requests = 0
    for req in trace.requests:
        if req.rid in shed:
            requests_out.append({
                "rid": req.rid, "klass": req.klass, "size": req.size,
                "arrival_s": req.arrival_s, "shed": True, "met": False})
            continue
        # The scheduler stamped its split on every part — read it back
        # rather than re-deriving the chunking rule here.
        n_parts = part_out[(req.rid, 0)][0]["n_parts"]
        parts = [part_out[(req.rid, i)] for i in range(n_parts)]
        completion = max(rec["done_s"] for rec, _ in parts)
        latency = completion - req.arrival_s
        met = completion <= req.deadline_s
        latencies.append(latency)
        waits.extend(rec["wait_s"] for rec, _ in parts)
        met_requests += int(met)
        met_images += req.size * int(met)
        late_requests += int(not met)
        requests_out.append({
            "rid": req.rid, "klass": req.klass, "size": req.size,
            "arrival_s": req.arrival_s, "deadline_s": req.deadline_s,
            "completion_s": completion, "latency_s": latency,
            "met": met, "shed": False,
            "slots": sorted({rec["slot"] for rec, _ in parts})})
        if collect_logits:
            logits_out[req.rid] = np.concatenate(
                [lg for _, lg in parts], axis=0)

    total = len(trace.requests)
    makespan = max((b["done_s"] for b in batches_log), default=0.0)
    real = sum(b["n_images"] for b in batches_log)
    padded = sum(b["bucket"] for b in batches_log)
    reasons = {}
    for b in batches_log:
        reasons[b["reason"]] = reasons.get(b["reason"], 0) + 1
    report = {
        "scenario": trace.scenario,
        "seed": trace.seed,
        "arm": pool.arm,
        "replicas": n_slots,
        "buckets": list(pool.buckets),
        "service_model_s": {str(b): s for b, s in svc.items()},
        "slack_s": scheduler.slack_s,
        "linger_s": scheduler.linger_s,
        "requests": total,
        "images": trace.total_images,
        "offered_images_per_s": trace.target_images_per_s,
        "served_requests": total - len(shed),
        "shed_requests": len(shed),
        "deadline_miss_rate": ((late_requests + len(shed)) / total
                               if total else 0.0),
        "deadline_met_requests": met_requests,
        "goodput_images_per_s": met_images / makespan if makespan else 0.0,
        "latency": latency_summary(latencies),
        "queue_wait": latency_summary(waits),
        "measured_batch": latency_summary(wall_samples),
        "batches": len(batches_log),
        "batch_size_mean": real / len(batches_log) if batches_log else 0.0,
        "padding_waste": padding_waste(real, padded),
        "dispatch_reasons": reasons,
        "virtual_makespan_s": makespan,
        "recompiles_after_warmup": pool.trace_count - traces_at_start,
    }
    return TrafficResult(report=report, requests=requests_out,
                         logits=logits_out, batches=batches_log)


# ---------------------------------------------------------------------------
# Policy sweep under traffic: the BENCH_traffic.json record
# ---------------------------------------------------------------------------

def _build_router_arm(base_cfg, dense_model, dense_params, telemetry, *,
                      buckets, impl, tune, iters, seed, steps, lr, shape):
    """The telemetry-trained router arm: the shiftadd conversion with
    measured (or model-mode) per-expert latencies applied and ONLY the
    router fine-tuned against them (train.router_tune). Returns
    (model, params, info, telemetry)."""
    from repro.serve.telemetry import (apply_expert_latencies,
                                       extract_expert_telemetry)
    from repro.serve.vision import build_policy_model
    from repro.train.router_tune import router_finetune

    model, params = build_policy_model(base_cfg, "shiftadd", dense_model,
                                       dense_params)
    if telemetry is None:
        telemetry = extract_expert_telemetry(model, params, buckets=buckets,
                                             impl=impl, tune=tune,
                                             iters=iters)
    apply_expert_latencies(model, telemetry)
    imgs = jax.random.normal(jax.random.PRNGKey(seed + 101), (16,) + shape)
    params, history = router_finetune(model, params, imgs, steps=steps,
                                      lr=lr)
    info = {"expert_latency_source": f"telemetry:{telemetry.mode}",
            "router_steps": len(history),
            "router_balance_loss_first": history[0],
            "router_balance_loss_last": history[-1]}
    return model, params, info, telemetry


def _moe_capacity_plans(model, n_tokens):
    from repro.core.moe_primitives import MoEPrimitives

    return [blk.feed.capacity_plan(n_tokens) for blk in model.blocks
            if isinstance(blk.feed, MoEPrimitives)]


def _arm_token_share(model, params, pool, images):
    """Expert token share under the arm's own frozen serving params."""
    from repro.serve.telemetry import measure_token_share

    eng = pool.engines[0]
    plan = getattr(eng, "plan", None)
    run_params = plan.params if plan is not None else params
    return measure_token_share(model, run_params, images,
                               impl=getattr(eng, "impl", None),
                               tune=getattr(eng, "tune", None))


def traffic_sweep(base_cfg=None, *, scenario="poisson",
                  policies=("dense", "shiftadd"), n_requests=500, seed=0,
                  replicas=2, arm="auto", utilization=0.4, buckets=None,
                  freeze=True, impl=None, tune=None, max_size=None,
                  slack_frac=0.5,
                  linger_frac=1.0, max_queue_images=None, target_p99_s=None,
                  calibrate_iters=3, verify_replay=False,
                  verify_one_vs_n=False, collect_logits=False,
                  telemetry=None, router_steps=40, router_lr=0.05) -> dict:
    """Serve one seeded trace through every policy arm; return the
    BENCH_traffic.json record.

    All arms share the SAME pretrained dense weights (the policy_sweep
    premise) and face the SAME trace: arrivals and deadline budgets are
    calibrated once, from the slowest arm listed (dense when present), at
    `utilization` × that arm's measured replica capacity — so the shiftadd
    vs dense p99 comparison is apples-to-apples and the calibrated default
    load is feasible for every arm (deadline-miss rate 0, CI-gated).

    Per-arm scheduler knobs scale with that arm's own calibration
    (linger = linger_frac × max-bucket service, slack = slack_frac × it),
    which is exactly how an operator would deploy each model.

    verify_replay: serve the trace twice per arm and record whether the
    routing signature and the logits replay bit-identically (they must —
    the determinism acceptance criterion, MoE arms included).

    verify_one_vs_n: additionally serve each arm's trace through a ONE-slot
    thread pool over the same buckets/knobs and record
    `one_vs_n_bit_identical_logits`: per-request logits must survive the
    (generally different) single-replica batch compositions bit-for-bit —
    the serving-level statement of the per-image batch-invariance contract,
    CI-gated on the shiftadd arm by benchmarks/check_traffic.py.

    policy "router" is the telemetry-trained arm: the shiftadd conversion
    with per-expert serving telemetry applied (`telemetry`, or extracted
    in-process when None) and only the router fine-tuned against it
    (`router_steps` × `router_lr`). When its capacity plans equal the
    analytic shiftadd arm's (always in telemetry model mode — the analytic
    fallback IS the serving-geometry model), the two arms compile
    byte-identical program geometry and differ only in router weight
    values, so the router arm REUSES the shiftadd service model
    (`service_model_shared_with`): one timing law for one program geometry.
    Calibrating them separately could only inject runner noise into the
    router ≤ shiftadd p99 gate; with measured (TPU) telemetry the plans
    genuinely differ and each arm keeps its own interleaved calibration.
    """
    import dataclasses as _dc

    from repro.core.policy import DENSE
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve.replicas import ThreadPoolReplicas, make_replicas
    from repro.serve.traffic import default_budgets, make_trace
    from repro.serve.vision import DEFAULT_BUCKETS, build_policy_model

    base_cfg = base_cfg or ViTConfig(image_size=56)
    buckets = tuple(buckets) if buckets else DEFAULT_BUCKETS
    dense_model = ShiftAddViT(_dc.replace(base_cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(seed))
    shape = (base_cfg.image_size, base_cfg.image_size, base_cfg.in_channels)

    pools = {}
    arms = {}
    router_info = None
    for name in policies:
        if name == "router":
            model, params, router_info, telemetry = _build_router_arm(
                base_cfg, dense_model, dense_params, telemetry,
                buckets=buckets, impl=impl, tune=tune,
                iters=calibrate_iters, seed=seed, steps=router_steps,
                lr=router_lr, shape=shape)
        else:
            model, params = build_policy_model(base_cfg, name, dense_model,
                                               dense_params)
        arms[name] = (model, params)
        pools[name] = make_replicas(model, params, n_replicas=replicas,
                                    arm=arm, buckets=buckets, freeze=freeze,
                                    impl=impl, tune=tune).warmup()
    # Interleaved calibration: load drift hits every arm equally, so the
    # p99 crossover the CI gates compares calibrations taken under the
    # same conditions (see calibrate_service_models).
    svc_list = calibrate_service_models(list(pools.values()), shape,
                                        iters=calibrate_iters)
    svc_models = dict(zip(pools, svc_list))
    svc_shared = {}
    if "router" in pools and "shiftadd" in pools:
        n_pat = base_cfg.n_patches
        if (_moe_capacity_plans(arms["router"][0], n_pat)
                == _moe_capacity_plans(arms["shiftadd"][0], n_pat)):
            # Identical capacity plans ⇒ identical compiled program geometry
            # (only router weight VALUES differ) ⇒ one timing law. See the
            # docstring's router-arm paragraph.
            svc_models["router"] = dict(svc_models["shiftadd"])
            svc_shared["router"] = "shiftadd"

    # One trace for every arm, calibrated on the slowest arm listed so the
    # load is feasible everywhere (dense is the slowest policy by design).
    anchor = "dense" if "dense" in pools else list(policies)[0]
    bmax = pools[anchor].buckets[-1]
    svc_anchor = svc_models[anchor]
    capacity = pools[anchor].n_slots * bmax / svc_anchor[bmax]
    budgets = default_budgets(svc_anchor[bmax])
    if target_p99_s is not None:
        budgets["interactive"] = float(target_p99_s)
    trace = make_trace(scenario, n_requests, seed,
                       target_images_per_s=utilization * capacity,
                       budgets_s=budgets, max_size=max_size or bmax)

    from repro.kernels import ops
    record = {
        "backend": jax.default_backend(),
        "model": (f"shiftadd_vit({base_cfg.n_layers}L,{base_cfg.d_model}d,"
                  f"{base_cfg.n_patches}p)"),
        "image_size": base_cfg.image_size,
        "frozen": bool(freeze),
        "impl": impl or ops.default_impl(),
        "tuned": tune is not None,
        "tune_meta": dict(getattr(tune, "meta", ()) or ()) or None,
        "utilization": utilization,
        "trace": trace.summary(),
        "budgets_s": budgets,
        "target_p99_s": target_p99_s,
        "policies": {},
    }
    for name in policies:
        pool, svc = pools[name], svc_models[name]
        pmax = pool.buckets[-1]

        def make_sched():
            return MicroBatchScheduler(
                pool.buckets, svc,
                slack_s=slack_frac * svc[pmax],
                linger_s=linger_frac * svc[pmax],
                max_queue_images=(max_queue_images
                                  if max_queue_images is not None
                                  else 8 * pmax))

        res = serve_trace(pool, make_sched(), trace,
                          collect_logits=(collect_logits or verify_replay
                                          or verify_one_vs_n))
        rep = res.report
        if target_p99_s is not None:
            rep["slo_attained"] = rep["latency"]["p99_s"] <= target_p99_s
        # MoE arms record the measured expert token share (seeded images,
        # the arm's own frozen serving params) — the router-vs-shiftadd
        # share gate in check_traffic.py reads these.
        share_imgs = jax.random.normal(jax.random.PRNGKey(seed + 202),
                                       (8,) + shape)
        share = _arm_token_share(*arms[name], pool, share_imgs)
        if share:
            rep["expert_token_share"] = share
        if name == "router":
            rep.update(router_info)
            if name in svc_shared:
                rep["service_model_shared_with"] = svc_shared[name]
        if verify_replay:
            res2 = serve_trace(pool, make_sched(), trace,
                               collect_logits=True)
            rep["replay_identical_routing"] = (
                res.routing_signature() == res2.routing_signature())
            rep["replay_bit_identical_logits"] = all(
                np.array_equal(res.logits[r], res2.logits[r])
                for r in res.logits)
        if verify_one_vs_n:
            # A one-slot thread pool over the SAME effective buckets and
            # batching knobs: batch compositions generally differ from the
            # N-slot arm's, and per-request logits must not care
            # (batch-invariance contract; a fresh engine also makes this a
            # program-clone check). The solo run gets an UNBOUNDED
            # admission queue — it faces N× its calibrated share, and a
            # shed request cannot be compared at all; since the contract
            # says every scheduler knob is logit-neutral, deepening the
            # queue is itself one of the perturbations being verified, and
            # it buys full coverage by construction. The record still
            # carries the compared/shed counts and check_traffic.py fails
            # on a partial comparison, so a future regression in either
            # cannot silently hollow the gate out.
            model, params = arms[name]
            solo = ThreadPoolReplicas(model, params, n_replicas=1,
                                      buckets=pool.buckets, freeze=freeze,
                                      impl=impl, tune=tune).warmup()
            pmax_solo = solo.buckets[-1]
            solo_sched = MicroBatchScheduler(
                solo.buckets, svc,
                slack_s=slack_frac * svc[pmax_solo],
                linger_s=linger_frac * svc[pmax_solo],
                max_queue_images=None)
            res1 = serve_trace(solo, solo_sched, trace,
                               collect_logits=True)
            solo.close()
            common = set(res.logits) & set(res1.logits)
            rep["one_vs_n_diverged_batches"] = (
                res.routing_signature() != res1.routing_signature())
            rep["one_vs_n_compared"] = len(common)
            rep["one_vs_n_solo_shed"] = res1.report["shed_requests"]
            rep["one_vs_n_bit_identical_logits"] = bool(common) and all(
                np.array_equal(res.logits[r], res1.logits[r])
                for r in common)
        record["policies"][name] = rep
        pool.close()
    if "dense" in record["policies"] and len(record["policies"]) > 1:
        d99 = record["policies"]["dense"]["latency"]["p99_s"]
        for name, rep in record["policies"].items():
            rep["p99_vs_dense"] = rep["latency"]["p99_s"] / d99
        if "shiftadd" in record["policies"]:
            record["shiftadd_vs_dense_p99"] = (
                record["policies"]["shiftadd"]["latency"]["p99_s"] / d99)
    pols = record["policies"]
    if "router" in pols and "shiftadd" in pols:
        record["telemetry_meta"] = (telemetry.meta_dict
                                    if telemetry is not None else None)
        s99 = pols["shiftadd"]["latency"]["p99_s"]
        if s99 > 0:
            record["router_vs_shiftadd_p99"] = (
                pols["router"]["latency"]["p99_s"] / s99)
        sa = pols["shiftadd"].get("expert_token_share", {})
        ro = pols["router"].get("expert_token_share", {})
        if "shift" in sa and "shift" in ro:
            record["router_shift_share_gain"] = ro["shift"] - sa["shift"]
    return record


# ---------------------------------------------------------------------------
# Token-level LM serving: continuous batching under the same virtual clock
# ---------------------------------------------------------------------------
# Same determinism model as the vision path above: engine execution is REAL
# (every prefill / decode chunk runs through the warmed BucketedLMEngine and
# per-request tokens+logits are reassembled from the slot rows), scheduling
# TIME is VIRTUAL (a calibrated service model advances per-engine timelines).
# The event grid is the engine's CHUNK BOUNDARY: finished slots are evicted,
# queued requests are admitted into free slots (joining the RUNNING decode
# batch — the continuous-batching tentpole), and one decode chunk advances
# every slot. `mode="static"` is the fixed-batch refill baseline: the SAME
# engine, but a request may only be admitted when EVERY slot is free (gang
# refill), so the continuous-vs-static comparison is pure scheduling — zero
# extra compiled programs, identical per-request logits (decode is row-wise
# per slot; admission timing cannot move a logit, only a latency).


def calibrate_lm_service(pool, iters=3):
    """LM timing law: median prefill seconds per prompt bucket + median
    decode-chunk seconds, measured on engine 0 of a WARM pool (all engines
    serve identical programs). Uses the real serving entry points
    (`admit` / `decode_chunk`), so the host-transfer cost serving actually
    pays is included. The pool is reset afterwards — calibration leaves no
    slot state and compiles nothing."""
    eng = pool.engines[0]
    pre = {b: [] for b in eng.prompt_buckets}
    chunks = []
    for _ in range(max(1, int(iters)) + 1):    # round 0 = touch, discarded
        for b in eng.prompt_buckets:
            prompt = np.zeros((b,), np.int32)
            t0 = time.perf_counter()
            eng.admit(0, prompt)
            pre[b].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            eng.decode_chunk()
            chunks.append(time.perf_counter() - t0)
            eng.evict(0)
    pool.reset()
    # Shared warmup convention (metrics.service_median_warm): drop round 0 —
    # one sample per prompt bucket, n_b chunk samples (chunks interleave
    # round-major across buckets).
    n_b = len(eng.prompt_buckets)
    return {"prefill_s": {b: service_median_warm(xs, warmup=1)
                          for b, xs in pre.items()},
            "chunk_s": service_median_warm(chunks, warmup=n_b)}


@dataclasses.dataclass
class LMTrafficResult:
    report: dict                 # the BENCH_lm_traffic.json arm record
    requests: list               # per-request dicts (rid order, shed incl.)
    tokens: dict                 # rid → np.ndarray (new_tokens,) int32
    logits: dict                 # rid → np.ndarray (new_tokens, vocab)
    dispatches: list             # admission log (the dispatch signature)

    def dispatch_signature(self):
        """Hashable view of the routing: which request was admitted where
        and when — identical across replays of the same seeded trace."""
        return tuple(
            (d["rid"], round(d["admit_s"], 9), d["engine"], d["slot"],
             d["bucket"])
            for d in self.dispatches)


def serve_lm_trace(pool, scheduler: SlotScheduler, trace: Trace, svc, *,
                   mode="continuous", new_token_range=(4, 24),
                   collect_logits=True) -> LMTrafficResult:
    """Serve a seeded token trace through the slot scheduler and LM pool.

    `Request.size` is the prompt length; the payload helpers in
    serve.traffic derive the prompt tokens and decode length from the
    request seed. svc is `calibrate_lm_service`'s output. mode:
    "continuous" admits into any free slot at any chunk boundary;
    "static" only refills when ALL of an engine's slots are free.
    """
    assert mode in ("continuous", "static"), mode
    engines = pool.engines
    vocab = engines[0].model.cfg.vocab_size
    prefill_s, chunk_s = svc["prefill_s"], svc["chunk_s"]
    K = pool.chunk
    t = [0.0] * len(engines)
    slot_state = [[None] * e.n_slots for e in engines]
    arrivals = list(trace.requests)
    ai = 0
    traces_at_start = pool.trace_count
    dispatches, shed, done = [], {}, {}
    tokens_out, logits_out = {}, {}
    n_chunks = occupancy_sum = 0

    def finish(rec, now):
        req = rec["req"]
        done[req.rid] = {
            "rid": req.rid, "klass": req.klass, "prompt_len": req.size,
            "new_tokens": rec["target"], "arrival_s": req.arrival_s,
            "deadline_s": req.deadline_s, "admit_s": rec["admit_s"],
            "ttft_s": rec["ttft_s"], "completion_s": now,
            "latency_s": now - req.arrival_s,
            "met": now <= req.deadline_s, "shed": False,
            "engine": rec["engine"], "slot": rec["slot"],
            "bucket": rec["bucket"]}
        tokens_out[req.rid] = np.concatenate(rec["toks"])
        if collect_logits:
            logits_out[req.rid] = np.concatenate(rec["logits"], axis=0)

    while True:
        if (ai >= len(arrivals) and not scheduler.has_queued()
                and all(r is None for st in slot_state for r in st)):
            break
        e = min(range(len(engines)), key=lambda i: t[i])
        now = t[e]
        while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
            req = arrivals[ai]
            if not scheduler.offer(req, req.arrival_s):
                shed[req.rid] = req
            ai += 1
        eng, st = engines[e], slot_state[e]

        # 1) chunk boundary: evict finished slots (they free NOW).
        for s_i, rec in enumerate(st):
            if rec is not None and rec["gen"] >= rec["target"]:
                eng.evict(s_i)
                finish(rec, now)
                st[s_i] = None

        # 2) admissions — continuous: any free slot; static: gang refill.
        free = [i for i, r in enumerate(st) if r is None]
        gang_ok = mode != "static" or len(free) == eng.n_slots
        while free and gang_ok and scheduler.has_queued():
            req, _enq = scheduler.next_request(now)
            slot = free.pop(0)
            admit_s = now
            first, first_logits = eng.admit(
                slot, lm_prompt_tokens(req, vocab), rid=req.rid)
            bucket = eng.bucket_for(min(req.size, eng.prompt_buckets[-1]))
            now += prefill_s[bucket]
            target = lm_new_tokens(req, *new_token_range)
            st[slot] = {
                "req": req, "admit_s": admit_s, "ttft_s": now - req.arrival_s,
                "target": target, "gen": 1, "engine": e, "slot": slot,
                "bucket": bucket,
                "toks": [np.asarray([first], np.int32)],
                "logits": [first_logits[None]] if collect_logits else None}
            dispatches.append({
                "rid": req.rid, "admit_s": admit_s, "engine": e, "slot": slot,
                "bucket": bucket, "prompt_len": req.size,
                "new_tokens": target})

        # 3) decode one chunk over ALL slots, or jump to the next arrival.
        alive = [i for i, r in enumerate(st) if r is not None]
        if alive:
            toks_seq, logits_seq = eng.decode_chunk()
            for s_i in alive:
                rec = st[s_i]
                take = min(K, rec["target"] - rec["gen"])
                if take > 0:
                    rec["toks"].append(toks_seq[:take, s_i].copy())
                    if collect_logits:
                        rec["logits"].append(logits_seq[:take, s_i].copy())
                    rec["gen"] += take
            n_chunks += 1
            occupancy_sum += len(alive)
            t[e] = now + chunk_s
        elif ai < len(arrivals):
            t[e] = max(now, arrivals[ai].arrival_s)
        else:
            t[e] = _INF

    # -- per-request records, rid order -------------------------------------
    requests_out, latencies, ttfts, waits = [], [], [], []
    met = late = gen_total = 0
    for req in trace.requests:
        if req.rid in shed:
            requests_out.append({
                "rid": req.rid, "klass": req.klass, "prompt_len": req.size,
                "arrival_s": req.arrival_s, "shed": True, "met": False})
            continue
        r = done[req.rid]
        requests_out.append(r)
        latencies.append(r["latency_s"])
        ttfts.append(r["ttft_s"])
        waits.append(r["admit_s"] - req.arrival_s)
        gen_total += r["new_tokens"]
        met += int(r["met"])
        late += int(not r["met"])

    total = len(trace.requests)
    makespan = max((r["completion_s"] for r in done.values()), default=0.0)
    n_slots_total = len(engines) * pool.n_slots
    report = {
        "scenario": trace.scenario,
        "seed": trace.seed,
        "mode": mode,
        "engines": len(engines),
        "n_slots": pool.n_slots,
        "chunk": K,
        "prompt_buckets": list(pool.prompt_buckets),
        "service_model": {"prefill_s": {str(b): s for b, s in
                                        prefill_s.items()},
                          "chunk_s": chunk_s},
        "requests": total,
        "served_requests": total - len(shed),
        "shed_requests": len(shed),
        "deadline_miss_rate": (late + len(shed)) / total if total else 0.0,
        "deadline_met_requests": met,
        "generated_tokens": gen_total,
        "virtual_makespan_s": makespan,
        "tokens_per_s": rate_per_s(gen_total, makespan),
        "latency": latency_summary(latencies),
        "ttft": latency_summary(ttfts),
        "queue_wait": latency_summary(waits),
        "decode_chunks": n_chunks,
        "chunk_occupancy": (occupancy_sum / (n_chunks * pool.n_slots)
                            if n_chunks else 0.0),
        "recompiles_after_warmup": pool.trace_count - traces_at_start,
        "prefill_trace_count": pool.prefill_trace_count,
        "expected_prefill_traces": len(engines) * len(pool.prompt_buckets),
    }
    return LMTrafficResult(report=report, requests=requests_out,
                           tokens=tokens_out, logits=logits_out,
                           dispatches=dispatches)


def lm_serial_oracle(pool, trace, rids, *, slots=None,
                     new_token_range=(4, 24), collect_logits=True):
    """Batch=1 oracle: the SAME engine serves each request ALONE, one at a
    time. Decode being row-wise per slot, the packed continuous run must
    reproduce these tokens and logits bit for bit — the LM serving statement
    of the batch-invariance contract (co-residency, join round and neighbor
    eviction can never move a logit).

    `slots` (rid → slot, default 0) pins each solo run to the slot the
    packed run used. The pin matters: XLA may compile a row's reductions
    differently per row *position* at some batch shapes (observed at
    n_slots=2 on CPU — ULP-level, slot-1 rows only), so comparing packed
    slot 1 against solo slot 0 would charge that kernel artifact to the
    scheduler. Holding the slot fixed isolates the property actually being
    gated; slot-*permutation* invariance is pinned separately by the
    property tier at the gated geometries. Returns (tokens, logits) dicts
    keyed by rid; the pool is reset before and after."""
    eng = pool.engines[0]
    pool.reset()
    vocab = eng.model.cfg.vocab_size
    K = eng.chunk
    slots = slots or {}
    toks_out, logits_out = {}, {}
    for req in trace.requests:
        if req.rid not in rids:
            continue
        slot = slots.get(req.rid, 0)
        first, first_logits = eng.admit(slot, lm_prompt_tokens(req, vocab),
                                        rid=req.rid)
        target = lm_new_tokens(req, *new_token_range)
        toks = [np.asarray([first], np.int32)]
        lgs = [first_logits[None]]
        gen = 1
        while gen < target:
            ts, ls = eng.decode_chunk()
            take = min(K, target - gen)
            toks.append(ts[:take, slot].copy())
            if collect_logits:
                lgs.append(ls[:take, slot].copy())
            gen += take
        eng.evict(slot)
        toks_out[req.rid] = np.concatenate(toks)
        if collect_logits:
            logits_out[req.rid] = np.concatenate(lgs, axis=0)
    pool.reset()
    return toks_out, logits_out


def lm_traffic_sweep(*, scenario="poisson", policies=("stage1", "shiftadd"),
                     n_requests=60, seed=0, n_replicas=1, n_slots=4,
                     prompt_buckets=(4, 8, 16), chunk=4, layers=2,
                     d_model=64, vocab_size=256, utilization=1.5,
                     new_token_range=(4, 24), max_queue_requests=None,
                     calibrate_iters=3, verify_replay=True,
                     verify_serial_oracle=True) -> dict:
    """Continuous vs static (gang-refill) LM decode on one seeded trace per
    policy arm; returns the BENCH_lm_traffic.json record.

    Both modes run on the SAME warmed pool (mode is host-side scheduling
    only), so the tokens/s comparison carries zero compile-count or
    program-identity confounds — `recompiles_after_warmup` must be 0 on
    both arms and `prefill_trace_count` must equal engines × buckets.
    The default load (`utilization=1.5` of the calibrated full-occupancy
    request capacity) is deliberately an overload: continuous admission
    then keeps slots busy where gang refill drains them, which is the
    structural win the gate (benchmarks/check_lm_traffic.py) asserts as
    continuous tokens/s >= static tokens/s.

    verify_replay: serve the continuous trace twice and record whether the
    dispatch signature, tokens, and logits replay bit-identically.
    verify_serial_oracle: re-serve every request alone at batch=1 through
    the same engine and record `one_vs_n_bit_identical_logits` (plus the
    compared count, so a partial comparison cannot impersonate a full one).
    """
    import math

    from repro.configs.base import ModelConfig
    from repro.core.policy import SHIFTADD, STAGE1
    from repro.serve.replicas import make_lm_replicas

    from repro.serve.traffic import default_budgets, make_trace

    POLICY_BY_NAME = {"stage1": STAGE1, "shiftadd": SHIFTADD}
    g_lo, g_hi = new_token_range
    record = {
        "backend": jax.default_backend(),
        "model": f"lm({layers}L,{d_model}d,vocab{vocab_size})",
        "n_replicas": n_replicas,
        "n_slots": n_slots,
        "chunk": chunk,
        "prompt_buckets": list(prompt_buckets),
        "utilization": utilization,
        "new_token_range": list(new_token_range),
        "policies": {},
    }
    for name in policies:
        from repro.nn.model import LanguageModel

        cfg = ModelConfig(name=f"lm-traffic-{name}", family="dense",
                          policy=POLICY_BY_NAME[name], n_layers=layers,
                          d_model=d_model, n_heads=2, n_kv_heads=2,
                          d_ff=2 * d_model, vocab_size=vocab_size,
                          dtype="float32", scan_layers=True, remat="none",
                          moe_primitives_capacity=2.0)
        model = LanguageModel(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        pool = make_lm_replicas(model, params, n_replicas=n_replicas,
                                n_slots=n_slots,
                                prompt_buckets=prompt_buckets,
                                chunk=chunk).warmup()
        svc = calibrate_lm_service(pool, iters=calibrate_iters)

        # Offered load calibration: at full occupancy one engine completes
        # ~n_slots requests per (mean prefill + mean decode chunks), so the
        # request capacity is slots/(per-request service). make_trace takes
        # a token (image) rate with mean request size ~4 tokens (the
        # geometric(0.25) prompt-length mean).
        mean_prompt = 4.0
        chunks_mean = math.ceil(max(0.5 * (g_lo + g_hi) - 1, 0) / chunk)
        bucket_mean = pool.engines[0].bucket_for(int(mean_prompt))
        req_service = (svc["prefill_s"][bucket_mean]
                       + chunks_mean * svc["chunk_s"])
        capacity_req_s = n_replicas * n_slots / req_service
        bmax = pool.prompt_buckets[-1]
        chunks_max = math.ceil(max(g_hi - 1, 0) / chunk)
        budgets = default_budgets(svc["prefill_s"][bmax]
                                  + chunks_max * svc["chunk_s"])
        trace = make_trace(scenario, n_requests, seed,
                           target_images_per_s=(utilization * capacity_req_s
                                                * mean_prompt),
                           budgets_s=budgets, max_size=bmax)

        def sched():
            return SlotScheduler(max_queue_requests=max_queue_requests)

        collect = verify_replay or verify_serial_oracle
        res_c = serve_lm_trace(pool, sched(), trace, svc, mode="continuous",
                               new_token_range=new_token_range,
                               collect_logits=collect)
        pool.reset()
        res_s = serve_lm_trace(pool, sched(), trace, svc, mode="static",
                               new_token_range=new_token_range,
                               collect_logits=False)
        pool.reset()
        rep = {"continuous": res_c.report, "static": res_s.report,
               "trace": trace.summary(),
               "continuous_vs_static_tokens_per_s": (
                   res_c.report["tokens_per_s"]
                   / res_s.report["tokens_per_s"]
                   if res_s.report["tokens_per_s"] else float("inf"))}
        if verify_replay:
            res2 = serve_lm_trace(pool, sched(), trace, svc,
                                  mode="continuous",
                                  new_token_range=new_token_range,
                                  collect_logits=True)
            pool.reset()
            rep["replay_identical_dispatch"] = (
                res_c.dispatch_signature() == res2.dispatch_signature())
            rep["replay_bit_identical_tokens"] = (
                set(res_c.tokens) == set(res2.tokens) and all(
                    np.array_equal(res_c.tokens[r], res2.tokens[r])
                    for r in res_c.tokens))
            rep["replay_bit_identical_logits"] = (
                set(res_c.logits) == set(res2.logits) and all(
                    np.array_equal(res_c.logits[r], res2.logits[r])
                    for r in res_c.logits))
        if verify_serial_oracle:
            slot_of = {r["rid"]: r["slot"] for r in res_c.requests
                       if not r.get("shed")}
            toks1, lgs1 = lm_serial_oracle(
                pool, trace, set(res_c.tokens), slots=slot_of,
                new_token_range=new_token_range)
            common = set(res_c.logits) & set(lgs1)
            rep["one_vs_n_compared"] = len(common)
            rep["one_vs_n_bit_identical_tokens"] = bool(toks1) and all(
                np.array_equal(res_c.tokens[r], toks1[r]) for r in toks1)
            rep["one_vs_n_bit_identical_logits"] = bool(common) and all(
                np.array_equal(res_c.logits[r], lgs1[r]) for r in common)
        record["policies"][name] = rep
    return record
