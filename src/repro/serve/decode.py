"""Serving: parallel prefill + scan-fused batched autoregressive decode.

The serving hot path has two phases, matching the paper's linear-attention
duality (chunked vs recurrent — the same split flash-linear-attention exposes
as mode='chunk' vs 'fused_recurrent'):

- **prefill**: the whole prompt is consumed in ONE chunked full-sequence pass
  (`model.prefill`) that emits a decode-ready cache — the final (d_k × d_v)
  recurrent carry for linear-attention layers, bulk-written KV rows for dense
  layers, trailing conv windows for the recurrent families. O(P) work, no
  per-token host round-trips.
- **decode**: the sampling loop is a single `jax.lax.scan` over
  `model.decode_step` + on-device sampling, jit-compiled with the cache
  donated. The host sees exactly one dispatch for the entire generation.

`make_serve_step` remains the single-token unit the decode dry-run cells
lower. Continuous batching lives one layer up, in `serve.lm.BucketedLMEngine`
(token-level slot array; requests join a running decode batch at chunk
boundaries) driven by `serve.frontend.serve_lm_trace` — that is the path
`examples/serve_lm.py` demonstrates and benchmarks/bench_lm_traffic.py gates.
`generate` below stays the one-shot whole-batch entry point and doubles as
the independent greedy oracle the continuous property tier compares against.

Note on token-choice MoE feeds: prefill routes the whole prompt as one group
while sequential decode routes per token, so capacity-limited dropping can
differ between the two paths. Non-MoE feeds (and MoE with generous capacity)
are bit-comparable — see tests/test_prefill_decode.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, _ = model(params, batch["inputs"],
                          positions=batch.get("positions"), train=False)
        return logits

    return prefill_step


def make_prefill(model):
    """Cache-filling prefill step: (params, prompts, cache) → (logits, cache).

    logits is (B, 1, vocab) — the head runs on the last position only, since
    that is the one row the decode loop samples from.
    """
    def prefill(params, prompts, cache):
        return model.prefill(params, prompts, cache, last_only=True)

    return prefill


def make_serve_step(model):
    def serve_step(params, inputs_t, cache):
        return model.decode_step(params, inputs_t, cache)

    return serve_step


def _sample(logits, key, temperature):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature).astype(jnp.int32)


def make_decode_loop(model, temperature=0.0):
    """Whole-generation decode loop: sampling + decode_step fused in one
    `lax.scan`, so the entire autoregressive phase is a single device program
    (jit with the cache donated; no per-token host round-trip).

    (params, logits0 (B, V), cache, keys (T, ...)) → (tokens (B, T), cache).
    """
    def loop(params, logits0, cache, keys):
        def step(carry, key):
            logits, cache = carry
            tok = _sample(logits, key, temperature)
            logits, cache = model.decode_step(params, tok, cache)
            return (logits, cache), tok

        (_, cache), toks = jax.lax.scan(step, (logits0, cache), keys)
        return toks.swapaxes(0, 1), cache

    return loop


def generate(model, params, prompts, max_new_tokens, *, temperature=0.0,
             rng=None, max_len=None):
    """prompts: (B, P) int32. Returns (B, P+max_new_tokens) tokens.

    The prompt is consumed by one parallel chunked prefill pass; new tokens
    are then sampled by the scan-fused decode loop entirely on device.
    """
    if temperature > 0.0 and rng is None:
        raise ValueError(
            "temperature > 0 requires an rng key: pass "
            "rng=jax.random.PRNGKey(...) (or use temperature=0 for greedy)")
    b, p = prompts.shape
    max_len = max_len or (p + max_new_tokens)
    cache = model.init_cache(b, max_len=max_len)

    prefill = jax.jit(make_prefill(model), donate_argnums=(2,))
    logits_all, cache = prefill(params, prompts, cache)
    logits0 = logits_all[:, -1]

    if temperature > 0.0:
        keys = jax.random.split(rng, max_new_tokens)
    else:
        keys = jnp.zeros((max_new_tokens, 2), jnp.uint32)  # unused by argmax
    loop = jax.jit(make_decode_loop(model, temperature), donate_argnums=(2,))
    toks, _ = loop(params, logits0, cache, keys)
    return jnp.concatenate([prompts, toks], axis=1)
