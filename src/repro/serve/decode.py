"""Serving: prefill + batched autoregressive decode.

serve_step is the unit the decode dry-run cells lower: one new token against
a persistent cache (dense KV / ring-buffer / MLA latent / O(1) linear-attn
state — whichever the (arch, policy) pair dictates). `generate` is the
minimal batched driver used by the serving example: greedy or temperature
sampling, step-fused via jit with donated cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, _ = model(params, batch["inputs"],
                          positions=batch.get("positions"), train=False)
        return logits

    return prefill_step


def make_serve_step(model):
    def serve_step(params, inputs_t, cache):
        return model.decode_step(params, inputs_t, cache)

    return serve_step


def generate(model, params, prompts, max_new_tokens, *, temperature=0.0,
             rng=None, max_len=None):
    """prompts: (B, P) int32. Returns (B, P+max_new_tokens) tokens.

    Prompt tokens are fed through the decode path (cache warmup), then new
    tokens are sampled autoregressively.
    """
    b, p = prompts.shape
    max_len = max_len or (p + max_new_tokens)
    cache = model.init_cache(b, max_len=max_len)
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    logits = None
    for t in range(p):
        logits, cache = step(params, prompts[:, t], cache)

    out = [prompts]
    tok = None
    for i in range(max_new_tokens):
        if temperature <= 0.0:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(
                sub, logits.astype(jnp.float32) / temperature).astype(jnp.int32)
        out.append(tok[:, None])
        if i + 1 < max_new_tokens:
            logits, cache = step(params, tok, cache)
    return jnp.concatenate(out, axis=1)
