"""Seeded traffic-trace generator for the vision serving frontend.

A trace is a list of `Request`s — (arrival time, image count, deadline
class, absolute deadline, payload seed) — drawn from one of three arrival
scenarios with `numpy.random.default_rng(seed)`, so the same seed always
reproduces the same trace, bit for bit, on any machine:

- **poisson**: memoryless arrivals (exponential inter-arrival gaps) at a
  constant offered rate — the steady-state baseline.
- **bursty**: a two-state on/off process: bursts of geometrically many
  back-to-back requests at BURST_SPEEDUP× the base rate separated by long
  idle gaps — stresses queue growth and admission control.
- **diurnal**: the offered rate ramps sinusoidally from RAMP_LO× up to
  RAMP_HI× the base rate and back over the trace (a compressed day) —
  stresses the scheduler's behavior across load levels in one trace.

Rates are specified in *images* per second (requests carry variable image
counts), so the benchmark can calibrate offered load as a fraction of the
measured replica capacity: `target_images_per_s = utilization × capacity`.
Deadlines are per-class budgets added to the arrival time; the benchmark
derives the budgets from the measured max-bucket service time, which makes
the whole virtual timeline scale-invariant across machines (everything is
proportional to the calibration).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Deadline classes, strictest first. The scheduler serves FIFO *within* a
# class; across classes the earliest absolute deadline wins.
DEADLINE_CLASSES = ("interactive", "standard", "relaxed")

# Default mix of deadline classes and budget multipliers (× the measured
# max-bucket service time). Budgets are generous at the calibrated default
# load on purpose: the CI gate asserts deadline-miss rate == 0 there.
DEFAULT_CLASS_MIX = (0.5, 0.3, 0.2)
DEFAULT_BUDGET_MULTIPLIERS = {"interactive": 8.0, "standard": 16.0,
                              "relaxed": 40.0}

BURST_SPEEDUP = 5.0      # bursty: in-burst rate multiplier
BURST_MEAN_LEN = 8       # bursty: mean requests per burst (geometric)
IDLE_GAP_FACTOR = 6.0    # bursty: idle gap, in mean inter-arrival units
RAMP_LO, RAMP_HI = 0.4, 1.8   # diurnal: rate multiplier range

SCENARIOS = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int             # dense ids, 0..n-1 in arrival order
    arrival_s: float     # virtual arrival time
    size: int            # images in this request
    klass: str           # deadline class (DEADLINE_CLASSES)
    deadline_s: float    # absolute: arrival_s + class budget
    seed: int            # payload seed (deterministic synthetic images)

    @property
    def budget_s(self) -> float:
        return self.deadline_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class Trace:
    scenario: str
    seed: int
    requests: tuple
    target_images_per_s: float

    @property
    def total_images(self) -> int:
        return sum(r.size for r in self.requests)

    @property
    def horizon_s(self) -> float:
        return self.requests[-1].arrival_s if self.requests else 0.0

    def summary(self) -> dict:
        classes = {}
        for r in self.requests:
            classes[r.klass] = classes.get(r.klass, 0) + 1
        return {
            "scenario": self.scenario,
            "seed": self.seed,
            "requests": len(self.requests),
            "images": self.total_images,
            "horizon_s": self.horizon_s,
            "target_images_per_s": self.target_images_per_s,
            "classes": classes,
        }


def _draw_sizes(rng, n, max_size, oversize_prob):
    """Mostly-small geometric request sizes with an occasional oversize
    request (> max_size, exercising the scheduler's split path)."""
    sizes = np.minimum(rng.geometric(0.25, size=n), max_size)
    over = rng.random(n) < oversize_prob
    sizes = np.where(over, 2 * max_size + rng.integers(0, max_size, size=n),
                     sizes)
    return sizes.astype(int)


def _arrival_gaps(rng, scenario, n, mean_gap_s):
    """Inter-arrival gaps (seconds) for one scenario at a mean request gap.

    The modulated scenarios (bursty, diurnal) are renormalized so the gaps
    SUM to n × mean_gap_s exactly: their heavy-tailed/ramped shapes stay,
    but the trace-level offered rate is pinned to the calibrated target —
    the load calibration (utilization × measured capacity) must mean the
    same thing in every scenario. Poisson is left raw (its realized rate
    converges by the law of large numbers and renormalizing would denature
    the memorylessness the baseline scenario exists to provide).
    """
    if scenario == "poisson":
        return rng.exponential(mean_gap_s, size=n)
    if scenario == "bursty":
        gaps = []
        while len(gaps) < n:
            burst = max(1, int(rng.geometric(1.0 / BURST_MEAN_LEN)))
            burst = min(burst, n - len(gaps))
            gaps.extend(rng.exponential(mean_gap_s / BURST_SPEEDUP,
                                        size=burst))
            if len(gaps) < n:
                gaps[-1] += rng.exponential(IDLE_GAP_FACTOR * mean_gap_s)
        gaps = np.asarray(gaps[:n])
    elif scenario == "diurnal":
        # Rate multiplier ramps RAMP_LO → RAMP_HI → RAMP_LO across the
        # trace; gap i is exponential with the instantaneous mean.
        phase = np.sin(np.pi * np.arange(n) / max(n - 1, 1)) ** 2
        mult = RAMP_LO + (RAMP_HI - RAMP_LO) * phase
        gaps = rng.exponential(mean_gap_s / mult)
    else:
        raise ValueError(
            f"unknown scenario {scenario!r}; try one of {SCENARIOS}")
    return gaps * (n * mean_gap_s / gaps.sum())


def make_trace(scenario: str, n_requests: int, seed: int, *,
               target_images_per_s: float,
               budgets_s: dict,
               max_size: int = 32,
               class_mix=DEFAULT_CLASS_MIX,
               oversize_prob: float = 0.02) -> Trace:
    """Generate a seeded trace.

    target_images_per_s: offered load in images/s — the *mean request gap*
    is mean(size)/rate so the realized image rate matches regardless of the
    size distribution. budgets_s: deadline budget (seconds) per class name.
    """
    assert scenario in SCENARIOS, scenario
    rng = np.random.default_rng(seed)
    sizes = _draw_sizes(rng, n_requests, max_size, oversize_prob)
    mean_gap_s = float(sizes.mean()) / target_images_per_s
    gaps = _arrival_gaps(rng, scenario, n_requests, mean_gap_s)
    arrivals = np.cumsum(gaps)
    klasses = rng.choice(len(DEADLINE_CLASSES), size=n_requests, p=class_mix)
    payload_seeds = rng.integers(0, 2**31 - 1, size=n_requests)
    reqs = []
    for i in range(n_requests):
        klass = DEADLINE_CLASSES[klasses[i]]
        t = float(arrivals[i])
        reqs.append(Request(rid=i, arrival_s=t, size=int(sizes[i]),
                            klass=klass, deadline_s=t + budgets_s[klass],
                            seed=int(payload_seeds[i])))
    return Trace(scenario=scenario, seed=seed, requests=tuple(reqs),
                 target_images_per_s=target_images_per_s)


# ---------------------------------------------------------------------------
# LM traffic: deterministic token payloads
# ---------------------------------------------------------------------------
# The SAME seeded traces serve the LM frontend (serve.frontend.serve_lm_trace):
# `Request.size` is then the PROMPT LENGTH in tokens (the same mostly-short
# geometric distribution; oversize requests exercise the engine's
# context-window clipping instead of the vision split path) and the payload
# seed deterministically derives both the prompt tokens and the decode
# length — the same request always asks the same question and the same
# amount of answer, so replays and the batch=1 serial oracle compare like
# for like.

def lm_prompt_tokens(req: Request, vocab_size: int) -> np.ndarray:
    """Deterministic prompt for one request: (req.size,) int32 in [0, vocab)."""
    rng = np.random.default_rng(req.seed)
    return rng.integers(0, vocab_size, size=req.size).astype(np.int32)


def lm_new_tokens(req: Request, lo: int, hi: int) -> int:
    """Deterministic decode length (tokens to generate) in [lo, hi]."""
    assert 1 <= lo <= hi, (lo, hi)
    return int(lo + req.seed % (hi - lo + 1))


def default_budgets(max_bucket_service_s: float,
                    multipliers=None) -> dict:
    """Per-class deadline budgets from the measured max-bucket service time.
    The calibrated default (the CI-gated load) is deliberately generous —
    misses at that point indicate a scheduler bug, not tightness."""
    mult = multipliers or DEFAULT_BUDGET_MULTIPLIERS
    return {k: mult[k] * max_bucket_service_s for k in DEADLINE_CLASSES}
