"""Shared latency-metrics schema for the serving benchmarks.

BENCH_vit.json (per-batch sweep latencies) and BENCH_traffic.json
(per-request latencies under a simulated arrival process) report the same
summary keys, produced here, so dashboards and CI gates read one schema:

    {"p50_s": ..., "p95_s": ..., "p99_s": ..., "mean_s": ..., "max_s": ...,
     "n": ...}

Percentiles use sorted linear interpolation (numpy's default), which is
well-defined down to a single sample — a one-element list reports that
element for every percentile rather than NaN.
"""
from __future__ import annotations

import numpy as np

PERCENTILES = (50, 95, 99)


def latency_summary(samples_s) -> dict:
    """Summary stats of a list of latencies (seconds) under the shared
    BENCH_* schema. Empty input returns zeros with n=0 (a shed-everything
    run must still serialize)."""
    xs = np.asarray(list(samples_s), dtype=np.float64)
    if xs.size == 0:
        out = {f"p{p}_s": 0.0 for p in PERCENTILES}
        out.update(mean_s=0.0, max_s=0.0, n=0)
        return out
    out = {f"p{p}_s": float(np.percentile(xs, p)) for p in PERCENTILES}
    out.update(mean_s=float(xs.mean()), max_s=float(xs.max()), n=int(xs.size))
    return out


def padding_waste(real_images: int, padded_images: int) -> float:
    """Fraction of served batch slots that were padding: 1 - real/padded.
    0 when nothing was served (no slots, no waste)."""
    if padded_images <= 0:
        return 0.0
    return 1.0 - real_images / padded_images
