"""Shared latency-metrics schema for the serving benchmarks.

BENCH_vit.json (per-batch sweep latencies) and BENCH_traffic.json
(per-request latencies under a simulated arrival process) report the same
summary keys, produced here, so dashboards and CI gates read one schema:

    {"p50_s": ..., "p95_s": ..., "p99_s": ..., "mean_s": ..., "max_s": ...,
     "n": ..., "timer_resolution_s": ..., "method": "nearest-rank"}

Percentiles use NEAREST-RANK (the p-th percentile is an actual observed
sample: `sorted(xs)[ceil(p/100 * n) - 1]`), not interpolation. At the tiny
sample counts the CI sweeps run (n = 2..20 per cell), interpolated "p99"
is an extrapolation between the two largest samples — a value nobody
measured, dominated by single-sample noise — and gating on it made the
freeze/pallas checks flap. Nearest-rank is well-defined down to n=1 (every
percentile reports that one element) and at n < 100 degrades honestly:
p99 of 10 samples IS the max, and says so.

`gate_percentile(n)` encodes which percentile a gate may trust at a given
n: p99 needs >= 100 samples to be a distinct order statistic, p95 needs
>= 20, below that only p50 is meaningful. check_vit_freeze.py /
check_vit_pallas.py / check_traffic.py pick their gate key through it.
"""
from __future__ import annotations

import math
import time

import numpy as np

PERCENTILES = (50, 95, 99)


def nearest_rank(xs_sorted, p: float) -> float:
    """p-th percentile by nearest-rank on an already-sorted sequence.

    rank = ceil(p/100 * n), clamped to [1, n]; returns xs_sorted[rank-1].
    Always an observed sample, never an interpolated value.
    """
    n = len(xs_sorted)
    if n == 0:
        return 0.0
    rank = min(max(int(math.ceil(p / 100.0 * n)), 1), n)
    return float(xs_sorted[rank - 1])


def timer_resolution_s() -> float:
    """Resolution of the clock every serving benchmark times with."""
    return float(time.get_clock_info("perf_counter").resolution)


def gate_percentile(n: int) -> str:
    """Which summary key a CI gate may trust at sample count n.

    p99 is only a distinct order statistic at n >= 100 (below that it
    equals the max); p95 needs n >= 20; otherwise gate on the median.
    Returns the summary-dict key, e.g. "p50_s".
    """
    if n >= 100:
        return "p99_s"
    if n >= 20:
        return "p95_s"
    return "p50_s"


def latency_summary(samples_s) -> dict:
    """Summary stats of a list of latencies (seconds) under the shared
    BENCH_* schema. Empty input returns zeros with n=0 (a shed-everything
    run must still serialize). Percentiles are nearest-rank (see module
    docstring); `timer_resolution_s` records the perf_counter granularity
    so downstream readers can tell a 1e-5 s median apart from timer noise.
    """
    xs = np.sort(np.asarray(list(samples_s), dtype=np.float64))
    res = timer_resolution_s()
    if xs.size == 0:
        out = {f"p{p}_s": 0.0 for p in PERCENTILES}
        out.update(mean_s=0.0, max_s=0.0, n=0,
                   timer_resolution_s=res, method="nearest-rank")
        return out
    out = {f"p{p}_s": nearest_rank(xs, p) for p in PERCENTILES}
    out.update(mean_s=float(xs.mean()), max_s=float(xs[-1]), n=int(xs.size),
               timer_resolution_s=res, method="nearest-rank")
    return out


def service_median(samples_s) -> float:
    """Median measured service seconds — the calibration statistic every
    serving frontend freezes its virtual clock on (`calibrate_service_models`
    per ViT bucket, `calibrate_lm_service` per prompt bucket / decode chunk).

    Nearest-rank p50 over the samples (the same order-statistic convention
    as `latency_summary`): always an observed sample, well-defined from n=1,
    and at the odd sample counts the calibrators use (iters=3) identical to
    the classic `sorted(xs)[n // 2]` median both previously inlined.
    """
    return nearest_rank(sorted(float(x) for x in samples_s), 50)


def service_median_warm(samples_s, warmup=1) -> float:
    """`service_median` with the leading compile/cache-warm samples dropped.

    THE warmup convention for every service-model calibrator (ViT buckets,
    LM prompt buckets and decode chunks): the first `warmup` samples of a
    measurement series are discarded before taking the nearest-rank median.
    The two calibrators previously disagreed — LM dropped its first sample
    (`xs[1:]`) while ViT medianed over all of them — biasing the ViT service
    model (and any telemetry α derived from it) toward first-round noise.
    Falls back to the full series when discarding would leave nothing, so a
    single-sample calibration still returns that sample.
    """
    xs = [float(x) for x in samples_s]
    kept = xs[max(int(warmup), 0):]
    return service_median(kept if kept else xs)


def rate_per_s(count, seconds) -> float:
    """Throughput `count / seconds`; 0 when no time elapsed (an empty or
    shed-everything run must still serialize). Used for goodput (images/s)
    and decode throughput (tokens/s) so both serving benches derive their
    headline rate the same way."""
    if seconds <= 0:
        return 0.0
    return float(count) / float(seconds)


def padding_waste(real_images: int, padded_images: int) -> float:
    """Fraction of served batch slots that were padding: 1 - real/padded.
    0 when nothing was served (no slots, no waste)."""
    if padded_images <= 0:
        return 0.0
    return 1.0 - real_images / padded_images
