"""Engine replicas for the traffic frontend: thread-pool and data-parallel.

The scheduler sees `n_slots` identical logical servers; how a slot maps to
hardware is this module's concern:

- **ThreadPoolReplicas** (the CPU arm): R slots served by a
  `ThreadPoolExecutor`. By default all slots share ONE
  `BucketedViTEngine` — a jitted executable is stateless and thread-safe,
  so sharing keeps warmup at one compile per bucket no matter how many
  replicas. `share_engine=False` builds one engine per slot (full isolation,
  R× the warmup compiles — the shape a future multi-process pool takes).
  1-vs-N logit parity does NOT depend on the sharing, nor on replicas
  forming the same batches: the engine forward is batch-invariant per image
  (per-image MoE capacity dispatch, serve/vision.py), so per-request logits
  are bit-identical across replica counts even when batch compositions
  diverge — the `one_vs_n_bit_identical_logits` gate asserts exactly this.

- **DataParallelReplicas** (the multi-device arm): ONE slot whose engine
  shards every batch row-wise across a `("data",)` device mesh via
  `distributed.sharding.batch_sharding` — the repo's `batch → data` logical
  rule, reused by the vision path. Parallelism here accelerates each batch
  (the calibrated service model picks the speedup up automatically) instead
  of multiplying concurrent batches. Buckets are rounded up to multiples of
  the device count by the engine; read the effective set off
  `pool.buckets`. Row-sharding composes with the per-image dispatch (a
  row's routing reads only that row), so sharded logits are bit-identical
  to the single-device path — shiftadd included, pinned by the
  data-parallel arm test in tests/test_traffic_serve.py.

`make_replicas(..., arm="auto")` picks data-parallel when the backend has
enough devices, else the thread pool — so the same frontend code serves a
laptop CPU and a multi-device accelerator host.

All submissions return `concurrent.futures.Future`s; the frontend's virtual
clock never blocks on one until its completion event fires, so thread-pool
replicas genuinely overlap engine execution.
"""
from __future__ import annotations

import concurrent.futures
import time

import jax

from repro.serve.vision import DEFAULT_BUCKETS, BucketedViTEngine


class _ReplicaBase:
    engines: list
    n_slots: int

    @property
    def buckets(self):
        return self.engines[0].buckets

    @property
    def trace_count(self) -> int:
        return sum(e.trace_count for e in self.engines)

    def warmup(self):
        for e in self.engines:
            e.warmup()
        return self

    def close(self):
        pass


class ThreadPoolReplicas(_ReplicaBase):
    arm = "thread"

    def __init__(self, model, params, n_replicas=2, buckets=DEFAULT_BUCKETS,
                 freeze=True, impl=None, tune=None, share_engine=True):
        assert n_replicas >= 1
        n_engines = 1 if share_engine else n_replicas
        self.engines = [BucketedViTEngine(model, params, buckets=buckets,
                                          freeze=freeze, impl=impl, tune=tune)
                        for _ in range(n_engines)]
        self.n_slots = n_replicas
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=n_replicas, thread_name_prefix="vit-replica")
        self._closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    def _engine_for(self, slot: int) -> BucketedViTEngine:
        return self.engines[slot % len(self.engines)]

    def submit(self, slot: int, images) -> concurrent.futures.Future:
        """Future resolving to (logits, measured wall seconds)."""
        if self._closed:
            raise RuntimeError("submit() on a closed ThreadPoolReplicas")
        engine = self._engine_for(slot)

        def run():
            t0 = time.perf_counter()
            logits = jax.block_until_ready(engine.infer(images))
            return logits, time.perf_counter() - t0

        return self._pool.submit(run)

    def close(self):
        """Idempotent shutdown: waits for in-flight submissions (their
        Futures stay resolvable after close), then marks the pool closed —
        a second close is a no-op and a submit after close raises rather
        than silently queueing onto a dead executor."""
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)


class DataParallelReplicas(_ReplicaBase):
    arm = "sharded"

    def __init__(self, model, params, n_replicas=2, buckets=DEFAULT_BUCKETS,
                 freeze=True, impl=None, tune=None, devices=None):
        devices = list(devices if devices is not None else jax.devices())
        if len(devices) < n_replicas:
            raise ValueError(
                f"data-parallel arm needs {n_replicas} devices, backend has "
                f"{len(devices)} — use the thread arm (or arm='auto')")
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((n_replicas,), ("data",),
                         devices=devices[:n_replicas])
        self.mesh = mesh
        self.engines = [BucketedViTEngine(model, params, buckets=buckets,
                                          freeze=freeze, impl=impl, tune=tune,
                                          mesh=mesh)]
        self.n_slots = 1        # one logical server, n× per-batch speed

    def submit(self, slot: int, images) -> concurrent.futures.Future:
        """Future resolving to (logits, measured wall seconds); the sharded
        arm executes synchronously (one device set, one program at a time)."""
        fut = concurrent.futures.Future()
        t0 = time.perf_counter()
        logits = jax.block_until_ready(self.engines[0].infer(images))
        fut.set_result((logits, time.perf_counter() - t0))
        return fut


class LMReplicas:
    """R independent `BucketedLMEngine`s for token-level continuous batching.

    Unlike the ViT pool, LM engines are STATEFUL — the packed slot array
    (recurrent carries / KV rows / conv windows) lives in the engine — so
    replicas never share one: each replica owns its slot array and its own
    compiled programs. The frontend (`serve.frontend.serve_lm_trace`)
    advances one virtual timeline per engine and hands a queued request to
    whichever engine reaches a chunk boundary with a free slot first
    (ties: lowest index) — deterministic dispatch, same contract as the
    vision pool's lowest-idle-slot rule.
    """

    arm = "lm"

    def __init__(self, model, params, n_replicas=1, **engine_kw):
        from repro.serve.lm import BucketedLMEngine

        assert n_replicas >= 1
        self.engines = [BucketedLMEngine(model, params, **engine_kw)
                        for _ in range(n_replicas)]
        self.n_replicas = n_replicas

    @property
    def prompt_buckets(self):
        return self.engines[0].prompt_buckets

    @property
    def chunk(self) -> int:
        return self.engines[0].chunk

    @property
    def n_slots(self) -> int:
        return self.engines[0].n_slots

    @property
    def trace_count(self) -> int:
        return sum(e.trace_count for e in self.engines)

    @property
    def prefill_trace_count(self) -> int:
        return sum(e.prefill_trace_count for e in self.engines)

    @property
    def expected_programs(self) -> int:
        return sum(e.expected_programs for e in self.engines)

    def warmup(self):
        for e in self.engines:
            e.warmup()
        return self

    def reset(self):
        """Fresh slot arrays everywhere (no new programs)."""
        for e in self.engines:
            e.reset()
        return self

    def close(self):
        pass


def make_lm_replicas(model, params, n_replicas=1, **engine_kw):
    """LM pool factory, mirroring `make_replicas` for the vision arms.
    engine_kw forwards to BucketedLMEngine (n_slots, prompt_buckets, chunk,
    max_len)."""
    return LMReplicas(model, params, n_replicas=n_replicas, **engine_kw)


def make_replicas(model, params, n_replicas=2, arm="auto", **kw):
    """arm: 'thread' | 'sharded' | 'auto' (sharded when the backend has
    ≥ n_replicas devices and n_replicas > 1, else thread)."""
    if arm == "auto":
        arm = ("sharded" if n_replicas > 1
               and len(jax.devices()) >= n_replicas else "thread")
    cls = {"thread": ThreadPoolReplicas, "sharded": DataParallelReplicas}[arm]
    return cls(model, params, n_replicas=n_replicas, **kw)
