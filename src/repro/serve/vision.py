"""Batched ShiftAddViT inference engine — the paper's model, served.

Three pieces (DESIGN: measure the paper's headline latency/energy claims
end-to-end, not per-layer):

- **Inference forward**: `ShiftAddViT.infer` — train=False fast path with
  clean-logit argmax MoE routing, no rng, no aux-loss computation, and the
  deterministic latency-aware capacities of `MoEPrimitives.capacities`.
  Two calls on the same batch return identical logits.

- **Shape-bucketed batch assembly** (`BucketedViTEngine`): a stream of
  variable-size requests is padded into a small closed set of batch sizes
  (default {1, 8, 32} — the benchmark/CI set, surfaced as `engine.buckets`),
  so jit compiles exactly one program per bucket
  and steady-state traffic never retraces. `trace_count` exposes the compile
  counter the no-recompilation test asserts on. The image buffer is NOT
  donated: (B, H, W, C) inputs can never alias the (B, n_classes) logits, so
  donation was pure dead weight (`engine.donate_argnums`, audited by
  repro.analysis' JX005 rule, records the intent).

- **Deployment freeze** (`freeze=True`, the default): the engine builds a
  `core.deploy.DeployPlan` at construction — every shift weight decoded or
  packed exactly once, the MoE capacity plan warmed for the per-image token
  count — and the jitted forward closes over the frozen params as
  constants. Frozen and unfrozen logits are bit-identical (the decode is
  exact); the freeze only removes the per-call fake-quant/decode work from
  the compiled program. `freeze=False` is the A/B arm the benchmark and CI
  compare against.

- **Policy sweep** (`policy_sweep`): the same pretrained dense params pushed
  through `convert_from` at stage 0/1/2, measured for batch latency,
  throughput, and analytic per-image energy (`vit_energy_per_image`, built
  from core.energy's Tab.-1 unit energies + data-movement terms). Drives
  benchmarks/bench_vit.py → BENCH_vit.json and repro.launch.serve_vit.

**Batch-invariance contract** (ISSUE 5): MoE feeds plan expert capacity PER
IMAGE ROW (`MoEPrimitives.infer` routes one group per batch row with the
memoized per-image `capacity_plan`), so under EVERY sweep policy — shiftadd
included — an image's logits are bit-identical across batch composition,
row order, bucket padding and replica count. Tokens never compete with
another image's tokens for expert slots; the scheduler may co-batch, split
and shed requests freely with zero logit consequences. The property tier
(tests/test_batch_invariance.py) and the traffic gates
(benchmarks/check_traffic.py replay + 1-vs-N on the shiftadd arm) pin this.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp

from repro.core import energy
from repro.core.policy import DENSE, SHIFTADD, STAGE1
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.serve.metrics import latency_summary

# The default bucket set IS the benchmark/CI set: bench_vit.py,
# check_vit_freeze.py and the traffic frontend all read the effective set
# off `engine.buckets` instead of re-declaring it (the old default carried
# an extra 128 bucket no serving path compiled — records and gates drifted).
DEFAULT_BUCKETS = (1, 8, 32)


class BucketedViTEngine:
    """Pads variable-size image batches into jit-cached bucket shapes.

    model/params: a ShiftAddViT and its (possibly convert_from'd) params.
    buckets: allowed batch sizes, ascending. Requests larger than the biggest
    bucket are split into max-bucket chunks, so any request size is served.
    freeze: build a core.deploy DeployPlan at engine construction (decode
    every shift weight once, warm MoE capacity plans for the buckets) and
    close the jitted forward over the frozen params as constants — the
    deployment-freeze serving path. freeze=False serves the live params
    (the A/B arm of the freeze benchmark); logits are bit-identical.
    impl: kernel implementation the plan decodes for (default: process-wide
    `kernels.ops.default_impl()`).
    mesh: optional jax Mesh for the data-parallel serving arm. Batches are
    placed with `distributed.sharding.batch_sharding` (the `batch → data`
    logical rule), and each bucket is rounded UP to a multiple of the
    mesh's batch-axis size so every device holds an equal shard.

    The *effective* bucket set (sorted, deduplicated, mesh-rounded) is
    surfaced as `engine.buckets`; benchmark records and CI gates read it
    from here rather than re-declaring their own set.
    """

    def __init__(self, model: ShiftAddViT, params, buckets=DEFAULT_BUCKETS,
                 freeze=True, impl=None, tune=None, mesh=None):
        from repro.kernels import ops

        assert len(buckets) > 0 and min(buckets) >= 1
        self.model = model
        self.params = params
        self.mesh = mesh
        dp = 1
        if mesh is not None:
            from repro.distributed.sharding import LOGICAL_AXIS_RULES
            for ax in LOGICAL_AXIS_RULES["batch"]:
                dp *= mesh.shape.get(ax, 1)
        self._dp = dp
        self.buckets = tuple(sorted(set(
            dp * ((int(b) + dp - 1) // dp) for b in buckets)))
        self.frozen = bool(freeze)
        # The engine's impl is resolved ONCE here and threaded explicitly
        # through model.infer → blocks → kernels.ops on every call, so the
        # plan's weight format and the kernels the jitted forward runs can
        # never disagree. (The old design instead RAISED on any impl that
        # differed from ops.default_impl() — a memoized process global that
        # every impl=None call site silently inherited.)
        self.impl = impl or ops.default_impl()
        self.tune = tune
        self.trace_count = 0        # incremented only when jit (re)traces
        self.batches_served = 0
        self.images_served = 0
        self.padded_images_served = 0   # bucket slots incl. padding
        # Thread-pool replicas share one engine across workers; unguarded
        # '+=' on the counters would drop updates under concurrent infer().
        self._counter_lock = threading.Lock()

        # Donation intent, surfaced for the serving-contract audit (JX005):
        # the image buffer is NEVER donatable — (B, H, W, C) inputs cannot
        # alias the (B, n_classes) logits, so a donate_argnums on it is dead
        # weight XLA warns about ("donated buffers were not usable") and it
        # forced a defensive copy of full-bucket chunks in infer(). Keep ()
        # unless a future output actually matches an input buffer.
        self.donate_argnums = ()
        jit_kw = {}
        if mesh is not None:
            from repro.distributed import sharding as shd
            # Data-parallel arm: rows over the mesh's batch axes, logits
            # back the same way — the distributed/sharding.py batch → data
            # rule, reused verbatim by the vision serving path.
            jit_kw = dict(in_shardings=shd.batch_sharding(mesh, rank=4),
                          out_shardings=shd.batch_sharding(mesh, rank=2))
        if freeze:
            # The MoE dispatch routes one group per image row, so the only
            # token count it ever plans capacity for is the per-image patch
            # count — identical across buckets (a bucket changes how many
            # rows are vmapped over, never a row's capacity split).
            self.plan = model.prepare_inference(
                params, impl=self.impl,
                token_counts=(model.cfg.n_patches,), tune=tune)
            run_params = self.plan.params
            impl_, tune_ = self.impl, self.tune

            # Frozen params are closed over, not passed: they are constants
            # of the serving program, never retraced against. impl/tune ride
            # along as explicit closure constants — never a process global.
            def fwd(images):
                # Runs at trace time, not at execution — the compile counter
                # the no-recompilation gate asserts on.
                self.trace_count += 1  # lint: allow(LT004 trace-time compile counter, guarded by gates)
                return model.infer(run_params, images, impl=impl_,
                                   tune=tune_)

            self._fwd = fwd
            self._call = jax.jit(fwd, donate_argnums=self.donate_argnums,
                                 **jit_kw)
        else:
            self.plan = None

            # The live arm keeps the pre-freeze calling convention: params
            # are a per-call ARGUMENT, so XLA cannot constant-fold the
            # per-forward po2 decode out of the program (which would turn
            # the no-freeze benchmark arm into a de-facto frozen one), and
            # a caller that swaps engine.params serves the new weights.
            impl_, tune_ = self.impl, self.tune

            def fwd(p, images):
                self.trace_count += 1  # lint: allow(LT004 trace-time compile counter, guarded by gates)
                return model.infer(p, images, impl=impl_, tune=tune_)

            if jit_kw:
                from repro.distributed import sharding as shd
                jit_kw["in_shardings"] = (shd.replicated(mesh),
                                          jit_kw["in_shardings"])
            self._fwd = fwd
            fwd_j = jax.jit(fwd, **jit_kw)
            self._call = lambda images: fwd_j(self.params, images)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket that fits n (callers chunk to max bucket first)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def warmup(self):
        """Compile every bucket once so serving never pays a trace."""
        c = self.model.cfg
        shape = (c.image_size, c.image_size, c.in_channels)
        for b in self.buckets:
            jax.block_until_ready(
                self._call(jnp.zeros((b,) + shape, jnp.float32)))
        return self

    def infer(self, images):
        """images: (n, H, W, C), any n ≥ 1 → logits (n, n_classes).

        Chunks to the max bucket, pads each chunk up to its bucket size and
        slices the padding back off. Input dtype is canonicalized to the
        float32 warmup dtype (jit caches key on dtype — a raw uint8 client
        batch must not retrace). After warmup() this never recompiles.
        """
        images = jnp.asarray(images, jnp.float32)
        n = images.shape[0]
        if n == 0:
            return jnp.zeros((0, self.model.cfg.n_classes), jnp.float32)
        bmax = self.buckets[-1]
        outs = []
        start = 0
        while start < n:
            take = min(bmax, n - start)
            bucket = self.bucket_for(take)
            chunk = images[start:start + take]
            if take < bucket:
                pad = jnp.zeros((bucket - take,) + chunk.shape[1:], chunk.dtype)
                chunk = jnp.concatenate([chunk, pad], axis=0)
            logits = self._call(chunk)
            outs.append(logits[:take])
            with self._counter_lock:
                self.batches_served += 1
                self.padded_images_served += bucket
            start += take
        with self._counter_lock:
            self.images_served += n
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    @property
    def padding_waste(self) -> float:
        """Lifetime fraction of served bucket slots that were padding."""
        from repro.serve.metrics import padding_waste as _waste
        return _waste(self.images_served, self.padded_images_served)


# ---------------------------------------------------------------------------
# Interleaved freeze A/B (the CI gate's measurement)
# ---------------------------------------------------------------------------

def freeze_ab(base_cfg: ViTConfig = None, batch=32, iters=20, seed=0,
              policy="shiftadd"):
    """Frozen-vs-live A/B of one policy arm, interleaved in one process.

    Two engines over the SAME converted params — one serving the DeployPlan,
    one serving the live tree — timed in alternating rounds so machine-load
    drift hits both arms equally (two sequential benchmark processes on a
    shared runner can drift 20%+ between runs, swamping the ~10-20% freeze
    effect the CI gate checks). Returns the BENCH_vit_freeze_ab.json record.
    """
    base_cfg = base_cfg or ViTConfig()
    dense_model = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(seed))
    model, params = build_policy_model(base_cfg, policy, dense_model,
                                       dense_params)
    imgs = jax.random.normal(
        jax.random.PRNGKey(seed + 1),
        (batch, base_cfg.image_size, base_cfg.image_size, base_cfg.in_channels))
    engines = {
        "frozen": BucketedViTEngine(model, params, buckets=(batch,),
                                    freeze=True).warmup(),
        "live": BucketedViTEngine(model, params, buckets=(batch,),
                                  freeze=False).warmup(),
    }
    samples = {name: [] for name in engines}
    for name, eng in engines.items():
        jax.block_until_ready(eng.infer(imgs))      # post-warmup touch
    for _ in range(iters):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            jax.block_until_ready(eng.infer(imgs))
            samples[name].append(time.perf_counter() - t0)
    med = {name: sorted(ts)[len(ts) // 2] for name, ts in samples.items()}
    return {
        "backend": jax.default_backend(),
        "policy": policy,
        "image_size": base_cfg.image_size,
        "batch": batch,
        "iters": iters,
        "frozen_latency_s": med["frozen"],
        "live_latency_s": med["live"],
        "frozen_vs_live": med["frozen"] / med["live"],
        "recompiles_after_warmup": sum(
            e.trace_count - 1 for e in engines.values()),
    }


# ---------------------------------------------------------------------------
# Measured per-component serving latency (attention / MLP-MoE / dispatch)
# ---------------------------------------------------------------------------

def component_breakdown(model: ShiftAddViT, run_params, images, iters=10):
    """Wall-clock per-component breakdown of one serving forward.

    The measured twin of benchmarks/bench_breakdown.py's roofline rows:
    attention (norm1 + mixer serving path), MLP/MoE (norm2 + feed serving
    path), dispatch (MoE routing + gather dispatch + combine with identity
    experts — the pure machinery cost; a SUBSET of mlp_moe_s, not an
    additive fourth component), and other (total minus attention and
    mlp_moe: patchify/embed/final norm/head/residual glue). For MoE arms a
    `dispatch_global_s` row re-measures the LEGACY flattened-co-batch
    dispatch (group_tokens + whole-batch capacity plan) next to the served
    per-image dispatch, and `dispatch_delta_s` = per-image − global records
    what the batch-invariance refactor costs (or saves) on the hot path —
    the BENCH_vit.json trajectory row ISSUE 5 asks for. Each component is jitted
    standalone on the real activation shapes and the components are timed
    INTERLEAVED round-robin (medians over `iters` rounds), so machine-load
    drift hits every component equally — independently-timed components on a
    noisy host can otherwise sum past the separately-measured total. other_s
    is still a residual and is clamped at 0 when residual noise leaves the
    fused total below the component sum.
    """
    dt = model.mc.activation_dtype
    x0 = model.patch_embed(run_params["patch_embed"],
                           model.patchify(jnp.asarray(images)).astype(dt))

    def attn_all(x):
        for blk, p in zip(model.blocks, run_params["blocks"]):
            x = x + blk._infer_mixer(p, blk.norm1(p["norm1"], x), None)
        return x

    def feed_all(x):
        for blk, p in zip(model.blocks, run_params["blocks"]):
            x = x + blk._infer_feed(p, blk.norm2(p["norm2"], x))
        return x

    def dispatch_all(grouping):
        from repro.core.moe_primitives import MoEPrimitives

        def run(x):
            for blk, p in zip(model.blocks, run_params["blocks"]):
                if isinstance(blk.feed, MoEPrimitives):
                    x = blk.feed.dispatch_only(p["feed"], x,
                                               grouping=grouping)
            return x

        return run

    has_moe = any(hasattr(blk.feed, "dispatch_only") for blk in model.blocks)
    components = {
        "total_s": (jax.jit(lambda im: model.infer(run_params, im)), images),
        "attention_s": (jax.jit(attn_all), x0),
        "mlp_moe_s": (jax.jit(feed_all), x0),
    }
    if has_moe:
        components["dispatch_s"] = (jax.jit(dispatch_all("image")), x0)
        components["dispatch_global_s"] = (jax.jit(dispatch_all("flat")), x0)
    samples = {name: [] for name in components}
    for name, (f, arg) in components.items():
        jax.block_until_ready(f(arg))                    # compile
    for _ in range(iters):
        for name, (f, arg) in components.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(arg))
            samples[name].append(time.perf_counter() - t0)
    out = {name: sorted(ts)[len(ts) // 2] for name, ts in samples.items()}
    out.setdefault("dispatch_s", 0.0)
    out.setdefault("dispatch_global_s", 0.0)
    out["dispatch_delta_s"] = out["dispatch_s"] - out["dispatch_global_s"]
    out["other_s"] = max(out["total_s"] - out["attention_s"]
                         - out["mlp_moe_s"], 0.0)
    return out


# ---------------------------------------------------------------------------
# Analytic per-image energy under a policy (paper Tab. 1 / Tab. 3 view)
# ---------------------------------------------------------------------------

def vit_energy_per_image(cfg: ViTConfig) -> dict:
    """Forward energy of one image under cfg.policy, in pJ.

    Composes core.energy's per-op models (45 nm unit energies + DRAM
    movement) over the actual architecture: patch embed, q/k/v/o projections
    (dense vs shift), attention contractions (quadratic softmax vs the
    linear/binary-linear Q(KᵀV) order), and MLPs (dense, shift, or the MoE —
    whose token split follows the same inverse-latency capacity weights the
    dispatcher uses).
    """
    p = cfg.policy
    n, d, f, h = cfg.n_patches, cfg.d_model, cfg.d_ff, cfg.n_heads
    dh = d // h
    total = energy.matmul_energy(n, cfg.patch_size ** 2 * cfg.in_channels, d,
                                 "fp16")                       # patch embed
    if p.projections == "shift":
        proj = energy.shift_matmul_energy
    else:
        proj = lambda m, k, nn: energy.matmul_energy(m, k, nn, "fp16")
    if p.mlp == "moe_primitives":
        # Same per-image token count and normalization the dispatcher's
        # capacity split uses (MoEPrimitives.latencies_at at the serving
        # group size — one image row), so the modeled Mult/Shift token split
        # matches the one served.
        moe_w = energy.inverse_latency_weights(energy.expert_latencies(
            n, d, f, p.moe_experts))
    for _ in range(cfg.n_layers):
        for _ in range(4):                                     # q, k, v, o
            total += proj(n, d, d)
        for _ in range(h):
            if p.attention == "binary_linear":
                total += energy.add_matmul_energy(dh, n, dh)   # KᵀV (MatAdd)
                total += energy.add_matmul_energy(n, dh, dh)   # Q(KᵀV)
            elif p.attention == "linear":
                total += energy.matmul_energy(dh, n, dh, "fp16")
                total += energy.matmul_energy(n, dh, dh, "fp16")
            else:
                total += energy.matmul_energy(n, dh, n, "fp16")  # QKᵀ
                total += energy.matmul_energy(n, n, dh, "fp16")  # AV
        if p.mlp == "moe_primitives":
            for kind, w in zip(p.moe_experts, moe_w):
                t = max(1, round(n * w))
                op = (energy.shift_matmul_energy if kind == "shift"
                      else lambda m, k, nn: energy.matmul_energy(m, k, nn, "fp16"))
                total += op(t, d, f)
                total += op(t, f, d)
        elif p.mlp == "shift":
            total += energy.shift_matmul_energy(n, d, f)
            total += energy.shift_matmul_energy(n, f, d)
        else:
            total += energy.matmul_energy(n, d, f, "fp16")
            total += energy.matmul_energy(n, f, d, "fp16")
    total += energy.matmul_energy(1, d, cfg.n_classes, "fp16")  # pooled head
    return {"total_pj": total.total_pj, "compute_pj": total.compute_pj,
            "dram_pj": total.dram_pj}


# ---------------------------------------------------------------------------
# Policy sweep: same pretrained dense weights, stage 0 / 1 / 2
# ---------------------------------------------------------------------------

SWEEP_POLICIES = {
    # name → (policy, convert_from stage)
    "dense": (DENSE, 0),
    "stage1": (STAGE1, 1),
    "shiftadd": (SHIFTADD, 2),
}


def build_policy_model(base_cfg: ViTConfig, name: str,
                       dense_model: ShiftAddViT, dense_params):
    """A (model, params) pair for one sweep arm: the base config re-policied
    and the pretrained dense params pushed through the paper's conversion."""
    policy, stage = SWEEP_POLICIES[name]
    cfg = dataclasses.replace(base_cfg, policy=policy)
    model = ShiftAddViT(cfg)
    params = model.convert_from(dense_model, dense_params, stage=stage)
    return model, params


def policy_sweep(base_cfg: ViTConfig = None, batch=32, iters=10,
                 buckets=None, seed=0, policies=tuple(SWEEP_POLICIES),
                 freeze=True, impl=None, tune=None, breakdown=False):
    """Measure every policy arm on the same pretrained dense weights.

    Returns the BENCH_vit.json record: per-policy batch latency (median over
    `iters` post-warmup runs), throughput, analytic energy per image, and
    the engine's compile count. freeze selects the
    deployment-freeze arm (DeployPlan closed over by the jitted forward) vs
    the live-params arm; the record carries `frozen` and the
    shiftadd-vs-dense latency ratio so the crossover is tracked across PRs.
    impl/tune thread explicitly to every engine (never via a process
    default); each policy arm also reports PER-BUCKET latency summaries
    (`bucket_latency`) — the per-bucket series check_vit_pallas.py gates
    pallas <= xla on.
    """
    base_cfg = base_cfg or ViTConfig()
    buckets = tuple(buckets) if buckets else DEFAULT_BUCKETS
    if batch not in buckets:
        buckets = tuple(sorted(set(buckets) | {batch}))
    dense_model = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(seed))
    imgs = jax.random.normal(
        jax.random.PRNGKey(seed + 1),
        (batch, base_cfg.image_size, base_cfg.image_size, base_cfg.in_channels))

    from repro.kernels import ops
    record = {
        "backend": jax.default_backend(),
        "model": (f"shiftadd_vit({base_cfg.n_layers}L,{base_cfg.d_model}d,"
                  f"{base_cfg.n_patches}p)"),
        "image_size": base_cfg.image_size,
        "batch": batch,
        "iters": iters,
        "frozen": bool(freeze),
        "impl": impl or ops.default_impl(),
        "tuned": tune is not None,
        "tune_meta": dict(getattr(tune, "meta", ()) or ()) or None,
        "policies": {},
    }
    for name in policies:
        model, params = build_policy_model(base_cfg, name, dense_model,
                                           dense_params)
        engine = BucketedViTEngine(model, params, buckets=buckets,
                                   freeze=freeze, impl=impl,
                                   tune=tune).warmup()
        # The effective bucket set comes off the engine — records and the
        # CI gate must never re-declare it (the old drift: DEFAULT_BUCKETS
        # advertised a 128 bucket the benchmark path never compiled).
        record.setdefault("buckets", list(engine.buckets))
        traces_after_warmup = engine.trace_count
        jax.block_until_ready(engine.infer(imgs))   # bucket already compiled
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(engine.infer(imgs))
            times.append(time.perf_counter() - t0)
        # Median, not mean: per-batch wall clock on shared CI machines has
        # heavy right-tail noise and the crossover ratio gates CI.
        latency_s = sorted(times)[len(times) // 2]
        # Per-bucket series: the granularity check_vit_pallas.py gates
        # pallas <= xla at. Buckets above the benchmark batch have no full
        # batch to feed and are skipped (never silently zero-filled).
        bucket_latency = {}
        for bkt in engine.buckets:
            if bkt > batch:
                continue
            sub = imgs[:bkt]
            jax.block_until_ready(engine.infer(sub))    # already compiled
            bts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(engine.infer(sub))
                bts.append(time.perf_counter() - t0)
            bucket_latency[str(bkt)] = latency_summary(bts)
        e = vit_energy_per_image(model.cfg)
        record["policies"][name] = {
            "latency_s_per_batch": latency_s,
            "images_per_s": batch / latency_s,
            # Same summary schema as BENCH_traffic.json (serve.metrics):
            # here the samples are per-batch sweep latencies.
            "latency": latency_summary(times),
            "bucket_latency": bucket_latency,
            "buckets": list(engine.buckets),
            "padding_waste": engine.padding_waste,
            "energy_pj_per_image": e["total_pj"],
            "energy_compute_pj": e["compute_pj"],
            "energy_dram_pj": e["dram_pj"],
            "frozen": bool(freeze),
            "compiles": engine.trace_count,
            "recompiles_after_warmup": engine.trace_count - traces_after_warmup,
        }
        if breakdown:
            run_params = engine.plan.params if engine.plan is not None else params
            record["policies"][name]["breakdown"] = component_breakdown(
                model, run_params, imgs, iters=iters)
    dense_rec = record["policies"].get("dense", {})
    dense_e = dense_rec.get("energy_pj_per_image")
    dense_lat = dense_rec.get("latency_s_per_batch")
    if dense_e:
        for name, rec in record["policies"].items():
            rec["energy_vs_dense"] = rec["energy_pj_per_image"] / dense_e
            rec["latency_vs_dense"] = rec["latency_s_per_batch"] / dense_lat
    if "shiftadd" in record["policies"] and dense_lat:
        # The paper's headline crossover, tracked per PR (≤ 1.0 means the
        # reparameterized serving path beats dense at serve time).
        record["shiftadd_vs_dense_latency"] = (
            record["policies"]["shiftadd"]["latency_s_per_batch"] / dense_lat)
    return record
