"""BucketedLMEngine: token-level continuous batching for LM decode.

The decode state is a packed *slot array*: one fixed-shape cache pytree of
`n_slots` rows (recurrent carries, conv windows, KV/latent rows — every leaf
carries the batch axis, including the per-slot positions, see
nn.attention/nn.recurrent init_cache) plus the current last token per slot
and a host-side alive mask. Because the linear-attention carry is a fixed
(d_k × d_v) block and the positions are per-row, admitting or evicting one
request is a single-axis gather/scatter over the pytree — requests join a
*running* decode batch at chunk boundaries instead of waiting for it to
drain (ROADMAP item 2; the chunk-vs-recurrent duality flash-linear-attention
exposes makes the prefill→slot handoff one O(P) pass).

Mirrors serve.vision.BucketedViTEngine: a fixed set of jitted, donated,
bucket-shaped programs compiled once by `warmup()` and keyed by a
`trace_count` compile counter the no-recompilation gates assert on:

- one lengths-masked prefill per *prompt-length bucket* (batch=1: the prompt
  is padded up to the bucket; `lengths` keeps the padding out of the carry),
- ONE decode-chunk program — a `lax.scan` of `chunk` greedy decode steps
  over all slots at once,
- one admit scatter, one evict scatter (reset a slot to its fresh-cache
  row), and the fresh-row/fresh-batch cache initializers.

Decode is greedy (argmax) — the per-request bit-identical replay and
batch-1-vs-packed oracle gates (benchmarks/check_lm_traffic.py) are
statements about deterministic programs. Every per-slot computation in
decode_step is row-wise (the MoE feed is batch-grouped but drop-free at
generous capacity — see serve.decode's MoE note), so a request's logits are
bit-identical no matter who it shares the batch with or when its neighbors
are admitted/evicted — the property tier in tests/test_lm_continuous.py
pins exactly that. Slot *position* is pinned too at the gated geometries,
but is the one axis XLA does not guarantee universally: some batch shapes
compile per-row-position reduction variants (ULP-level; observed at
n_slots=2 on CPU), which is why the serial oracle holds the slot fixed.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_PROMPT_BUCKETS = (8, 16, 32)
DEFAULT_CHUNK = 8


def _batch_axes(model, max_len):
    """Per-leaf batch-axis pytree for the decode cache.

    Found structurally: the one axis whose extent differs between
    init_cache(2) and init_cache(1). "layers" leaves carry a leading
    n_cycles stacking axis, so the batch axis is not a fixed position.
    """
    two = jax.eval_shape(lambda: model.init_cache(2, max_len))
    one = jax.eval_shape(lambda: model.init_cache(1, max_len))

    def axis(a, b):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cache leaf {a.shape} vs {b.shape}: expected exactly one "
                "batch axis — is some leaf still batch-less?")
        return diff[0]

    return jax.tree_util.tree_map(axis, two, one)


class BucketedLMEngine:
    """Continuous-batching LM decode over a packed slot array.

    Host-visible state: `tokens` (n_slots,) current last token per slot,
    `cache` the packed pytree, `alive`/`slot_rid` host-side masks. All
    device mutation goes through the jitted programs below; the scheduler
    (serve.scheduler.SlotScheduler) decides *which* request a free slot
    gets, the frontend (serve.frontend.serve_lm_trace) decides *when*.
    """

    def __init__(self, model, params, *, n_slots=4,
                 prompt_buckets=DEFAULT_PROMPT_BUCKETS, chunk=DEFAULT_CHUNK,
                 max_len=None):
        assert n_slots >= 1 and chunk >= 1
        assert len(prompt_buckets) > 0 and min(prompt_buckets) >= 1
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.chunk = int(chunk)
        self.prompt_buckets = tuple(sorted(set(int(b) for b in prompt_buckets)))
        self.max_len = int(max_len or (self.prompt_buckets[-1] + 128))
        if self.max_len < self.prompt_buckets[-1]:
            raise ValueError("max_len must cover the largest prompt bucket")
        self._axes = _batch_axes(model, self.max_len)

        self.trace_count = 0          # every jit (re)trace, all programs
        self.prefill_trace_count = 0  # bucket-shaped prefill traces only
        self._counter_lock = threading.Lock()

        # Host-side slot lifecycle (the device never sees "alive": dead rows
        # hold the fresh zero cache and compute harmless garbage — decode is
        # row-wise, so they cannot perturb live rows).
        self.alive = [False] * self.n_slots
        self.slot_rid = [None] * self.n_slots

        def _count(prefill=False):
            # Runs at trace time, not execution — the compile counter the
            # recompiles-after-warmup gate asserts on.
            with self._counter_lock:
                self.trace_count += 1  # lint: allow(LT004 trace-time compile counter, guarded by gates)
                if prefill:
                    self.prefill_trace_count += 1  # lint: allow(LT004 trace-time compile counter, guarded by gates)

        mdl = model
        S, K, L = self.n_slots, self.chunk, self.max_len
        axes = self._axes

        def init_row():
            _count()
            return mdl.init_cache(1, L)

        def init_batch():
            _count()
            return mdl.init_cache(S, L)

        def prefill(p, toks, length, row):
            _count(prefill=True)
            logits, row = mdl.prefill(p, toks, row, last_only=True,
                                      lengths=length)
            logits = logits[:, 0]                       # (1, V)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return first, logits, row

        def decode_chunk(p, toks, cache):
            _count()

            def step(carry, _):
                t, c = carry
                logits, c = mdl.decode_step(p, t, c)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (nxt, c), (nxt, logits)

            (t, cache), (toks_seq, logits_seq) = jax.lax.scan(
                step, (toks, cache), None, length=K)
            return t, cache, toks_seq, logits_seq      # (K,S), (K,S,V)

        def admit(cache, toks, row, first, slot):
            _count()

            def put(leaf, r, ax):
                return jax.lax.dynamic_update_slice_in_dim(
                    leaf, r.astype(leaf.dtype), slot, axis=ax)

            cache = jax.tree_util.tree_map(put, cache, row, axes)
            toks = jax.lax.dynamic_update_slice(toks, first, (slot,))
            return cache, toks

        def evict(cache, slot):
            _count()
            fresh = mdl.init_cache(1, L)

            def put(leaf, r, ax):
                return jax.lax.dynamic_update_slice_in_dim(
                    leaf, r.astype(leaf.dtype), slot, axis=ax)

            return jax.tree_util.tree_map(put, cache, fresh, axes)

        # Cache pytrees are donated everywhere they are consumed (the linear
        # prefill *accumulates* into its donated row — JX005-consumable).
        self.donate_argnums = {"prefill": (3,), "decode": (1, 2),
                               "admit": (0, 1), "evict": (0,)}
        self._init_row = jax.jit(init_row)
        self._init_batch = jax.jit(init_batch)
        self._prefill = jax.jit(prefill, donate_argnums=(3,))
        self._decode = jax.jit(decode_chunk, donate_argnums=(1, 2))
        self._admit = jax.jit(admit, donate_argnums=(0, 1))
        self._evict = jax.jit(evict, donate_argnums=(0,))
        # Raw traced fns, surfaced for the serving-contract jaxpr audit.
        self.programs = {"prefill": prefill, "decode_chunk": decode_chunk,
                         "admit": admit, "evict": evict}

        self.cache = self._init_batch()
        self.tokens = jnp.zeros((S,), jnp.int32)

    # -- shape bookkeeping ---------------------------------------------------
    @property
    def expected_programs(self) -> int:
        """Program count after warmup: one prefill per prompt bucket plus
        decode_chunk, admit, evict, and the two cache initializers."""
        return len(self.prompt_buckets) + 5

    def bucket_for(self, n: int) -> int:
        """Smallest prompt bucket that fits n (oversize prompts are clipped
        to the largest bucket by admit — context-window semantics)."""
        for b in self.prompt_buckets:
            if n <= b:
                return b
        return self.prompt_buckets[-1]

    def warmup(self):
        """Compile every program once so serving never pays a trace."""
        for b in self.prompt_buckets:
            row = self._init_row()
            toks = jnp.zeros((1, b), jnp.int32)
            first, logits, row_cache = self._prefill(
                self.params, toks, jnp.asarray([b], jnp.int32), row)
        self.cache, self.tokens = self._admit(
            self.cache, self.tokens, row_cache, first,
            jnp.asarray(0, jnp.int32))
        out = self._decode(self.params, self.tokens, self.cache)
        self.cache = self._evict(out[1], jnp.asarray(0, jnp.int32))
        jax.block_until_ready(self.cache)
        self.reset()
        return self

    def reset(self):
        """Fresh slot array (no new programs — reuses the jitted init)."""
        self.cache = self._init_batch()
        self.tokens = jnp.zeros((self.n_slots,), jnp.int32)
        self.alive = [False] * self.n_slots
        self.slot_rid = [None] * self.n_slots
        return self

    def free_slots(self):
        return [i for i, a in enumerate(self.alive) if not a]

    # -- slot lifecycle ------------------------------------------------------
    def admit(self, slot, prompt, rid=None):
        """Prefill `prompt` (1D int tokens) and scatter the resulting cache
        row + first generated token into `slot` of the running batch.

        Returns (first_token int, first_logits (V,) np.ndarray) — the greedy
        argmax over the prompt's last real position and the distribution it
        came from (the first row of the request's logit stream).
        """
        assert not self.alive[slot], f"slot {slot} is occupied"
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        bmax = self.prompt_buckets[-1]
        if prompt.shape[0] > bmax:
            prompt = prompt[-bmax:]          # clip to the context window
        n = prompt.shape[0]
        bucket = self.bucket_for(n)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = prompt
        row = self._init_row()
        first, logits, row_cache = self._prefill(
            self.params, jnp.asarray(padded), jnp.asarray([n], jnp.int32), row)
        self.cache, self.tokens = self._admit(
            self.cache, self.tokens, row_cache, first,
            jnp.asarray(slot, jnp.int32))
        self.alive[slot] = True
        self.slot_rid[slot] = rid
        return int(first[0]), np.asarray(logits[0])

    def evict(self, slot):
        """Scatter the fresh-cache row back into `slot` (jitted; the next
        admit fully overwrites it anyway, but a clean row keeps dead-slot
        compute finite and the state replay-exact)."""
        self.cache = self._evict(self.cache, jnp.asarray(slot, jnp.int32))
        self.alive[slot] = False
        self.slot_rid[slot] = None

    def decode_chunk(self):
        """Advance every slot by `chunk` greedy tokens (dead slots compute
        garbage that never leaves their row). Returns (tokens (K, S) int32,
        logits (K, S, V) float32) as host arrays."""
        self.tokens, self.cache, toks_seq, logits_seq = self._decode(
            self.params, self.tokens, self.cache)
        return np.asarray(toks_seq), np.asarray(logits_seq)
