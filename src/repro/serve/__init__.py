from repro.serve.decode import make_serve_step, make_prefill_step, generate
