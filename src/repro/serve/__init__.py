from repro.serve.decode import (generate, make_decode_loop, make_prefill,
                                make_prefill_step, make_serve_step)
from repro.serve.vision import (BucketedViTEngine, component_breakdown,
                                policy_sweep, vit_energy_per_image)
