from repro.serve.decode import (generate, make_decode_loop, make_prefill,
                                make_prefill_step, make_serve_step)
from repro.serve.frontend import (TrafficResult, calibrate_service_model,
                                  calibrate_service_models, serve_trace,
                                  traffic_sweep)
from repro.serve.metrics import latency_summary, padding_waste
from repro.serve.replicas import (DataParallelReplicas, ThreadPoolReplicas,
                                  make_replicas)
from repro.serve.scheduler import Batch, MicroBatchScheduler, Part
from repro.serve.traffic import (DEADLINE_CLASSES, SCENARIOS, Request, Trace,
                                 default_budgets, make_trace)
from repro.serve.vision import (BucketedViTEngine, component_breakdown,
                                policy_sweep, vit_energy_per_image)
