"""Elastic serving control plane: autoscaling, failure injection, and
graceful degradation between the virtual-clock frontend and the replica
pools (ROADMAP item 4).

Three mechanisms, one determinism contract:

- **Warm pool + autoscaler.** `ElasticWarmPool` builds `max_replicas +
  spares` fully isolated `BucketedViTEngine`s up front and `warmup()`
  compiles every bucket program on every one of them — attached or parked.
  Scaling is then pure membership: `attach()` moves a parked, already-warm
  engine into the active set, `detach()` parks it again, and a scale event
  can never trace a program. The pool-wide `trace_count` sums over ALL
  reserve engines (parked, active, and dead alike), so the
  zero-recompiles-after-warmup gate extends across every scale-up,
  scale-down, and failure-recovery event of the whole elastic run. The
  `Autoscaler` samples queue backlog (seconds of single-replica work) and
  deadline slack (time until the most urgent queued part forces a dispatch)
  at every scheduler tick of the virtual clock and grows/shrinks the active
  set under cooldowns — a pure function of virtual state, so the same
  seeded trace always produces the same scaling timeline.

- **Failure injection + recovery.** `distributed.fault_tolerance` supplies
  the fault plan: `ReplicaFault("kill" | "slowdown")` events fire at chosen
  virtual-clock times through `FailureInjector.due()`. A kill removes the
  replica mid-trace, requeues its in-flight micro-batch at the head of its
  class queues (`MicroBatchScheduler.requeue` — the retry is a pure
  function of virtual state, and batch-invariant logits make it
  bit-identical), and the autoscaler re-admits capacity from the warm pool
  (`n_active < min_replicas` backfills immediately, bypassing cooldown). A
  slowdown multiplies the replica's virtual service time; completions feed
  `StragglerMonitor` with actual/nominal service ratios (1.0 for healthy
  batches, so mixed buckets can't skew the median), and a flagged replica
  is quarantined — killed and backfilled from the warm pool — which is
  exactly "straggler detection feeds the autoscaler signal".

- **Graceful degradation.** When the primary (dense) pool is saturated —
  active at `max_replicas` with no parked engine left to attach — the
  admission path sheds load to a cheaper policy arm (the shiftadd
  mixture-of-primitives model served from its own warm pool) instead of
  dropping requests: a deterministic ladder degrades deadline classes in
  `DegradePolicy.order` as backlog grows (`"ladder"`), and a request the
  primary admission bound would shed is rerouted whole (`"overflow"`).
  Every decision is recorded per request (arm + reason) and folded into
  `ElasticResult.elastic_signature()`, so replay stays bit-identical
  including degradation decisions — and because both arms derive from the
  same pretrained dense weights, a degraded request still gets real logits,
  just from the cheaper primitives.

Determinism model is unchanged from serve.frontend: engine execution is
REAL, scheduling time is VIRTUAL (calibrated service models), and the
batch-invariance contract means none of this — scaling, killing,
requeueing, degrading — can move a logit; it can only move latency.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import (FailureInjector, ReplicaFault,
                                               StragglerMonitor)
from repro.serve.metrics import latency_summary, padding_waste
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.traffic import DEADLINE_CLASSES, Trace
from repro.serve.vision import DEFAULT_BUCKETS, BucketedViTEngine

_INF = float("inf")

PARKED, ACTIVE, DEAD = "parked", "active", "dead"


class ElasticWarmPool:
    """Warm reserve of isolated ViT engines with attach/detach/kill.

    Unlike ThreadPoolReplicas' shared-engine default, every replica here
    owns its engine (the shape a real multi-host pool takes): killing one
    cannot touch another's programs, and a parked spare is a complete,
    already-compiled server. Slots are engine ids (0..reserve-1), stable
    for the pool's lifetime; `active` is the sorted id list the frontend
    dispatches over.

    arm="thread": all engines on the default device, concurrency via one
    executor sized to the full reserve. arm="sharded": engine i is pinned
    to device i (mod device count) through a one-device `("data",)` mesh —
    scale-up attaches another device's pre-compiled engine.
    """

    def __init__(self, model, params, *, max_replicas=2, spares=1,
                 buckets=DEFAULT_BUCKETS, freeze=True, impl=None, tune=None,
                 arm="thread", devices=None):
        assert max_replicas >= 1 and spares >= 0
        assert arm in ("thread", "sharded"), arm
        self.arm = arm
        self.max_replicas = int(max_replicas)
        self.spares = int(spares)
        self.reserve = self.max_replicas + self.spares
        meshes = [None] * self.reserve
        if arm == "sharded":
            from repro.distributed.sharding import make_mesh
            devices = list(devices if devices is not None else jax.devices())
            meshes = [make_mesh((1,), ("data",),
                                devices=[devices[i % len(devices)]])
                      for i in range(self.reserve)]
        self.engines = [BucketedViTEngine(model, params, buckets=buckets,
                                          freeze=freeze, impl=impl, tune=tune,
                                          mesh=meshes[i])
                        for i in range(self.reserve)]
        self.state = [PARKED] * self.reserve
        self.active = []                     # sorted engine ids
        self.speed_factor = [1.0] * self.reserve
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.reserve, thread_name_prefix="vit-elastic")
        self._closed = False

    # -- warm-pool invariants -----------------------------------------------

    @property
    def buckets(self):
        return self.engines[0].buckets

    @property
    def trace_count(self) -> int:
        """Compiles across the WHOLE reserve — parked and dead engines
        included, so a compile anywhere trips the elastic gate."""
        return sum(e.trace_count for e in self.engines)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_parked(self) -> int:
        return sum(1 for s in self.state if s == PARKED)

    def warmup(self):
        """Compile every bucket on every reserve engine — the whole point:
        after this, no attach/detach/kill/recovery can ever trace."""
        for e in self.engines:
            e.warmup()
        return self

    # -- membership (the control plane's verbs) ------------------------------

    def attach(self):
        """Activate the lowest-id parked engine (None when exhausted or the
        active set is at max_replicas). Zero compiles — it is already warm."""
        if self.n_active >= self.max_replicas:
            return None
        for i, s in enumerate(self.state):
            if s == PARKED:
                self.state[i] = ACTIVE
                self.active.append(i)
                self.active.sort()
                return i
        return None

    def detach(self, slot: int):
        """Park an active engine (scale-down). It stays warm."""
        assert self.state[slot] == ACTIVE, (slot, self.state[slot])
        self.state[slot] = PARKED
        self.active.remove(slot)

    def kill(self, slot: int):
        """Remove an active engine permanently (failure / quarantine)."""
        assert self.state[slot] == ACTIVE, (slot, self.state[slot])
        self.state[slot] = DEAD
        self.active.remove(slot)

    def reset_membership(self):
        """Park everything and heal the dead — the replay/baseline harness
        hook. Engines persist (still warm, still counted by trace_count);
        only the control-plane state resets."""
        self.state = [PARKED] * self.reserve
        self.active = []
        self.speed_factor = [1.0] * self.reserve
        return self

    # -- execution -----------------------------------------------------------

    def submit(self, slot: int, images) -> concurrent.futures.Future:
        """Future resolving to (logits, measured wall seconds)."""
        if self._closed:
            raise RuntimeError("submit() on a closed ElasticWarmPool")
        assert self.state[slot] == ACTIVE, (slot, self.state[slot])
        engine = self.engines[slot]

        def run():
            t0 = time.perf_counter()
            logits = jax.block_until_ready(engine.infer(images))
            return logits, time.perf_counter() - t0

        return self._pool.submit(run)

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Autoscaler: queue-depth + deadline-slack policy under cooldowns
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutoscalerPolicy:
    """Thresholds in calibrated seconds (derive them from the measured
    max-bucket service time — `default_autoscaler_policy` — so the policy
    means the same thing on every host)."""
    min_replicas: int = 1
    max_replicas: int = 2
    up_backlog_s: float = 0.0      # per-active-replica queued work (s)
    slack_up_s: float = 0.0        # urgency: time-to-forced-dispatch floor
    up_cooldown_s: float = 0.0
    down_backlog_s: float = 0.0    # total queued work under which to shrink
    down_cooldown_s: float = 0.0


def default_autoscaler_policy(svc_max_s: float, *, min_replicas=1,
                              max_replicas=2) -> AutoscalerPolicy:
    """Scale up when a replica's share of the backlog exceeds one max-bucket
    service time (or the most urgent queued part is within two service times
    of being forced out with every replica busy); scale down once the queue
    is nearly dry, with a 4× longer cooldown so the pool doesn't flap."""
    s = float(svc_max_s)
    return AutoscalerPolicy(min_replicas=int(min_replicas),
                            max_replicas=int(max_replicas),
                            up_backlog_s=1.0 * s, slack_up_s=2.0 * s,
                            up_cooldown_s=1.0 * s,
                            down_backlog_s=0.25 * s,
                            down_cooldown_s=4.0 * s)


class Autoscaler:
    """Mutable cooldown state around a frozen policy. decide() is pure in
    (inputs, cooldown state); the serve loop owns applying the decision."""

    def __init__(self, policy: AutoscalerPolicy):
        self.policy = policy
        self.last_up_s = -_INF
        self.last_down_s = -_INF

    def decide(self, now: float, *, n_active: int, n_idle: int,
               backlog_s: float, until_forced_s=None) -> int:
        """+1 grow, -1 shrink, 0 hold. n_active < min_replicas always grows
        (failure backfill — recovery is not thrash, so no cooldown)."""
        p = self.policy
        if n_active < p.min_replicas:
            return +1
        urgent = (until_forced_s is not None and n_idle == 0
                  and until_forced_s < p.slack_up_s)
        if ((backlog_s / max(n_active, 1) > p.up_backlog_s or urgent)
                and n_active < p.max_replicas
                and now - self.last_up_s >= p.up_cooldown_s):
            return +1
        if (backlog_s <= p.down_backlog_s and n_idle > 0
                and n_active > p.min_replicas
                and now - self.last_down_s >= p.down_cooldown_s
                and now - self.last_up_s >= p.down_cooldown_s):
            return -1
        return 0


# ---------------------------------------------------------------------------
# Graceful degradation: dense → shiftadd ladder per deadline class
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Deterministic shed-to-cheaper-arm ladder, applied at admission when
    the primary pool is *saturated* (at max_replicas with no spare to
    attach). Classes in `order` degrade cumulatively: the first
    `min_backlog_s` seconds of backlog degrade order[0], every further
    `step_backlog_s` adds the next class. Laxest-first by default — an
    interactive request keeps the premium arm until the backlog says
    otherwise."""
    order: tuple = ("relaxed", "standard", "interactive")
    min_backlog_s: float = 0.0
    step_backlog_s: float = _INF


def degrade_level(policy: DegradePolicy, *, saturated: bool,
                  backlog_s: float) -> int:
    """How many classes of `policy.order` currently shed to the cheap arm —
    a pure function of (saturation, backlog), hence replayable."""
    if not saturated or backlog_s <= policy.min_backlog_s:
        return 0
    extra = backlog_s - policy.min_backlog_s
    return min(1 + int(extra // policy.step_backlog_s), len(policy.order))


@dataclasses.dataclass
class DegradeArm:
    """The cheap arm: its own warm pool (shiftadd weights), its own
    scheduler over its own calibrated service model, one shared virtual
    clock with the primary. The arm is static — the autoscaler governs the
    primary; this is the pressure-relief valve."""
    pool: ElasticWarmPool
    scheduler: MicroBatchScheduler
    policy: DegradePolicy
    image_fn: object = None


# ---------------------------------------------------------------------------
# The elastic event loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticResult:
    report: dict                 # the BENCH_elastic.json arm record
    requests: list               # per-request dicts (rid order, shed incl.)
    logits: dict                 # rid → np.ndarray (size, n_classes)
    batches: list                # dispatch log across BOTH arms
    events: dict                 # {"scale": [...], "faults": [...],
                                 #  "degraded": [...]}

    def routing_signature(self):
        return tuple(
            (b["arm"], round(b["formed_s"], 9), b["slot"], b["bucket"],
             b["reason"], bool(b["killed"]), tuple(b["parts"]))
            for b in self.batches)

    def elastic_signature(self):
        """Routing + scaling timeline + fault firings + degradation
        decisions: the full control-plane history replay must reproduce."""
        return (self.routing_signature(),
                tuple(self.events["scale"]),
                tuple(self.events["faults"]),
                tuple(self.events["degraded"]))


def serve_elastic_trace(pool: ElasticWarmPool,
                        scheduler: MicroBatchScheduler, trace: Trace, *,
                        policy: AutoscalerPolicy, faults=(),
                        degrade: DegradeArm = None,
                        straggler_threshold=2.0, straggler_window=32,
                        image_fn=None,
                        collect_logits=True) -> ElasticResult:
    """serve.frontend.serve_trace with membership dynamics: the active slot
    set changes under the autoscaler and the fault plan, and a DegradeArm
    absorbs what a saturated primary would shed. All control decisions are
    functions of virtual state only; see the module docstring."""
    from repro.serve.frontend import default_image_fn

    if image_fn is None:
        image_fn = default_image_fn(pool.engines[0].model.cfg)
    if degrade is not None and degrade.image_fn is None:
        degrade.image_fn = default_image_fn(
            degrade.pool.engines[0].model.cfg)
    injector = FailureInjector(faults=tuple(faults))
    scaler = Autoscaler(policy)
    monitor = StragglerMonitor(threshold=straggler_threshold,
                               window=straggler_window)

    svc = scheduler.service_model_s
    bmax = pool.buckets[-1]
    svc_max = svc[bmax]

    arms = {"primary": (pool, scheduler, image_fn, svc)}
    if degrade is not None:
        arms["degraded"] = (degrade.pool, degrade.scheduler,
                            degrade.image_fn,
                            degrade.scheduler.service_model_s)

    pools_total = lambda: sum(a[0].trace_count for a in arms.values())
    traces_at_start = pools_total()

    # Initial membership: primary at min_replicas, degrade arm fully on.
    free_at = {name: {} for name in arms}
    scale_log = []
    while pool.n_active < policy.min_replicas:
        s = pool.attach()
        if s is None:
            raise RuntimeError("warm pool smaller than min_replicas")
        free_at["primary"][s] = 0.0
        scale_log.append(("init", 0.0, s))
    if degrade is not None:
        while True:
            s = degrade.pool.attach()
            if s is None:
                break
            free_at["degraded"][s] = 0.0

    arrivals = list(trace.requests)
    ai = 0
    now = 0.0
    inflight = []                # mutable dicts; see dispatch()
    cur = {}                     # (arm, slot) → in-flight entry
    unprocessed = []             # completions the straggler monitor hasn't seen
    batches_log = []
    shed, arm_of, degrade_reason = {}, {}, {}
    fault_log, degraded_log = [], []
    timeline = [(0.0, pool.n_active)]
    kills = straggler_evictions = recoveries = scale_ups = scale_downs = 0
    completion_seq = 0

    def backlog_s():
        """Queued primary work in single-replica seconds at max-bucket
        rate — the autoscaler's and the ladder's shared pressure signal."""
        return scheduler.queued_images * svc_max / bmax

    def saturated():
        return not (pool.n_active < policy.max_replicas
                    and pool.n_parked > 0)

    def mark(t):
        timeline.append((t, pool.n_active))

    def kill_slot(slot, t, *, why):
        nonlocal kills, straggler_evictions
        entry = cur.get(("primary", slot))
        if entry is not None and entry["done_s"] > t and not entry["killed"]:
            entry["killed"] = True
            scheduler.requeue(entry["batch"].parts)
        pool.kill(slot)
        free_at["primary"].pop(slot, None)
        cur.pop(("primary", slot), None)
        if why == "kill":
            kills += 1
        else:
            straggler_evictions += 1
        fault_log.append((why, round(t, 9), slot))
        mark(t)

    def apply_fault(f: ReplicaFault, t):
        act = pool.active
        if not act:
            fault_log.append((f.kind + "_skipped", round(t, 9), -1))
            return
        victim = act[f.slot % len(act)]
        if f.kind == "kill":
            kill_slot(victim, t, why="kill")
        else:
            pool.speed_factor[victim] = float(f.factor)
            fault_log.append(("slowdown", round(t, 9), victim, f.factor))

    def process_completions(t):
        """Feed finished primary batches to the straggler monitor in
        completion order; quarantine flagged replicas (kill + backfill via
        the autoscaler — the detector feeding the scaling signal)."""
        nonlocal completion_seq
        due = [e for e in unprocessed if e["done_s"] <= t]
        if not due:
            return
        due.sort(key=lambda e: (e["done_s"], e["slot"]))
        for e in due:
            unprocessed.remove(e)
            if e["killed"] or e["arm"] != "primary":
                continue
            completion_seq += 1
            ratio = (e["done_s"] - e["dispatch_s"]) / svc[e["batch"].bucket]
            if (monitor.record(completion_seq, ratio)
                    and pool.state[e["slot"]] == ACTIVE):
                kill_slot(e["slot"], t, why="straggler_evict")

    def autoscale(t):
        nonlocal scale_ups, scale_downs, recoveries
        while True:
            idle = [s for s in pool.active
                    if free_at["primary"][s] <= t]
            forced = scheduler.next_forced_dispatch_s()
            until = None if forced is None else forced - t
            d = scaler.decide(t, n_active=pool.n_active, n_idle=len(idle),
                              backlog_s=backlog_s(), until_forced_s=until)
            if d > 0:
                recovery = pool.n_active < policy.min_replicas
                s = pool.attach()
                if s is None:
                    return
                free_at["primary"][s] = t
                if recovery:
                    recoveries += 1
                    scale_log.append(("recover", round(t, 9), s))
                else:
                    scale_ups += 1
                    scaler.last_up_s = t
                    scale_log.append(("up", round(t, 9), s))
                mark(t)
            elif d < 0:
                victim = max(s for s in pool.active
                             if free_at["primary"][s] <= t)
                pool.detach(victim)
                del free_at["primary"][victim]
                scale_downs += 1
                scaler.last_down_s = t
                scale_log.append(("down", round(t, 9), victim))
                mark(t)
            else:
                return

    def admit(req):
        lvl = degrade_level(degrade.policy, saturated=saturated(),
                            backlog_s=backlog_s()) if degrade else 0
        ladder = degrade and req.klass in degrade.policy.order[:lvl]
        if not ladder and scheduler.offer(req, req.arrival_s):
            arm_of[req.rid] = "primary"
            return
        reason = "ladder" if ladder else "overflow"
        if degrade and degrade.scheduler.offer(req, req.arrival_s):
            arm_of[req.rid] = "degraded"
            degrade_reason[req.rid] = reason
            degraded_log.append((req.rid, req.klass, reason,
                                 round(req.arrival_s, 9)))
            return
        shed[req.rid] = req

    def dispatch(name, drain=False):
        apool, sched, ifn, asvc = arms[name]
        fa = free_at[name]
        while True:
            idle = [s for s in apool.active if fa[s] <= now]
            if not idle:
                return
            batch = sched.form_batch(now, drain=drain)
            if batch is None:
                return
            slot = min(idle)
            images = jnp.concatenate(
                [jnp.asarray(ifn(p.req, p.offset, p.size))
                 for p in batch.parts], axis=0) if len(batch.parts) > 1 \
                else jnp.asarray(ifn(batch.parts[0].req,
                                     batch.parts[0].offset,
                                     batch.parts[0].size))
            fut = apool.submit(slot, images)
            done = now + asvc[batch.bucket] * apool.speed_factor[slot]
            fa[slot] = done
            entry = {"arm": name, "slot": slot, "batch": batch, "fut": fut,
                     "dispatch_s": now, "done_s": done, "killed": False}
            inflight.append(entry)
            cur[(name, slot)] = entry
            unprocessed.append(entry)
            batches_log.append({
                "arm": name, "formed_s": batch.formed_s, "slot": slot,
                "bucket": batch.bucket, "n_images": batch.n_images,
                "reason": batch.reason, "done_s": done, "entry": entry,
                "parts": [(p.rid, p.part_idx, p.size) for p in batch.parts]})

    any_queued = lambda: any(a[1].has_queued() for a in arms.values())

    while True:
        for f in injector.due(now):
            apply_fault(f, now)
        while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
            admit(arrivals[ai])
            ai += 1
        process_completions(now)
        autoscale(now)
        if not pool.active and scheduler.has_queued():
            raise RuntimeError(
                "all primary replicas dead with work queued and the warm "
                "pool exhausted — provision more spares for this fault plan")
        for name in arms:
            dispatch(name)
        candidates = []
        if ai < len(arrivals):
            candidates.append(arrivals[ai].arrival_s)
        nf = injector.next_fault_s()
        if nf is not None and (ai < len(arrivals) or any_queued()
                               or any(t > now for fa in free_at.values()
                                      for t in fa.values())):
            candidates.append(nf)
        for name in arms:
            fa = free_at[name]
            busy = [t for t in fa.values() if t > now]
            if busy:
                candidates.append(min(busy))
            if arms[name][1].has_queued() and len(busy) < len(fa):
                forced = arms[name][1].next_forced_dispatch_s()
                if forced is not None and forced > now:
                    candidates.append(forced)
        # The autoscaler can unblock a queue no event would otherwise serve
        # (all actives busy forever is impossible, but a queue below every
        # trigger with idle slots only frees on the next busy/arrival tick).
        if not candidates:
            if any_queued():
                for name in arms:
                    dispatch(name, drain=True)
                continue
            break
        now = max(now, min(candidates))

    process_completions(_INF)

    # -- resolve real execution, reassemble per-request ---------------------
    part_out = {}
    wall_samples = []
    for e in inflight:
        if e["killed"]:
            continue
        logits, wall_s = e["fut"].result()
        wall_samples.append(wall_s)
        logits = np.asarray(logits)
        batch = e["batch"]
        off = 0
        for p in batch.parts:
            rec = {"dispatch_s": batch.formed_s, "done_s": e["done_s"],
                   "arm": e["arm"], "slot": e["slot"],
                   "bucket": batch.bucket, "n_parts": p.n_parts,
                   "wait_s": batch.formed_s - p.enqueued_s}
            part_out[(p.rid, p.part_idx)] = (
                rec, logits[off:off + p.size] if collect_logits else None)
            off += p.size

    requests_out, logits_out = [], {}
    latencies, waits = [], []
    met_requests = met_images = late_requests = 0
    degraded_by_class = {k: 0 for k in DEADLINE_CLASSES}
    for req in trace.requests:
        if req.rid in shed:
            requests_out.append({
                "rid": req.rid, "klass": req.klass, "size": req.size,
                "arrival_s": req.arrival_s, "shed": True, "met": False})
            continue
        n_parts = part_out[(req.rid, 0)][0]["n_parts"]
        parts = [part_out[(req.rid, i)] for i in range(n_parts)]
        completion = max(rec["done_s"] for rec, _ in parts)
        latency = completion - req.arrival_s
        met = completion <= req.deadline_s
        latencies.append(latency)
        waits.extend(rec["wait_s"] for rec, _ in parts)
        met_requests += int(met)
        met_images += req.size * int(met)
        late_requests += int(not met)
        arm = arm_of[req.rid]
        if arm == "degraded":
            degraded_by_class[req.klass] += 1
        requests_out.append({
            "rid": req.rid, "klass": req.klass, "size": req.size,
            "arrival_s": req.arrival_s, "deadline_s": req.deadline_s,
            "completion_s": completion, "latency_s": latency,
            "met": met, "shed": False, "arm": arm,
            "degrade_reason": degrade_reason.get(req.rid),
            "slots": sorted({rec["slot"] for rec, _ in parts})})
        if collect_logits:
            logits_out[req.rid] = np.concatenate(
                [lg for _, lg in parts], axis=0)

    served_batches = [b for b in batches_log if not b["entry"]["killed"]]
    for b in batches_log:
        b["killed"] = b["entry"]["killed"]
        del b["entry"]
    total = len(trace.requests)
    makespan = max((b["done_s"] for b in served_batches), default=0.0)
    real = sum(b["n_images"] for b in served_batches)
    padded = sum(b["bucket"] for b in served_batches)
    reasons = {}
    for b in served_batches:
        reasons[b["reason"]] = reasons.get(b["reason"], 0) + 1
    # Replica-seconds: integral of the active count over the run — the cost
    # side of elasticity (a fixed pool pays max_replicas × makespan).
    replica_seconds = 0.0
    for (t0, n), (t1, _) in zip(timeline, timeline[1:] + [(makespan, 0)]):
        replica_seconds += n * max(0.0, min(t1, makespan) - min(t0, makespan))
    n_degraded = sum(degraded_by_class.values())
    reasons_deg = {}
    for _, _, r, _ in degraded_log:
        reasons_deg[r] = reasons_deg.get(r, 0) + 1
    report = {
        "scenario": trace.scenario,
        "seed": trace.seed,
        "arm": f"elastic-{pool.arm}",
        "min_replicas": policy.min_replicas,
        "max_replicas": policy.max_replicas,
        "spares": pool.spares,
        "buckets": list(pool.buckets),
        "service_model_s": {str(b): s for b, s in svc.items()},
        "requests": total,
        "images": trace.total_images,
        "offered_images_per_s": trace.target_images_per_s,
        "served_requests": total - len(shed),
        "shed_requests": len(shed),
        "deadline_miss_rate": ((late_requests + len(shed)) / total
                               if total else 0.0),
        "deadline_met_requests": met_requests,
        "goodput_images_per_s": met_images / makespan if makespan else 0.0,
        "latency": latency_summary(latencies),
        "queue_wait": latency_summary(waits),
        "measured_batch": latency_summary(wall_samples),
        "batches": len(served_batches),
        "killed_batches": len(batches_log) - len(served_batches),
        "padding_waste": padding_waste(real, padded),
        "dispatch_reasons": reasons,
        "virtual_makespan_s": makespan,
        "recompiles_after_warmup": pools_total() - traces_at_start,
        "scale_ups": scale_ups,
        "scale_downs": scale_downs,
        "kills": kills,
        "straggler_evictions": straggler_evictions,
        "recoveries": recoveries,
        "max_active": max(n for _, n in timeline),
        "replica_seconds": replica_seconds,
        "degraded_requests": n_degraded,
        "degraded_by_class": degraded_by_class,
        "degrade_reasons": reasons_deg,
        "faults_fired": len(injector.fired),
    }
    events = {"scale": scale_log, "faults": fault_log,
              "degraded": degraded_log}
    return ElasticResult(report=report, requests=requests_out,
                         logits=logits_out, batches=batches_log,
                         events=events)


# ---------------------------------------------------------------------------
# The diurnal elastic scenario: BENCH_elastic.json
# ---------------------------------------------------------------------------

def elastic_sweep(base_cfg=None, *, scenario="diurnal", n_requests=220,
                  seed=0, min_replicas=1, max_replicas=2, spares=1,
                  degrade_replicas=1, arm="thread", utilization=1.15,
                  buckets=None, freeze=True, impl=None, tune=None,
                  calibrate_iters=3, kill_at_frac=0.35,
                  slowdown_at_frac=0.6, slowdown_factor=4.0,
                  verify_replay=True, collect_logits=False) -> dict:
    """The acceptance scenario, one record for BENCH_elastic.json.

    The diurnal trace is deliberately calibrated ABOVE the fixed baseline:
    `utilization` × the min_replicas capacity, with the sinusoidal peak at
    RAMP_HI (1.8×) on top — the baseline (a fixed pool of min_replicas, no
    autoscaler, no degradation, served through the same elastic loop) must
    record a miss rate > 0, and the elastic arm (scale to max_replicas,
    shed the ladder to the shiftadd arm at saturation, survive a replica
    kill and a straggler at chosen virtual times) must record ZERO misses
    and ZERO recompiles. A replay re-runs the elastic arm from a reset
    control plane and must reproduce the elastic signature and every logit
    bit-for-bit — injected-failure timing and degradation decisions
    included. benchmarks/check_elastic.py gates all three.
    """
    import dataclasses as _dc

    from repro.core.policy import DENSE
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve.frontend import calibrate_service_models
    from repro.serve.traffic import default_budgets, make_trace
    from repro.serve.vision import build_policy_model

    base_cfg = base_cfg or ViTConfig(image_size=56)
    buckets = tuple(buckets) if buckets else DEFAULT_BUCKETS
    dense_model = ShiftAddViT(_dc.replace(base_cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(seed))
    sa_model, sa_params = build_policy_model(base_cfg, "shiftadd",
                                             dense_model, dense_params)
    shape = (base_cfg.image_size, base_cfg.image_size, base_cfg.in_channels)

    primary = ElasticWarmPool(dense_model, dense_params,
                              max_replicas=max_replicas, spares=spares,
                              buckets=buckets, freeze=freeze, impl=impl,
                              tune=tune, arm=arm).warmup()
    cheap = ElasticWarmPool(sa_model, sa_params,
                            max_replicas=degrade_replicas, spares=0,
                            buckets=buckets, freeze=freeze, impl=impl,
                            tune=tune, arm=arm).warmup()
    svc_p, svc_d = calibrate_service_models([primary, cheap], shape,
                                            iters=calibrate_iters)
    bmax = primary.buckets[-1]
    capacity_min = min_replicas * bmax / svc_p[bmax]
    budgets = default_budgets(svc_p[bmax])
    trace = make_trace(scenario, n_requests, seed,
                       target_images_per_s=utilization * capacity_min,
                       budgets_s=budgets, max_size=bmax)
    horizon = trace.horizon_s
    faults = []
    if kill_at_frac is not None:
        faults.append(ReplicaFault(at_s=kill_at_frac * horizon, kind="kill",
                                   slot=0))
    if slowdown_at_frac is not None:
        faults.append(ReplicaFault(at_s=slowdown_at_frac * horizon,
                                   kind="slowdown", slot=0,
                                   factor=slowdown_factor))
    faults = tuple(faults)

    def scheduler_for(pool, svc, max_queue_images):
        pmax = pool.buckets[-1]
        return MicroBatchScheduler(pool.buckets, svc,
                                   slack_s=0.5 * svc[pmax],
                                   linger_s=1.0 * svc[pmax],
                                   max_queue_images=max_queue_images)

    def run_baseline():
        primary.reset_membership()
        fixed = AutoscalerPolicy(min_replicas=min_replicas,
                                 max_replicas=min_replicas)
        return serve_elastic_trace(
            primary, scheduler_for(primary, svc_p, 8 * bmax), trace,
            policy=fixed, faults=(), degrade=None, collect_logits=False)

    def run_elastic(collect):
        primary.reset_membership()
        cheap.reset_membership()
        policy = default_autoscaler_policy(svc_p[bmax],
                                           min_replicas=min_replicas,
                                           max_replicas=max_replicas)
        degrade = DegradeArm(
            pool=cheap,
            scheduler=scheduler_for(cheap, svc_d, None),
            policy=DegradePolicy(min_backlog_s=1.0 * svc_p[bmax],
                                 step_backlog_s=2.0 * svc_p[bmax]))
        return serve_elastic_trace(
            primary, scheduler_for(primary, svc_p, 8 * bmax), trace,
            policy=policy, faults=faults, degrade=degrade,
            collect_logits=collect)

    base = run_baseline()
    elastic = run_elastic(collect=collect_logits or verify_replay)

    from repro.kernels import ops
    record = {
        "backend": jax.default_backend(),
        "model": (f"shiftadd_vit({base_cfg.n_layers}L,{base_cfg.d_model}d,"
                  f"{base_cfg.n_patches}p)"),
        "image_size": base_cfg.image_size,
        "frozen": bool(freeze),
        "impl": impl or ops.default_impl(),
        "scenario": scenario,
        "utilization": utilization,
        "trace": trace.summary(),
        "budgets_s": budgets,
        "service_model_s": {"dense": {str(b): s for b, s in svc_p.items()},
                            "shiftadd": {str(b): s
                                         for b, s in svc_d.items()}},
        "faults": [dataclasses.asdict(f) for f in faults],
        "baseline": base.report,
        "elastic": elastic.report,
        "baseline_deadline_miss_rate": base.report["deadline_miss_rate"],
        "elastic_deadline_miss_rate": elastic.report["deadline_miss_rate"],
        "recompiles_after_warmup": (base.report["recompiles_after_warmup"]
                                    + elastic.report[
                                        "recompiles_after_warmup"]),
        "replica_seconds_saved_vs_fixed_max": (
            max_replicas * elastic.report["virtual_makespan_s"]
            - elastic.report["replica_seconds"]),
    }
    if verify_replay:
        replay = run_elastic(collect=True)
        record["replay_identical_events"] = (
            elastic.elastic_signature() == replay.elastic_signature())
        record["replay_bit_identical_logits"] = (
            set(elastic.logits) == set(replay.logits) and all(
                np.array_equal(elastic.logits[r], replay.logits[r])
                for r in elastic.logits))
    primary.close()
    cheap.close()
    return record


# ---------------------------------------------------------------------------
# LM slots: elastic continuous batching
# ---------------------------------------------------------------------------

class ElasticLMPool:
    """Warm reserve of stateful `BucketedLMEngine`s with the same
    attach/detach/kill membership verbs as ElasticWarmPool. LM engines own
    their packed slot arrays, so replicas never share one — a kill loses
    that engine's in-progress decode state, and recovery restarts the
    requeued requests from prefill on another engine (greedy decode makes
    the retry bit-identical)."""

    arm = "lm"

    def __init__(self, model, params, *, max_replicas=2, spares=1,
                 **engine_kw):
        from repro.serve.lm import BucketedLMEngine

        assert max_replicas >= 1 and spares >= 0
        self.max_replicas = int(max_replicas)
        self.spares = int(spares)
        self.reserve = self.max_replicas + self.spares
        self.engines = [BucketedLMEngine(model, params, **engine_kw)
                        for _ in range(self.reserve)]
        self.state = [PARKED] * self.reserve
        self.active = []

    @property
    def prompt_buckets(self):
        return self.engines[0].prompt_buckets

    @property
    def chunk(self) -> int:
        return self.engines[0].chunk

    @property
    def n_slots(self) -> int:
        return self.engines[0].n_slots

    @property
    def trace_count(self) -> int:
        return sum(e.trace_count for e in self.engines)

    @property
    def prefill_trace_count(self) -> int:
        return sum(e.prefill_trace_count for e in self.engines)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_parked(self) -> int:
        return sum(1 for s in self.state if s == PARKED)

    def warmup(self):
        for e in self.engines:
            e.warmup()
        return self

    def reset(self):
        for e in self.engines:
            e.reset()
        return self

    def attach(self):
        if self.n_active >= self.max_replicas:
            return None
        for i, s in enumerate(self.state):
            if s == PARKED:
                self.state[i] = ACTIVE
                self.active.append(i)
                self.active.sort()
                return i
        return None

    def detach(self, slot: int):
        assert self.state[slot] == ACTIVE, (slot, self.state[slot])
        self.state[slot] = PARKED
        self.active.remove(slot)

    def kill(self, slot: int):
        assert self.state[slot] == ACTIVE, (slot, self.state[slot])
        self.state[slot] = DEAD
        self.active.remove(slot)

    def reset_membership(self):
        self.state = [PARKED] * self.reserve
        self.active = []
        return self.reset()

    def close(self):
        pass


def serve_elastic_lm_trace(pool: ElasticLMPool, scheduler, trace: Trace,
                           svc, *, policy: AutoscalerPolicy,
                           per_request_s: float, faults=(),
                           new_token_range=(4, 24), collect_logits=True):
    """serve.frontend.serve_lm_trace over a dynamic engine set.

    The decision grid stays the chunk boundary: at each boundary faults
    fire, the autoscaler attaches/parks warm engines (backlog measured as
    queued_requests × per_request_s, spread over active slots), and a
    killed engine's in-progress requests are requeued at their class heads
    to restart from prefill elsewhere. Returns the same LMTrafficResult as
    serve_lm_trace, with elastic counters added to the report."""
    from repro.serve.frontend import LMTrafficResult
    from repro.serve.traffic import lm_new_tokens, lm_prompt_tokens

    injector = FailureInjector(faults=tuple(faults))
    scaler = Autoscaler(policy)
    engines = pool.engines
    vocab = engines[0].model.cfg.vocab_size
    prefill_s, chunk_s = svc["prefill_s"], svc["chunk_s"]
    K = pool.chunk
    t = {}
    slot_state = {}
    scale_log, fault_log = [], []
    kills = recoveries = scale_ups = scale_downs = 0
    while pool.n_active < policy.min_replicas:
        e = pool.attach()
        if e is None:
            raise RuntimeError("warm pool smaller than min_replicas")
        t[e] = 0.0
        slot_state[e] = [None] * pool.n_slots
        scale_log.append(("init", 0.0, e))

    arrivals = list(trace.requests)
    ai = 0
    traces_at_start = pool.trace_count
    dispatches, shed, done = [], {}, {}
    tokens_out, logits_out = {}, {}
    n_chunks = occupancy_sum = 0

    def finish(rec, now):
        req = rec["req"]
        done[req.rid] = {
            "rid": req.rid, "klass": req.klass, "prompt_len": req.size,
            "new_tokens": rec["target"], "arrival_s": req.arrival_s,
            "deadline_s": req.deadline_s, "admit_s": rec["admit_s"],
            "ttft_s": rec["ttft_s"], "completion_s": now,
            "latency_s": now - req.arrival_s,
            "met": now <= req.deadline_s, "shed": False,
            "engine": rec["engine"], "slot": rec["slot"],
            "bucket": rec["bucket"]}
        tokens_out[req.rid] = np.concatenate(rec["toks"])
        if collect_logits:
            logits_out[req.rid] = np.concatenate(rec["logits"], axis=0)

    def kill_engine(eid, now):
        nonlocal kills
        recs = [r for r in slot_state[eid] if r is not None]
        scheduler.requeue([(r["req"], r["enq"]) for r in recs])
        pool.kill(eid)
        del t[eid]
        del slot_state[eid]
        kills += 1
        fault_log.append(("kill", round(now, 9), eid))

    def autoscale(now):
        nonlocal scale_ups, scale_downs, recoveries
        while True:
            n_free = sum(1 for e in pool.active
                         for r in slot_state[e] if r is None)
            backlog = scheduler.queued_requests * per_request_s
            spread = backlog / max(pool.n_active * pool.n_slots, 1)
            d = scaler.decide(now, n_active=pool.n_active, n_idle=n_free,
                              backlog_s=spread * pool.n_slots,
                              until_forced_s=None)
            if d > 0:
                recovery = pool.n_active < policy.min_replicas
                e = pool.attach()
                if e is None:
                    return
                t[e] = now
                slot_state[e] = [None] * pool.n_slots
                if recovery:
                    recoveries += 1
                    scale_log.append(("recover", round(now, 9), e))
                else:
                    scale_ups += 1
                    scaler.last_up_s = now
                    scale_log.append(("up", round(now, 9), e))
            elif d < 0:
                empties = [e for e in pool.active
                           if all(r is None for r in slot_state[e])]
                if not empties:
                    return
                victim = max(empties)
                pool.detach(victim)
                del t[victim]
                del slot_state[victim]
                scale_downs += 1
                scaler.last_down_s = now
                scale_log.append(("down", round(now, 9), victim))
            else:
                return

    while True:
        if (ai >= len(arrivals) and not scheduler.has_queued()
                and all(r is None for st in slot_state.values()
                        for r in st)):
            break
        e = min(pool.active, key=lambda i: (t[i], i))
        now = t[e]
        for f in injector.due(now):
            act = pool.active
            if not act:
                continue
            victim = act[f.slot % len(act)]
            if f.kind == "kill":
                kill_engine(victim, now)
            else:
                fault_log.append(("slowdown_unsupported", round(now, 9),
                                  victim))
        while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
            req = arrivals[ai]
            if not scheduler.offer(req, req.arrival_s):
                shed[req.rid] = req
            ai += 1
        autoscale(now)
        if not pool.active:
            raise RuntimeError(
                "all LM engines dead with work remaining and the warm pool "
                "exhausted — provision more spares for this fault plan")
        if e not in t:               # the boundary engine was just killed
            continue
        eng, st = engines[e], slot_state[e]

        for s_i, rec in enumerate(st):
            if rec is not None and rec["gen"] >= rec["target"]:
                eng.evict(s_i)
                finish(rec, now)
                st[s_i] = None

        free = [i for i, r in enumerate(st) if r is None]
        while free and scheduler.has_queued():
            req, enq = scheduler.next_request(now)
            slot = free.pop(0)
            admit_s = now
            first, first_logits = eng.admit(
                slot, lm_prompt_tokens(req, vocab), rid=req.rid)
            bucket = eng.bucket_for(min(req.size, eng.prompt_buckets[-1]))
            now += prefill_s[bucket]
            target = lm_new_tokens(req, *new_token_range)
            st[slot] = {
                "req": req, "enq": enq, "admit_s": admit_s,
                "ttft_s": now - req.arrival_s,
                "target": target, "gen": 1, "engine": e, "slot": slot,
                "bucket": bucket,
                "toks": [np.asarray([first], np.int32)],
                "logits": [first_logits[None]] if collect_logits else None}
            dispatches.append({
                "rid": req.rid, "admit_s": admit_s, "engine": e,
                "slot": slot, "bucket": bucket, "prompt_len": req.size,
                "new_tokens": target})

        alive = [i for i, r in enumerate(st) if r is not None]
        if alive:
            toks_seq, logits_seq = eng.decode_chunk()
            for s_i in alive:
                rec = st[s_i]
                take = min(K, rec["target"] - rec["gen"])
                if take > 0:
                    rec["toks"].append(toks_seq[:take, s_i].copy())
                    if collect_logits:
                        rec["logits"].append(logits_seq[:take, s_i].copy())
                    rec["gen"] += take
            n_chunks += 1
            occupancy_sum += len(alive)
            t[e] = now + chunk_s
        elif ai < len(arrivals):
            t[e] = max(now, arrivals[ai].arrival_s)
        else:
            t[e] = _INF

    requests_out, latencies, ttfts, waits = [], [], [], []
    met = late = gen_total = 0
    for req in trace.requests:
        if req.rid in shed:
            requests_out.append({
                "rid": req.rid, "klass": req.klass, "prompt_len": req.size,
                "arrival_s": req.arrival_s, "shed": True, "met": False})
            continue
        r = done[req.rid]
        requests_out.append(r)
        latencies.append(r["latency_s"])
        ttfts.append(r["ttft_s"])
        waits.append(r["admit_s"] - req.arrival_s)
        gen_total += r["new_tokens"]
        met += int(r["met"])
        late += int(not r["met"])

    total = len(trace.requests)
    makespan = max((r["completion_s"] for r in done.values()), default=0.0)
    report = {
        "scenario": trace.scenario,
        "seed": trace.seed,
        "mode": "elastic-continuous",
        "engines": pool.reserve,
        "max_replicas": pool.max_replicas,
        "n_slots": pool.n_slots,
        "chunk": K,
        "prompt_buckets": list(pool.prompt_buckets),
        "requests": total,
        "served_requests": total - len(shed),
        "shed_requests": len(shed),
        "deadline_miss_rate": (late + len(shed)) / total if total else 0.0,
        "generated_tokens": gen_total,
        "virtual_makespan_s": makespan,
        "latency": latency_summary(latencies),
        "ttft": latency_summary(ttfts),
        "queue_wait": latency_summary(waits),
        "decode_chunks": n_chunks,
        "chunk_occupancy": (occupancy_sum / (n_chunks * pool.n_slots)
                            if n_chunks else 0.0),
        "recompiles_after_warmup": pool.trace_count - traces_at_start,
        "scale_ups": scale_ups,
        "scale_downs": scale_downs,
        "kills": kills,
        "recoveries": recoveries,
        "scale_events": scale_log,
        "faults": fault_log,
    }
    return LMTrafficResult(report=report, requests=requests_out,
                           tokens=tokens_out, logits=logits_out,
                           dispatches=dispatches)
