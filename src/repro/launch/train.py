"""Production training driver.

    python -m repro.launch.train --arch yi-9b --policy shiftadd --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real TPU slice this binary is what every host runs (jax.distributed
initialization is environment-driven); on CPU, --reduced configs train for
real. The loop is fault-tolerant: checkpoint/restart + deterministic data
replay; rerunning the same command after a crash resumes.
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import Checkpointer
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, list_archs
from repro.data.pipeline import SyntheticLMData
from repro.distributed import sharding as shard_lib
from repro.nn.model import LanguageModel
from repro.train import train_loop
from repro.utils.logging import get_logger

log = get_logger("repro.launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--policy", default="dense",
                    choices=["dense", "shiftadd", "stage1", "all_shift"])
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 → (data=2, model=4) over local devices")
    args = ap.parse_args()

    cfg = get_config(args.arch, policy=args.policy, reduced=args.reduced)
    cfg = cfg.replace(moe_primitives_capacity=2.0)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=min(20, args.steps // 5),
                       total_steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, microbatch=args.microbatch,
                       grad_compression=args.grad_compression,
                       checkpoint_every=max(10, args.steps // 10))

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[: len(shape)]
        mesh = shard_lib.make_mesh(shape, axes)
        shard_lib.set_active_mesh(mesh)

    model = LanguageModel(cfg)
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, seed=tcfg.seed,
                           input_mode=cfg.input_mode, d_model=cfg.d_model,
                           mrope=(cfg.rope == "mrope"))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    def hook(m):
        if m["step"] % 20 == 0:
            log.info("step %5d  loss %.4f  ce %.4f  %.2fs",
                     m["step"], m["loss"], m.get("ce", float("nan")),
                     m["seconds"])

    if mesh is not None:
        with mesh:
            state, hist = train_loop(model, tcfg, data, mesh=mesh,
                                     checkpointer=ckpt, metrics_hook=hook)
    else:
        state, hist = train_loop(model, tcfg, data, checkpointer=ckpt,
                                 metrics_hook=hook)
    log.info("done: loss %.4f -> %.4f over %d steps",
             hist[0]["loss"], hist[-1]["loss"], len(hist))


if __name__ == "__main__":
    main()
