"""LM traffic-serving driver: token-level continuous batching vs the static
fixed-batch refill baseline, on the SAME seeded trace and the SAME engines.

    python -m repro.launch.serve_lm_traffic --scenario poisson --policy stage1
    python -m repro.launch.serve_lm_traffic --scenario bursty --policy all --slots 8
    python -m repro.launch.serve_lm_traffic --requests 120 --utilization 2.0

A seeded trace (`--scenario poisson|bursty|diurnal`) of variable-length,
deadline-classed LM requests (prompt tokens and decode lengths derived
deterministically from each request's seed — serve.traffic's LM payload
helpers) is pushed through `serve.scheduler.SlotScheduler` onto `--replicas`
`BucketedLMEngine`s of `--slots` decode slots each. Requests join the
RUNNING decode batch at chunk boundaries via the jitted admit/evict slot
scatters; the static arm re-serves the identical trace under gang-refill
admission on the same warmed pool. Offered load and deadline budgets are
calibrated from measured per-bucket prefill + decode-chunk times, so the
virtual timeline is machine-independent up to the calibration. Writes
BENCH_lm_traffic.json and exits non-zero if any program recompiled after
warmup or a determinism verification failed.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.serve.frontend import lm_traffic_sweep
from repro.serve.traffic import SCENARIOS
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve_lm_traffic")

POLICIES = ("stage1", "shiftadd")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="poisson", choices=SCENARIOS)
    ap.add_argument("--policy", default="stage1",
                    choices=list(POLICIES) + ["all"])
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--buckets", type=int, nargs="+", default=[4, 8, 16])
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--utilization", type=float, default=1.5,
                    help="offered load as a fraction of the calibrated "
                         "full-occupancy request capacity (>1 = overload, "
                         "where continuous batching pays off)")
    ap.add_argument("--new-tokens", type=int, nargs=2, default=[4, 24],
                    metavar=("LO", "HI"))
    ap.add_argument("--skip-verify", action="store_true")
    ap.add_argument("--out", default="BENCH_lm_traffic.json")
    args = ap.parse_args(argv)

    policies = POLICIES if args.policy == "all" else (args.policy,)
    rec = lm_traffic_sweep(
        scenario=args.scenario, policies=policies, n_requests=args.requests,
        seed=args.seed, n_replicas=args.replicas, n_slots=args.slots,
        prompt_buckets=tuple(args.buckets), chunk=args.chunk,
        layers=args.layers, d_model=args.d_model, vocab_size=args.vocab,
        utilization=args.utilization,
        new_token_range=tuple(args.new_tokens),
        verify_replay=not args.skip_verify,
        verify_serial_oracle=not args.skip_verify)

    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    bad = 0
    for name, r in rec["policies"].items():
        c, s = r["continuous"], r["static"]
        log.info(
            "%s: continuous %.1f tok/s (occupancy %.2f, ttft p50 %.1f ms) "
            "vs static %.1f tok/s (occupancy %.2f) — %.3fx",
            name, c["tokens_per_s"], c["chunk_occupancy"],
            c["ttft"]["p50_s"] * 1e3, s["tokens_per_s"],
            s["chunk_occupancy"], r["continuous_vs_static_tokens_per_s"])
        bad += c["recompiles_after_warmup"] + s["recompiles_after_warmup"]
        for key in ("replay_bit_identical_logits",
                    "one_vs_n_bit_identical_logits"):
            if key in r and not r[key]:
                log.error("%s: %s is FALSE", name, key)
                bad += 1
    log.info("wrote %s", os.path.abspath(args.out))
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
