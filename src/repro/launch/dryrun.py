import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first init. Do not set that flag globally (smoke tests and benches
must see 1 device).

Per cell:
  train_4k    → jax.jit(train_step)   (state donated, microbatched)
  prefill_32k → jax.jit(prefill_step)
  decode_32k / long_500k → jax.jit(serve_step) (cache donated)

Artifacts (one JSON per cell) carry: memory_analysis, XLA cost_analysis,
and the trip-count-corrected HLO costs (launch.hlo_analysis) that feed
§Roofline. All numbers are per-device (post-SPMD HLO).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both          # orchestrates
                                                           # subprocesses
"""
import argparse
import json
import math
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import shapes as shp
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, list_archs
from repro.distributed import sharding as shard_lib
from repro.analysis import ir
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.nn.model import LanguageModel
from repro.serve.decode import make_prefill_step, make_serve_step
from repro.train.step import init_train_state, make_train_step
from repro.utils.logging import get_logger

log = get_logger("repro.dryrun")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# Cache-leaf logical axes by param name (see DESIGN.md §3).
CACHE_RULES = {
    "k": ("batch", "kv_heads", None, None),
    "v": ("batch", "kv_heads", None, None),
    "k_scale": ("batch", "kv_heads", None),
    "v_scale": ("batch", "kv_heads", None),
    "kv": ("batch", "heads", None, None),
    "ksum": ("batch", "heads", None),
    "vsum": ("batch", "heads", None),
    "S": ("batch", "heads", None, None),
    "c_kv": ("batch", None, None),
    "k_rope": ("batch", None, None),
    "conv": ("batch", None, None),
    "x_prev": ("batch", None),
    "h": ("batch", None),
    "slot_pos": ("batch", None),
    "pos": ("batch",),
    "count": ("batch",),
}


# Cache leaves that may fall back to sharding their LAST dim over `model`
# when the head dim is indivisible (e.g. kv_heads=8 on model=16) — otherwise
# a 32k dense KV cache replicates 16× and blows the HBM budget.
_KV_LIKE = {"k", "v", "kv", "S", "c_kv", "k_rope", "ksum"}


def cache_shardings(cache_shapes, mesh):
    model_size = mesh.shape.get("model", 1)

    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        axes = CACHE_RULES.get(name, ())
        if leaf.ndim == len(axes) + 1:      # scan-stacked (cycles, ...)
            axes = (None,) + tuple(axes)
        elif leaf.ndim != len(axes):
            axes = (None,) * leaf.ndim
        pspec = shard_lib.logical_to_pspec(axes, mesh, leaf.shape)
        if (name in _KV_LIKE and "model" in mesh.axis_names
                and "model" not in jax.tree_util.tree_leaves(tuple(pspec))
                and leaf.ndim >= 2 and leaf.shape[-1] % model_size == 0):
            axes = tuple(axes[:-1]) + ("mlp",)   # mlp → model
            pspec = shard_lib.logical_to_pspec(axes, mesh, leaf.shape)
        return NamedSharding(mesh, pspec)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def state_shardings(state_shapes, params_shardings, mesh, opt_shard=None):
    rep = NamedSharding(mesh, P())
    opt_shard = opt_shard if opt_shard is not None else params_shardings

    def like_params(tree_shapes):
        # m/v mirror params (ZeRO: may be sharded more finely than params);
        # frozen (int) leaves became f32 scalars → replicate
        flat_p, treedef = jax.tree_util.tree_flatten(state_shapes["params"])
        flat_sh = treedef.flatten_up_to(opt_shard)
        flat_t = treedef.flatten_up_to(tree_shapes)
        out = [sh if t.shape == p.shape else rep
               for p, sh, t in zip(flat_p, flat_sh, flat_t)]
        return treedef.unflatten(out)

    out = {"params": params_shardings, "step": rep}
    opt = state_shapes["opt"]
    out["opt"] = type(opt)(count=rep, m=like_params(opt.m), v=like_params(opt.v))
    if "ef" in state_shapes:
        out["ef"] = like_params(state_shapes["ef"])
    return out


def batch_shardings(batch_specs, mesh):
    def one(leaf):
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, shard_lib.logical_to_pspec(axes, mesh, leaf.shape))

    return jax.tree_util.tree_map(one, batch_specs)


def lower_cell(arch, shape_name, mesh_kind, policy=None, n_micro=None,
               remat=None, cast_params="none", shard_mode="baseline",
               constrain_grad_acc=False, moe_cap=None):
    cfg = get_config(arch, policy=policy)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if moe_cap is not None:
        cfg = cfg.replace(moe_primitives_capacity=moe_cap)
    if os.environ.get("REPRO_RWKV_CHUNKED"):
        cfg = cfg.replace(rwkv_chunked=True)
    if os.environ.get("REPRO_KV_INT8"):
        cfg = cfg.replace(kv_cache_dtype="int8")
    plan = shp.plan_cell(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "policy": policy or "dense"}
    if plan.skip:
        result.update(skipped=True, reason=plan.reason)
        return result
    if plan.policy_override is not None:
        cfg = cfg.with_policy(plan.policy_override)
        result["policy"] = "shiftadd(auto: long-context requires sub-quadratic)"

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    spec = shp.SHAPES[shape_name]
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    shard_lib.set_active_mesh(mesh)
    with mesh:
        params_shapes = jax.eval_shape(model.init, key)
        n_params = sum(math.prod(l.shape) if l.shape else 1
                       for l in jax.tree_util.tree_leaves(params_shapes))
        pspec_tree = model.spec(params_shapes)
        opt_spec_tree = pspec_tree
        if shard_mode == "out_fsdp":
            pspec_tree = shard_lib.spec_to_out_fsdp(pspec_tree)
            opt_spec_tree = pspec_tree
        elif shard_mode == "tp_zero1":
            pspec_tree = shard_lib.spec_to_tp_zero1(pspec_tree)
        pshard = shard_lib.shardings_from_spec(pspec_tree, params_shapes, mesh)
        opt_shard = (pshard if opt_spec_tree is pspec_tree else
                     shard_lib.shardings_from_spec(opt_spec_tree, params_shapes,
                                                   mesh))

        if spec.kind == "train":
            # Microbatch count: keep per-microbatch batch divisible by the DP
            # shard count (pod×data), else GSPMD pads every activation 2×.
            dp = mesh.devices.size // mesh.shape.get("model", 1)
            default_micro = max(1, min(16, spec.global_batch // dp))
            tcfg = TrainConfig(global_batch=spec.global_batch, seq_len=spec.seq_len,
                               microbatch=n_micro or default_micro,
                               cast_params=cast_params,
                               constrain_grad_acc=constrain_grad_acc)
            state_shapes = jax.eval_shape(
                lambda k: init_train_state(model, tcfg, k), key)
            st_shard = state_shardings(state_shapes, pshard, mesh,
                                       opt_shard=opt_shard)
            batch = shp.input_specs(cfg, shape_name)
            b_shard = batch_shardings(batch, mesh)
            step = make_train_step(model, tcfg)
            lowered = jax.jit(step, in_shardings=(st_shard, b_shard),
                              out_shardings=(st_shard, None),
                              donate_argnums=(0,)).lower(state_shapes, batch)
        elif spec.kind == "prefill":
            batch = shp.input_specs(cfg, shape_name)
            b_shard = batch_shardings(batch, mesh)
            step = make_prefill_step(model)
            lowered = jax.jit(step, in_shardings=(pshard, b_shard)
                              ).lower(params_shapes, batch)
        else:  # decode
            inputs_t = shp.input_specs(cfg, shape_name)["inputs_t"]
            in_shard = batch_shardings({"t": inputs_t}, mesh)["t"]
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(spec.global_batch, max_len=spec.seq_len))
            c_shard = cache_shardings(cache_shapes, mesh)
            step = make_serve_step(model)
            lowered = jax.jit(step, in_shardings=(pshard, in_shard, c_shard),
                              out_shardings=(None, c_shard),
                              donate_argnums=(2,)
                              ).lower(params_shapes, inputs_t, cache_shapes)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = ir.xla_cost_dict(compiled)
    hlo_cost = hlo_analysis.analyze(compiled.as_text())

    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    # MODEL_FLOPS conventions per kind (6ND train, 2ND forward), N = active
    # params (MoE) excluding nothing — ratio analysis reported alongside.
    active_ratio = cfg.active_param_count() / max(cfg.param_count(), 1)
    n_active = n_params * active_ratio
    mf = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[spec.kind]
    model_flops = mf * n_active * tokens

    result.update(
        skipped=False,
        kind=spec.kind,
        seq_len=spec.seq_len,
        global_batch=spec.global_batch,
        n_devices=mesh.devices.size,
        n_params=n_params,
        n_params_active=n_active,
        model_flops_global=model_flops,
        lower_seconds=t_lower,
        compile_seconds=t_compile,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        xla_cost={k: v for k, v in xla_cost.items()
                  if k in ("flops", "bytes accessed")},
        hlo_flops_per_device=hlo_cost.flops,
        hlo_bytes_per_device=hlo_cost.bytes,
        collective_bytes_per_device=hlo_cost.collective_bytes,
        collective_breakdown=hlo_cost.collective_breakdown,
    )
    return result


def artifact_path(arch, shape_name, mesh_kind, policy, out_dir=None):
    d = out_dir or ARTIFACT_DIR
    os.makedirs(d, exist_ok=True)
    pol = policy or "dense"
    return os.path.join(d, f"{arch}__{shape_name}__{mesh_kind}__{pol}.json")


def run_one(args):
    res = lower_cell(args.arch, args.shape, args.mesh, args.policy,
                     n_micro=args.microbatch, remat=args.remat,
                     cast_params=args.cast_params, shard_mode=args.shard_mode,
                     constrain_grad_acc=args.grad_acc, moe_cap=args.moe_cap)
    res["variant"] = args.variant
    path = artifact_path(args.arch, args.shape, args.mesh, args.policy,
                         args.out)
    if args.variant:
        path = path.replace(".json", f"__{args.variant}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    log.info("wrote %s", path)
    status = "SKIP" if res.get("skipped") else "OK"
    extra = res.get("reason", "") if res.get("skipped") else (
        f"compile={res['compile_seconds']:.1f}s "
        f"temp={res['memory']['temp_bytes']/2**30:.2f}GiB "
        f"flops/dev={res['hlo_flops_per_device']:.3e}")
    print(f"[{status}] {args.arch} {args.shape} {args.mesh} "
          f"{args.policy or 'dense'}: {extra}")
    return 0


def run_all(args):
    """Orchestrate every cell in subprocesses (isolation + parallelism)."""
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = [(a, s, m) for a in list_archs() for s in shp.SHAPES for m in meshes]
    procs = []
    failures = []
    max_par = args.jobs

    def launch(a, s, m):
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", m]
        if args.policy:
            cmd += ["--policy", args.policy]
        if args.out:
            cmd += ["--out", args.out]
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        return subprocess.Popen(cmd, env=env)

    pending = list(cells)
    running = []
    while pending or running:
        while pending and len(running) < max_par:
            a, s, m = pending.pop(0)
            running.append(((a, s, m), launch(a, s, m)))
        done = [(c, p) for c, p in running if p.poll() is not None]
        running = [(c, p) for c, p in running if p.poll() is None]
        for cell, p in done:
            if p.returncode != 0:
                failures.append(cell)
                print(f"[FAIL] {cell}")
        time.sleep(0.5)
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells OK")
    if failures:
        print("failures:", failures)
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--policy", choices=["dense", "shiftadd", "shiftadd_deploy",
                                         "stage1", "all_shift"], default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--remat", choices=["none", "full", "dots_saveable"],
                    default=None)
    ap.add_argument("--cast-params", dest="cast_params",
                    choices=["none", "compute_dtype"], default="none")
    ap.add_argument("--shard-mode", dest="shard_mode",
                    choices=["baseline", "out_fsdp", "tp_zero1"],
                    default="baseline")
    ap.add_argument("--grad-acc-constraint", dest="grad_acc",
                    action="store_true")
    ap.add_argument("--moe-cap", dest="moe_cap", type=float, default=None)
    ap.add_argument("--variant", default=None,
                    help="suffix for §Perf hillclimb artifacts")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    assert args.arch and args.shape and args.mesh in ("single", "multi")
    sys.exit(run_one(args))


if __name__ == "__main__":
    main()
