"""Batched ShiftAddViT serving driver — the paper's model behind a
shape-bucketed inference engine.

    python -m repro.launch.serve_vit --policy shiftadd
    python -m repro.launch.serve_vit --policy shiftadd --sweep

Default mode serves a stream of variable-size image requests through
`repro.serve.vision.BucketedViTEngine`: requests are padded into the bucket
batch sizes, every bucket is compiled exactly once at warmup, and steady-state
traffic never retraces (the driver asserts it). --sweep instead runs the same
pretrained dense weights through all conversion stages (dense / stage1 /
shiftadd) and writes BENCH_vit.json with per-policy latency, throughput and
analytic per-image energy.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.core.policy import DENSE
from repro.serve.vision import (DEFAULT_BUCKETS, BucketedViTEngine,
                                SWEEP_POLICIES, build_policy_model,
                                policy_sweep)
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve_vit")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="shiftadd",
                    choices=sorted(SWEEP_POLICIES))
    ap.add_argument("--sweep", action="store_true",
                    help="benchmark all policies and write BENCH_vit.json")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--buckets", type=int, nargs="+", default=None,
                    help="override the bucket set (default: the engine's "
                         "DEFAULT_BUCKETS; the effective set is read back "
                         "off the engine and logged)")
    ap.add_argument("--requests", type=int, default=64,
                    help="number of variable-size requests to stream")
    ap.add_argument("--image-size", type=int, default=32)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None,
                    help="force the kernel implementation (CI forces "
                         "interpret to run the Pallas kernel bodies on CPU)")
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json",
                    help="persisted autotune table "
                         "(repro.launch.autotune output)")
    ap.add_argument("--no-freeze", action="store_true",
                    help="serve live params instead of the deployment-frozen "
                         "DeployPlan (A/B arm; logits are bit-identical)")
    ap.add_argument("--out", default="BENCH_vit.json")
    args = ap.parse_args()

    # --impl threads explicitly to every engine (policy_sweep and the
    # streaming engine below), not via the old process-global
    # ops.set_default_impl override.
    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            log.warning("could not load tune table %s; serving with "
                        "default block caps", args.tune)

    cfg = ViTConfig(image_size=args.image_size, n_layers=args.layers,
                    d_model=args.d_model, d_ff=2 * args.d_model)

    if args.sweep:
        rec = policy_sweep(cfg, batch=args.batch, buckets=args.buckets,
                           freeze=not args.no_freeze, impl=args.impl,
                           tune=tune)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        for name, r in rec["policies"].items():
            log.info("%9s: %7.2f ms/batch %9.1f img/s %8.3f uJ/img "
                     "(recompiles=%d)", name,
                     r["latency_s_per_batch"] * 1e3, r["images_per_s"],
                     r["energy_pj_per_image"] / 1e6,
                     r["recompiles_after_warmup"])
        log.info("wrote %s", os.path.abspath(args.out))
        return

    dense_model = ShiftAddViT(dataclasses.replace(cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(0))
    model, params = build_policy_model(cfg, args.policy, dense_model,
                                       dense_params)
    engine = BucketedViTEngine(model, params,
                               buckets=args.buckets or DEFAULT_BUCKETS,
                               freeze=not args.no_freeze,
                               impl=args.impl, tune=tune).warmup()
    traces = engine.trace_count
    log.info("warmup: compiled %d bucket programs %s (frozen=%s%s)", traces,
             list(engine.buckets), engine.frozen,
             f", {engine.plan.frozen_linears} shift weights decoded"
             if engine.plan is not None else "")

    # Stream variable-size requests (sizes cycle over the bucket range).
    sizes = [(i % engine.buckets[-1]) + 1 for i in range(args.requests)]
    shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
    t0 = time.perf_counter()
    n_images = 0
    for i, n in enumerate(sizes):
        imgs = jax.random.normal(jax.random.PRNGKey(100 + i), (n,) + shape)
        jax.block_until_ready(engine.infer(imgs))
        n_images += n
    dt = time.perf_counter() - t0
    if engine.trace_count != traces:
        raise RuntimeError(
            f"bucketed serving retraced after warmup "
            f"({engine.trace_count - traces} extra compiles)")
    log.info("served %d requests (%d images) in %.3fs — %.1f img/s, "
             "0 recompiles after warmup (policy=%s)",
             args.requests, n_images, dt, n_images / dt, args.policy)


if __name__ == "__main__":
    main()
