"""Kernel autotune driver — search tile/packing/residency caps for every
serving site × bucket and persist the winners.

    python -m repro.launch.autotune                       # TUNE_kernels.json
    python -m repro.launch.autotune --measure off         # model-rank only
    python -m repro.launch.autotune --buckets 1 8 32 --image-size 56

The search (repro.kernels.autotune) is seeded and pruned by the contract
table (repro.analysis.kernel_contracts) and its roofline cost model; on a
TPU backend the model-ranked shortlist is wall-clock measured through the
real kernels.ops wrappers, elsewhere the model ranking decides and the
table's meta records why. The output feeds `--tune` on bench_vit.py /
bench_traffic.py / serve_vit / serve_traffic, which thread the table to
every kernel call at deployment-freeze time.
"""
from __future__ import annotations

import argparse
import os

from repro.kernels import autotune as at
from repro.nn.vit import ViTConfig
from repro.utils.logging import get_logger

log = get_logger("repro.launch.autotune")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--image-size", type=int, default=56,
                    help="56 → 196 tokens at patch 4 (DeiT-T-like, the "
                         "serving-benchmark geometry)")
    ap.add_argument("--patch-size", type=int, default=4)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=None,
                    help="default 2 × d_model (the benchmark convention)")
    ap.add_argument("--buckets", type=int, nargs="+", default=None,
                    help="serving bucket set to tune for (default: the "
                         "engine's DEFAULT_BUCKETS)")
    ap.add_argument("--measure", choices=["auto", "on", "off"],
                    default="auto",
                    help="wall-clock measure the shortlist through "
                         "kernels.ops (auto: only on a TPU backend; "
                         "off-TPU the contract-model ranking decides)")
    ap.add_argument("--iters", type=int, default=20,
                    help="timed iterations per measured candidate")
    ap.add_argument("--shortlist", type=int, default=6,
                    help="model-ranked candidates measured per site")
    ap.add_argument("--out", default="TUNE_kernels.json")
    args = ap.parse_args(argv)

    cfg = ViTConfig(image_size=args.image_size, patch_size=args.patch_size,
                    n_layers=args.layers, d_model=args.d_model,
                    n_heads=args.heads, d_ff=args.d_ff or 2 * args.d_model)
    measure = {"auto": None, "on": True, "off": False}[args.measure]
    table, report = at.autotune(cfg, buckets=args.buckets, measure=measure,
                                iters=args.iters, shortlist=args.shortlist)
    table.save(args.out, report=report)

    meta = table.meta_dict
    log.info("tuned %d geometries over buckets %s (%s)", len(table),
             meta.get("buckets"), meta.get("reason"))
    for row in report:
        if row.get("winner") is None:
            log.info("%-22s %-12s b=%-3s %s (%s)", row["kernel"],
                     row["site"], row["bucket"], row["classification"],
                     row.get("note", ""))
            continue
        speedup = (row["t_model_default_s"] / row["t_model_s"]
                   if row["t_model_s"] else 1.0)
        measured = (f"  measured={row['measured_s'] * 1e6:.1f}us"
                    if row.get("measured_s") is not None else "")
        log.info("%-22s %-12s b=%-3s caps=%s blocks=%s  model %.2fx vs "
                 "default  waste %.3f→%.3f%s",
                 row["kernel"], row["site"], row["bucket"], row["winner"],
                 row["winner_blocks"], speedup,
                 row["pad_mac_waste_default"], row["pad_mac_waste"],
                 measured)
    log.info("wrote %s", os.path.abspath(args.out))


if __name__ == "__main__":
    main()
