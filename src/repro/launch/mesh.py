"""Production meshes. Defined as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — DP across pods,
FSDP over data, TP/EP over model (DESIGN.md §3).
"""
from __future__ import annotations

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (host) devices tests have."""
    return make_mesh(shape, axes)
