"""Telemetry-trained router driver — extract per-expert serving latencies,
persist them, and fine-tune the MoE router against them.

    python -m repro.launch.tune_router                  # TELEMETRY_experts.json
    python -m repro.launch.tune_router --steps 30 --telemetry TELEMETRY_experts.json
    python -m repro.launch.tune_router --measure off --buckets 1 8 32

Pipeline (ROADMAP item 3, the serving-telemetry → router-training loop):

1. Build the shiftadd policy arm from seeded pretrained-dense weights (the
   same `build_policy_model` conversion every sweep uses — router zero-init,
   all tokens initially on the Mult expert).
2. Extract per-expert telemetry at serving geometry (`serve.telemetry`) —
   or reuse a persisted table via --telemetry (fail-open: absent/invalid
   falls back to extraction) — and save it to --out.
3. Apply the α latencies to the MoE feeds and fine-tune ONLY the router
   (`train.router_tune`, gradient-masked AdamW on the balance loss).
4. Report before/after loss and the frozen-engine expert token share (the
   PR-3 deploy freeze serves the eval), so the paper's claim — faster
   experts win more tokens — is visible in the log.

The persisted table feeds `--telemetry` on bench_traffic.py, whose router
arm re-runs steps 3-4 inside the virtual-clock sweep and is gated by
check_traffic.py (router p99 <= analytic-shiftadd p99, shift share up).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.utils.logging import get_logger

log = get_logger("repro.launch.tune_router")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--image-size", type=int, default=56,
                    help="56 → 196 tokens at patch 4 (DeiT-T-like, the "
                         "serving-benchmark geometry)")
    ap.add_argument("--patch-size", type=int, default=4)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=None,
                    help="default 2 × d_model (the benchmark convention)")
    ap.add_argument("--buckets", type=int, nargs="+", default=None,
                    help="serving bucket set to probe (default: the "
                         "engine's DEFAULT_BUCKETS)")
    ap.add_argument("--iters", type=int, default=5,
                    help="timed probe rounds per expert × bucket")
    ap.add_argument("--measure", choices=["auto", "on", "off"],
                    default="auto",
                    help="wall-clock α (auto: only on a TPU backend; "
                         "elsewhere the analytic model at serving geometry "
                         "decides and the table records why)")
    ap.add_argument("--steps", type=int, default=40,
                    help="router fine-tune steps")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=16,
                    help="fine-tune/eval image batch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default=None,
                    help="existing TELEMETRY_experts.json to reuse instead "
                         "of probing (fail-open: falls back to extraction)")
    ap.add_argument("--tune", default=None,
                    help="TUNE_kernels.json to thread through the frozen "
                         "probes (fail-open)")
    ap.add_argument("--out", default="TELEMETRY_experts.json")
    args = ap.parse_args(argv)

    from repro.core.policy import DENSE
    from repro.kernels.autotune import load_table
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve import telemetry as tm
    from repro.serve.vision import build_policy_model
    from repro.train.router_tune import finetune_report, router_finetune

    tune = None
    if args.tune:
        tune = load_table(args.tune)
        if tune is None:
            log.warning("tune table %s missing/invalid — default blocks",
                        args.tune)

    base_cfg = ViTConfig(image_size=args.image_size,
                         patch_size=args.patch_size, n_layers=args.layers,
                         d_model=args.d_model, n_heads=args.heads,
                         d_ff=args.d_ff or 2 * args.d_model)
    dense_model = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = dense_model.init(jax.random.PRNGKey(args.seed))
    model, params = build_policy_model(base_cfg, "shiftadd", dense_model,
                                       dense_params)

    telem = tm.load_telemetry(args.telemetry) if args.telemetry else None
    if telem is not None:
        log.info("reusing telemetry %s (mode=%s)", args.telemetry,
                 telem.mode)
    else:
        if args.telemetry:
            log.warning("telemetry %s missing/invalid — extracting fresh",
                        args.telemetry)
        measure = {"auto": None, "on": True, "off": False}[args.measure]
        telem = tm.extract_expert_telemetry(
            model, params, buckets=args.buckets, tune=tune,
            iters=args.iters, measure=measure)
    telem.save(args.out)

    meta = telem.meta_dict
    kinds = tuple(meta.get("expert_kinds", ("mult", "shift")))
    log.info("telemetry mode=%s backend=%s (%s)", meta.get("mode"),
             meta.get("backend"), meta.get("reason"))
    for kind in kinds:
        log.info("  %-6s alpha_lat=%.3e s  buckets=%s", kind,
                 dict(telem.alpha_latencies)[kind],
                 {b: f"{s:.2e}" for b, s in telem.bucket_seconds(kind).items()})

    shape = (base_cfg.image_size, base_cfg.image_size, base_cfg.in_channels)
    imgs = jax.random.normal(jax.random.PRNGKey(args.seed + 1),
                             (args.batch,) + shape)
    tm.apply_expert_latencies(model, telem)
    before = finetune_report(model, params, imgs, tune=tune)
    tuned, history = router_finetune(model, params, imgs, steps=args.steps,
                                     lr=args.lr)
    after = finetune_report(model, tuned, imgs, tune=tune)

    log.info("router fine-tune: %d steps, balance loss %.4f → %.4f",
             len(history), history[0], history[-1])
    log.info("expert token share (frozen-engine eval): %s → %s  caps=%s",
             before["expert_token_share"], after["expert_token_share"],
             after["capacities_per_image"])
    log.info("wrote %s", os.path.abspath(args.out))


if __name__ == "__main__":
    main()
