"""Trip-count-aware HLO cost analysis (the §Roofline 'profiler').

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE (verified
empirically: a scanned 8-layer stack reports 1/8 the flops of the unrolled
stack). Since this framework scans over layers / microbatches / KV chunks,
all roofline terms here are derived by parsing `compiled.as_text()` directly:

- FLOPs: every `dot` (and dot-fusions) → 2 · |result| · contracted-size,
  multiplied by the trip counts of every enclosing while loop. Elementwise
  flops are ignored (dots dominate ≥95% on these models; stated in
  EXPERIMENTS.md §Roofline).
- Collective bytes: operand bytes of all-reduce / all-gather / reduce-scatter
  / all-to-all / collective-permute, trip-multiplied.
- HBM traffic estimate: *write-once model* — every materialized buffer
  (instruction result) counts its bytes once, ×2 for the paired read;
  dynamic-update-slice counts only the updated region; fusion internals are
  VMEM-resident. Trip-multiplied. Biases relative to a real TPU lowering are
  documented in EXPERIMENTS.md §Roofline (CPU upcasts bf16 math to f32 and
  stacks scan intermediates for backward, both inflating this estimate), so
  the dominant-bottleneck call also consults the analytic model in
  benchmarks/roofline.py; this estimate is still the right *relative* signal
  between two lowerings of the same cell, which is what §Perf iterates on.

Trip counts: a while's condition region compares the induction variable
against an integer constant; we take the largest integer constant found in
the condition region (incl. called computations). Every loop this framework
emits (lax.scan) has this form.

Shapes come from a global name→type symbol table built from instruction
definitions and computation signatures, so operand sizes resolve across
regions. Post-SPMD HLO is the per-device program: all numbers are per-chip.

The HLO-text parsing layer (shape/instruction/computation grammar, the
name→type symbol table, the jax cost_analysis list-vs-dict compat) lives in
`repro.analysis.ir` and is shared with the serving-contract static analyzer
(`repro.analysis`); this module keeps only the roofline-specific cost model
(trip counts, dot/conv flops, the write-once byte model).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# Shared HLO grammar — re-exported so existing consumers (tests, notebooks)
# keep importing them from here.
from repro.analysis.ir import (Computation, Instr, nbytes as _nbytes,  # noqa: F401
                               operand_names as _operand_names,
                               parse_hlo, parse_shapes as _parse_shapes,
                               symbol_table as _symbol_table,
                               xla_cost_dict, CALLS_RE as _CALLS_RE)

_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _dot_flops(ins: Instr, table) -> float:
    res = _parse_shapes(ins.result_type)
    if not res:
        return 0.0
    _, rshape = res[0]
    out_elems = 1
    for d in rshape:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    ops = _operand_names(ins.rest)
    contracted = 1
    if m and ops:
        lhs_type = table.get(ops[0], "")
        shapes = _parse_shapes(lhs_type)
        if shapes:
            _, lshape = shapes[0]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lshape):
                    contracted *= lshape[idx]
    return 2.0 * out_elems * contracted


def _conv_flops(ins: Instr, table) -> float:
    # flops ≈ 2 * |out| * (kernel spatial * in_features) — derive from window.
    res = _parse_shapes(ins.result_type)
    if not res:
        return 0.0
    _, rshape = res[0]
    out_elems = 1
    for d in rshape:
        out_elems *= d
    ops = _operand_names(ins.rest)
    k_elems = 1
    if len(ops) >= 2:
        kshapes = _parse_shapes(table.get(ops[1], ""))
        if kshapes:
            _, kshape = kshapes[0]
            for d in kshape:
                k_elems *= d
            # divide by output-feature dim (counted in out_elems)
            if kshape:
                k_elems //= max(kshape[-1], 1)
    return 2.0 * out_elems * k_elems


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "after-all", "partition-id", "replica-id",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_breakdown.items():
            self.collective_breakdown[k] = self.collective_breakdown.get(k, 0.0) + v
        return self

    def scaled(self, f):
        return Cost(self.flops * f, self.bytes * f, self.collective_bytes * f,
                    {k: v * f for k, v in self.collective_breakdown.items()})


def _trip_count(cond_name, comps) -> int:
    """Trip count of a while from its condition region.

    lax.scan lowers to `compare(induction_var, constant(N), LT)` — the
    constant may be a direct compare operand or threaded through a fusion.
    We locate the ROOT of the condition region, resolve its constant
    operands (following one fusion hop), and take the max. Falls back to
    the max constant anywhere in the region."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1

    local_defs = {ins.name: ins for ins in comp.instrs}

    def const_of(name):
        ins = local_defs.get(name)
        if ins is None:
            return None
        m = _CONST_RE.search(ins.line)
        return int(m.group(1)) if m else None

    candidates = []
    root = None
    for ins in comp.instrs:
        if ins.line.strip().startswith("ROOT"):
            root = ins
    if root is not None:
        frontier = [root]
        for hop in range(2):
            nxt = []
            for ins in frontier:
                for o in _operand_names(ins.rest):
                    c = const_of(o)
                    if c is not None:
                        candidates.append(c)
                    elif o in local_defs and local_defs[o].op in ("fusion", "compare", "call"):
                        nxt.append(local_defs[o])
            frontier = nxt
    if candidates:
        return max(candidates)
    best = 1
    for ins in comp.instrs:
        for c in _CONST_RE.findall(ins.line):
            best = max(best, int(c))
    return best


def analyze(text: str) -> Cost:
    comps = parse_hlo(text)
    table = _symbol_table(comps)
    memo: Dict[str, Cost] = {}

    entry = None
    for name in comps:
        if ".entry" in name or name.endswith("main") or "main" in name:
            entry = name
            break
    if entry is None:  # fall back: computation not referenced by any other
        called = set()
        for c in comps.values():
            for ins in c.instrs:
                called.update(_CALLS_RE.findall(ins.rest))
        candidates = [n for n in comps if n not in called]
        entry = candidates[0] if candidates else next(iter(comps))

    def comp_cost(name) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        total = Cost()
        comp = comps.get(name)
        if comp is None:
            return total
        for ins in comp.instrs:
            total += instr_cost(ins)
        memo[name] = total
        return total

    def instr_cost(ins: Instr) -> Cost:
        c = Cost()
        op = ins.op
        if op == "while":
            m_body = re.search(r"body=%?([\w\.\-]+)", ins.rest)
            m_cond = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
            trips = _trip_count(m_cond.group(1), comps) if m_cond else 1
            inner = comp_cost(m_body.group(1)) if m_body else Cost()
            return inner.scaled(trips)
        if op == "conditional":
            inner = Cost()
            for callee in _CALLS_RE.findall(ins.rest):
                inner += comp_cost(callee)
            return inner
        if op in ("call", "fusion", "custom-call"):
            for callee in _CALLS_RE.findall(ins.rest):
                inner = comp_cost(callee)
                if op == "fusion":
                    # Fusion internals are register/VMEM-resident: take the
                    # compute and collectives, not the per-op byte counts.
                    c += Cost(flops=inner.flops,
                              collective_bytes=inner.collective_bytes,
                              collective_breakdown=dict(inner.collective_breakdown))
                else:
                    c += inner
            c.bytes += 2 * _nbytes(ins.result_type)   # write-once model
            return c
        if op == "dot":
            c.flops += _dot_flops(ins, table)
            c.bytes += 2 * _nbytes(ins.result_type)
            return c
        if op == "convolution":
            c.flops += _conv_flops(ins, table)
            c.bytes += 2 * _nbytes(ins.result_type)
            return c
        if any(op.startswith(col) for col in COLLECTIVE_OPS):
            opbytes = sum(_nbytes(table.get(o, "")) for o in _operand_names(ins.rest))
            if opbytes == 0:
                opbytes = _nbytes(ins.result_type)
            c.collective_bytes += opbytes
            kind = next(col for col in COLLECTIVE_OPS if op.startswith(col))
            c.collective_breakdown[kind] = c.collective_breakdown.get(kind, 0.0) + opbytes
            c.bytes += opbytes + _nbytes(ins.result_type)
            return c
        if op in _SKIP_BYTES_OPS:
            return c
        if op in ("dynamic-update-slice", "scatter"):
            # Reads+writes only the update region (buffer aliased in place).
            ops = _operand_names(ins.rest)
            upd = _nbytes(table.get(ops[1], "")) if len(ops) > 1 else 0
            c.bytes += 2 * upd
            return c
        # generic op (copy, reduce, select, dynamic-slice, gather, ...):
        # write-once — count the materialized result, ×2 for the paired read.
        c.bytes += 2 * _nbytes(ins.result_type)
        return c

    return comp_cost(entry)
