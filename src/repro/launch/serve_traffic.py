"""Traffic-serving driver: seeded request traffic through the SLO-aware
micro-batch scheduler and replicated frozen ShiftAddViT engines.

    python -m repro.launch.serve_traffic --scenario poisson --policy shiftadd --replicas 2
    python -m repro.launch.serve_traffic --scenario bursty --policy all --target-p99 400
    python -m repro.launch.serve_traffic --scenario diurnal --arm thread --verify-replay

A seeded trace (`--scenario poisson|bursty|diurnal`, `--requests`, `--seed`)
of variable-size, deadline-classed image requests is pushed through the
fill-or-deadline micro-batch scheduler onto `--replicas` engine replicas
(`--arm thread` on CPU, `--arm sharded` data-parallel on multi-device
backends, `auto` picks). Arrival rate and deadline budgets are calibrated
from the measured per-bucket service times at `--utilization` of replica
capacity, so the default load is feasible by construction and the virtual
timeline is machine-independent up to the calibration. Writes
BENCH_traffic.json (per-policy p50/p95/p99 latency, goodput, deadline-miss
rate, padding waste, dispatch reasons, recompile count) and exits non-zero
if any bucket program recompiled after warmup.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.nn.vit import ViTConfig
from repro.serve.frontend import traffic_sweep
from repro.serve.traffic import SCENARIOS
from repro.serve.vision import SWEEP_POLICIES
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve_traffic")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="poisson", choices=SCENARIOS)
    ap.add_argument("--policy", default="shiftadd",
                    choices=sorted(SWEEP_POLICIES) + ["all"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--arm", default="auto",
                    choices=["auto", "thread", "sharded"])
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--utilization", type=float, default=0.4,
                    help="offered load as a fraction of measured replica "
                         "capacity (the calibrated default load)")
    ap.add_argument("--target-p99", type=float, default=None, metavar="MS",
                    help="SLO target: sets the interactive deadline budget "
                         "(ms) and reports p99 attainment against it")
    ap.add_argument("--slack-frac", type=float, default=0.5,
                    help="deadline-safety dispatch threshold, in units of "
                         "the max-bucket service time")
    ap.add_argument("--linger-frac", type=float, default=1.0,
                    help="padding-tradeoff wait cap (fill-or-deadline "
                         "policy knob), in max-bucket service times")
    ap.add_argument("--max-queue-images", type=int, default=None,
                    help="admission-control bound (default 8 × max bucket)")
    ap.add_argument("--buckets", type=int, nargs="+", default=None,
                    help="override the engine bucket set (default: the "
                         "engine's DEFAULT_BUCKETS; the effective set is "
                         "read back off the engine)")
    ap.add_argument("--image-size", type=int, default=56,
                    help="56 → 196 tokens at patch 4 (DeiT-T-like)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None)
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json",
                    help="persisted autotune table "
                         "(repro.launch.autotune output)")
    ap.add_argument("--no-freeze", action="store_true",
                    help="serve live params instead of the DeployPlan")
    ap.add_argument("--verify-replay", action="store_true",
                    help="serve the trace twice and check routing + logits "
                         "replay bit-identically")
    ap.add_argument("--verify-one-vs-n", action="store_true",
                    help="re-serve the trace on a one-slot pool and check "
                         "per-request logits are bit-identical despite the "
                         "diverging batch compositions (the batch-"
                         "invariance contract, every policy arm)")
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args(argv)

    # --impl threads explicitly (traffic_sweep → replicas → engines), not
    # via the old process-global ops.set_default_impl override.
    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            log.warning("could not load tune table %s; serving with "
                        "default block caps", args.tune)

    cfg = ViTConfig(image_size=args.image_size, n_layers=args.layers,
                    d_model=args.d_model, d_ff=2 * args.d_model)
    policies = (tuple(sorted(SWEEP_POLICIES)) if args.policy == "all"
                else (args.policy,))
    rec = traffic_sweep(
        cfg, scenario=args.scenario, policies=policies,
        n_requests=args.requests, seed=args.seed, replicas=args.replicas,
        arm=args.arm, utilization=args.utilization, buckets=args.buckets,
        freeze=not args.no_freeze, impl=args.impl, tune=tune,
        slack_frac=args.slack_frac, linger_frac=args.linger_frac,
        max_queue_images=args.max_queue_images,
        target_p99_s=None if args.target_p99 is None
        else args.target_p99 / 1e3,
        verify_replay=args.verify_replay,
        verify_one_vs_n=args.verify_one_vs_n)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    recompiled = False
    for name, r in rec["policies"].items():
        lat = r["latency"]
        log.info(
            "%9s [%s x%d]: p50 %6.1f ms  p95 %6.1f ms  p99 %6.1f ms  "
            "goodput %7.1f img/s  miss %.3f  shed %d  waste %.3f  "
            "batches %d (%s)  recompiles %d",
            name, r["arm"], r["replicas"], lat["p50_s"] * 1e3,
            lat["p95_s"] * 1e3, lat["p99_s"] * 1e3,
            r["goodput_images_per_s"], r["deadline_miss_rate"],
            r["shed_requests"], r["padding_waste"], r["batches"],
            ",".join(f"{k}={v}" for k, v in
                     sorted(r["dispatch_reasons"].items())),
            r["recompiles_after_warmup"])
        if "replay_identical_routing" in r:
            log.info("%9s: replay identical routing=%s, bit-identical "
                     "logits=%s", name, r["replay_identical_routing"],
                     r["replay_bit_identical_logits"])
        if "one_vs_n_bit_identical_logits" in r:
            log.info("%9s: 1-vs-N bit-identical logits=%s (batches "
                     "diverged=%s)", name,
                     r["one_vs_n_bit_identical_logits"],
                     r["one_vs_n_diverged_batches"])
        recompiled |= r["recompiles_after_warmup"] > 0
    if rec.get("shiftadd_vs_dense_p99") is not None:
        log.info("shiftadd vs dense p99: %.3fx", rec["shiftadd_vs_dense_p99"])
    log.info("wrote %s", os.path.abspath(args.out))
    if recompiled:
        raise SystemExit("bucket programs recompiled after warmup")


if __name__ == "__main__":
    main()
