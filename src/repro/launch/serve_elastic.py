"""Elastic-serving driver: diurnal traffic through the autoscaling /
failure-injection / graceful-degradation control plane (serve.elastic).

    python -m repro.launch.serve_elastic --scenario diurnal --requests 220
    python -m repro.launch.serve_elastic --max-replicas 3 --spares 2
    python -m repro.launch.serve_elastic --no-faults --utilization 1.3

Runs the two-arm comparison elastic_sweep defines: a FIXED min-replica
baseline (which must saturate at the diurnal peak) against the elastic
control plane (warm-pool autoscaling between --min-replicas and
--max-replicas, a replica kill at --kill-at and a straggler slowdown at
--slowdown-at of the virtual horizon, dense→shiftadd degradation per
deadline class when the pool saturates). Writes BENCH_elastic.json and
exits non-zero if the elastic arm missed a deadline, anything recompiled
after warmup, or the seeded replay diverged — the same conditions
benchmarks/check_elastic.py gates in CI.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.nn.vit import ViTConfig
from repro.serve.elastic import elastic_sweep
from repro.serve.traffic import SCENARIOS
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve_elastic")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="diurnal", choices=SCENARIOS)
    ap.add_argument("--requests", type=int, default=220)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=2)
    ap.add_argument("--spares", type=int, default=2,
                    help="pre-warmed engines beyond max-replicas (failure "
                         "headroom; all compiled at warmup)")
    ap.add_argument("--arm", default="thread", choices=["thread", "sharded"],
                    help="sharded pins each reserve engine to its own "
                         "device (needs >= max-replicas + spares devices)")
    ap.add_argument("--utilization", type=float, default=1.15,
                    help="offered load / min-replica capacity; > 1 so the "
                         "fixed baseline misses at the peak")
    ap.add_argument("--kill-at", type=float, default=0.35, metavar="FRAC")
    ap.add_argument("--slowdown-at", type=float, default=0.6, metavar="FRAC")
    ap.add_argument("--slowdown-factor", type=float, default=4.0)
    ap.add_argument("--no-faults", action="store_true")
    ap.add_argument("--image-size", type=int, default=56)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None)
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json")
    ap.add_argument("--no-verify-replay", action="store_true")
    ap.add_argument("--out", default="BENCH_elastic.json")
    args = ap.parse_args(argv)

    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            log.warning("could not load tune table %s; serving with "
                        "default block caps", args.tune)

    cfg = ViTConfig(image_size=args.image_size, n_layers=args.layers,
                    d_model=args.d_model, d_ff=2 * args.d_model)
    rec = elastic_sweep(
        cfg, scenario=args.scenario, n_requests=args.requests,
        seed=args.seed, min_replicas=args.min_replicas,
        max_replicas=args.max_replicas, spares=args.spares, arm=args.arm,
        utilization=args.utilization, impl=args.impl, tune=tune,
        kill_at_frac=None if args.no_faults else args.kill_at,
        slowdown_at_frac=None if args.no_faults else args.slowdown_at,
        slowdown_factor=args.slowdown_factor,
        verify_replay=not args.no_verify_replay)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    for arm in ("baseline", "elastic"):
        r = rec[arm]
        log.info("%9s: p50 %6.1f ms  p99 %6.1f ms  miss %.3f  shed %d  "
                 "recompiles %d", arm, r["latency"]["p50_s"] * 1e3,
                 r["latency"]["p99_s"] * 1e3, r["deadline_miss_rate"],
                 r["shed_requests"], r["recompiles_after_warmup"])
    e = rec["elastic"]
    log.info("elastic: ups %d downs %d kills %d evictions %d recoveries %d "
             "degraded %d max_active %d replica_s %.1f",
             e["scale_ups"], e["scale_downs"], e["kills"],
             e["straggler_evictions"], e["recoveries"],
             e["degraded_requests"], e["max_active"], e["replica_seconds"])
    if "replay_identical_events" in rec:
        log.info("replay: events=%s logits=%s",
                 rec["replay_identical_events"],
                 rec["replay_bit_identical_logits"])
    log.info("wrote %s", os.path.abspath(args.out))

    bad = []
    if e["deadline_miss_rate"] > 0:
        bad.append("elastic arm missed deadlines")
    if rec["recompiles_after_warmup"] > 0:
        bad.append("programs recompiled after warmup")
    if not rec.get("replay_identical_events", True) \
            or not rec.get("replay_bit_identical_logits", True):
        bad.append("seeded replay diverged")
    if bad:
        raise SystemExit("; ".join(bad))


if __name__ == "__main__":
    main()
