"""Production serving driver: parallel prefill + scan-fused batched decode.

    python -m repro.launch.serve --arch yi-9b --policy shiftadd_deploy \
        --reduced --batch 4 --prompt-len 64 --new-tokens 32

The prompt is consumed in one chunked prefill pass (the Q(KᵀV) linear order
makes it O(P)); decode then runs as a single fused lax.scan over the O(1)
linear-attention state (no KV cache under the ShiftAdd policies). Prefill and
decode throughput are reported separately — they are different regimes
(compute-bound vs latency/memory-bound) and regress independently.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.nn.model import LanguageModel
from repro.serve.decode import make_decode_loop, make_prefill
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--policy", default="dense",
                    choices=["dense", "shiftadd", "shiftadd_deploy", "stage1"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, policy=args.policy, reduced=args.reduced)
    cfg = cfg.replace(moe_primitives_capacity=2.0)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    b, p = prompts.shape
    max_len = p + args.new_tokens

    # Phase-split timing: jit'd parallel prefill, then the fused decode scan.
    prefill = jax.jit(make_prefill(model), donate_argnums=(2,))
    loop = jax.jit(make_decode_loop(model, args.temperature),
                   donate_argnums=(2,))
    t0 = time.perf_counter()
    logits_all, cache = prefill(params, prompts, model.init_cache(b, max_len))
    logits0 = jax.block_until_ready(logits_all[:, -1])
    t1 = time.perf_counter()
    if args.temperature > 0.0:
        keys = jax.random.split(jax.random.PRNGKey(2), args.new_tokens)
    else:
        keys = jnp.zeros((args.new_tokens, 2), jnp.uint32)
    toks, _ = loop(params, logits0, cache, keys)
    toks = jax.block_until_ready(toks)
    t2 = time.perf_counter()

    log.info("prefill: %d prompt tokens in %.3fs (%.1f tok/s incl. compile)",
             b * p, t1 - t0, b * p / (t1 - t0))
    log.info("decode: %d tokens in %.3fs (%.1f tok/s incl. compile, "
             "policy=%s)", b * args.new_tokens, t2 - t1,
             b * args.new_tokens / (t2 - t1), args.policy)
    print(jnp.asarray(toks)[:2])


if __name__ == "__main__":
    main()
