"""Production serving driver: batched autoregressive decode.

    python -m repro.launch.serve --arch yi-9b --policy shiftadd_deploy \
        --reduced --batch 4 --new-tokens 32

The decode step is the same unit the decode dry-run cells lower; under the
ShiftAdd policies it runs on O(1) linear-attention state (no KV cache).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.nn.model import LanguageModel
from repro.serve.decode import generate
from repro.utils.logging import get_logger

log = get_logger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--policy", default="dense",
                    choices=["dense", "shiftadd", "shiftadd_deploy", "stage1"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, policy=args.policy, reduced=args.reduced)
    cfg = cfg.replace(moe_primitives_capacity=2.0)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, args.new_tokens,
                   temperature=args.temperature, rng=jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    log.info("generated %d tokens in %.2fs (%.1f tok/s, policy=%s)",
             total, dt, total / dt, args.policy)
    print(jnp.asarray(out)[:, args.prompt_len:][:2])


if __name__ == "__main__":
    main()
