"""Deterministic synthetic data pipelines (sharded, restart-reproducible).

Batches are a pure function of (seed, step) — a restart from checkpoint step k
regenerates exactly the batches k, k+1, ... that the failed run would have
seen (the data-side half of fault tolerance). `shard_batch` places global
arrays on the mesh with batch sharded over (pod, data).

SyntheticLMData: Zipf-ish token stream with a learnable bigram structure
(next-token depends on current token + a fixed random permutation), so losses
actually *decrease* during the end-to-end examples.

SyntheticImageData: K-class images where each class plants a distinctive
patch-template at a random location over background noise — object tokens vs
background tokens, which is exactly the structure the paper's MoE router is
hypothesized to discover (Fig. 6); used by the paper-validation benchmarks.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_batch(batch, mesh=None):
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, batch)
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]

    def put(x):
        spec = P(tuple(axes)) if x.ndim >= 1 else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, batch)


class SyntheticLMData:
    def __init__(self, vocab_size, seq_len, global_batch, seed=0,
                 input_mode="tokens", d_model=None, mrope=False):
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.batch = int(global_batch)
        self.seed = seed
        self.input_mode = input_mode
        self.d_model = d_model
        self.mrope = mrope
        rng = np.random.default_rng(seed)
        # Fixed learnable structure: token t follows perm[t] w.p. 0.8.
        self.perm = rng.permutation(self.vocab)

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        follow = rng.random((self.batch, self.seq)) < 0.8
        noise = rng.integers(0, self.vocab, (self.batch, self.seq))
        for t in range(1, self.seq + 1):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(follow[:, t - 1], nxt, noise[:, t - 1])
        batch = {"labels": toks[:, 1:].astype(np.int32)}
        if self.input_mode == "tokens":
            batch["inputs"] = toks[:, :-1].astype(np.int32)
        else:
            emb = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32)
            batch["inputs"] = emb
        pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                              (self.batch, self.seq)).copy()
        if self.mrope:
            pos = np.broadcast_to(pos[:, None], (self.batch, 3, self.seq)).copy()
        batch["positions"] = pos
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticImageData:
    def __init__(self, image_size=32, n_classes=10, global_batch=64, seed=0,
                 patch=8, noise=0.4):
        self.hw = image_size
        self.k = n_classes
        self.batch = global_batch
        self.seed = seed
        self.patch = patch
        self.noise = noise
        rng = np.random.default_rng(seed)
        # One distinctive template per class (the "object").
        self.templates = rng.standard_normal(
            (n_classes, patch, patch, 3)).astype(np.float32)

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        labels = rng.integers(0, self.k, self.batch).astype(np.int32)
        imgs = self.noise * rng.standard_normal(
            (self.batch, self.hw, self.hw, 3)).astype(np.float32)
        lim = self.hw - self.patch
        ys = rng.integers(0, lim + 1, self.batch)
        xs = rng.integers(0, lim + 1, self.batch)
        for i in range(self.batch):
            imgs[i, ys[i]:ys[i] + self.patch, xs[i]:xs[i] + self.patch] += \
                self.templates[labels[i]]
        return {"images": imgs, "labels": labels,
                "object_yx": np.stack([ys, xs], 1).astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
