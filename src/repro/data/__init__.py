from repro.data.pipeline import SyntheticLMData, SyntheticImageData, shard_batch
