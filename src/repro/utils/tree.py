"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total nbytes of a pytree (works for ShapeDtypeStructs too)."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        shape = getattr(l, "shape", ())
        dtype = np.dtype(getattr(l, "dtype", np.float32))
        total += int(np.prod(shape)) * dtype.itemsize
    return total


def tree_cast(tree, dtype):
    """Cast every inexact-dtype leaf of a pytree to `dtype`."""

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def named_flatten(tree, prefix=""):
    """Flatten a nested-dict pytree into (dotted_name, leaf) pairs."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.extend(named_flatten(tree[k], f"{prefix}{k}." if prefix or True else k))
    else:
        out.append((prefix[:-1] if prefix.endswith(".") else prefix, tree))
    return out
