from repro.utils.tree import (
    tree_size_bytes,
    tree_num_params,
    tree_cast,
    tree_zeros_like,
    named_flatten,
)
from repro.utils.logging import get_logger
