"""Minimal structured logging (single-process friendly, multi-host aware)."""
from __future__ import annotations

import logging
import os
import sys

_FMT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
        logger.setLevel(getattr(logging, level, logging.INFO))
        logger.propagate = False
    return logger
