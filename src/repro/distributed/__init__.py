from repro.distributed.sharding import (
    LOGICAL_AXIS_RULES,
    logical_to_pspec,
    shardings_from_spec,
    batch_sharding,
    replicated,
)
from repro.distributed.collectives import compressed_psum
