"""Logical-axis sharding rules → mesh PartitionSpecs (DESIGN.md §3).

Model modules annotate parameters with logical axis names via `.spec()`;
this module maps them onto the production mesh:

    batch   → (pod, data)   activations / inputs (DP)
    embed   → data          FSDP weight shard of d_model dims
    vocab, heads, mlp, experts → model   (TP / EP)
    layers  → (replicated)  scan-stacked depth dim

Params are therefore sharded over BOTH data (FSDP) and model (TP) inside a
pod and replicated across pods (gradients all-reduce over `pod`). A logical
axis maps to nothing if its mesh axis is absent (single-pod mesh has no
`pod`) or if the dim is smaller than the mesh axis (e.g. kv_heads=1 MQA).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- jax version compatibility ---------------------------------------------
# The suite spans jax 0.4.x (no mesh axis_types, no jax.shard_map,
# AbstractMesh((name, size), ...) pairs) and current jax (axis_types on
# make_mesh, jax.shard_map, AbstractMesh(shape, names)). Every mesh/shard_map
# construction in src/ and tests/ goes through these three helpers.

def make_mesh(shape, axes, *, devices=None):
    """jax.make_mesh across versions; Auto axis_types where supported."""
    kwargs = {} if devices is None else {"devices": devices}
    try:
        return jax.make_mesh(
            shape, axes, **kwargs,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (TypeError, AttributeError):
        return jax.make_mesh(shape, axes, **kwargs)


def make_abstract_mesh(shape, axes):
    """jax.sharding.AbstractMesh across versions (carries shape/axis_names
    without real devices — used by spec tests)."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:                       # jax<=0.4.x: (name, size) pairs
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (new) or jax.experimental.shard_map (0.4.x)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


LOGICAL_AXIS_RULES = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "seq": ("data",),
    # Sequence-parallel fallback: used by attention internals so that archs
    # whose head count doesn't divide the model axis (e.g. 40 heads on 16)
    # still shard their O(N·chunk) score buffers — over the query length.
    "seq_model": ("model",),
    # §Perf experiment: weights FSDP-sharded on the OUT dim over (data,model)
    # with the contraction dim unsharded (avoids per-layer contraction
    # all-reduces over data; GSPMD gathers the weight shard instead).
    "fsdp_out": ("data", "model"),
    "layers": (),
    None: (),
}


def spec_to_out_fsdp(spec_tree):
    """Rewrite 2D linear specs (in→data, out→model) to (None, fsdp_out)."""
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(a, str) or a is None
                                            for a in x)

    def one(axes):
        a = tuple(axes)
        core = a[-2:] if len(a) >= 2 else a
        if len(a) >= 2 and core[0] == "embed" and core[1] in (
                "heads", "mlp", "vocab", "kv_heads"):
            return a[:-2] + (None, "fsdp_out")
        return a

    return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_axes)


def spec_to_tp_zero1(spec_tree):
    """TP + ZeRO-1: drop the data-axis (embed) shard from weight matrices so
    contractions never partial-sum over `data` (no per-layer per-microbatch
    activation all-reduces). Weights are then replicated over data; the
    optimizer state keeps the full (data, model) shard (ZeRO-1) — dryrun
    passes the original spec for m/v. Embedding tables keep their vocab
    shard (gathers don't contract)."""
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(a, str) or a is None
                                            for a in x)

    def one(axes):
        a = tuple(axes)
        if len(a) >= 2 and a == ("vocab", "embed"):
            return a                       # embedding table: keep
        return tuple(None if x == "embed" else x for x in a)

    return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_axes)


def _mesh_axes_for(logical, mesh, dim_size=None, used=()):
    axes = LOGICAL_AXIS_RULES.get(logical, ())
    present = tuple(a for a in axes if a in mesh.axis_names and a not in used)
    if not present:
        return None
    total = math.prod(mesh.shape[a] for a in present)
    if dim_size is not None and dim_size % total != 0:
        # Uneven shard: prefer dropping axes (right-to-left) until divisible;
        # fall back to replication. Keeps GSPMD away from padded shards on
        # dims like kv_heads=1 or odd vocab sizes.
        while present:
            total = math.prod(mesh.shape[a] for a in present)
            if dim_size % total == 0:
                break
            present = present[:-1]
        if not present:
            return None
    return present if len(present) > 1 else present[0]


def logical_to_pspec(axes, mesh, shape=None):
    """axes: tuple of logical names (len == rank). shape optional for
    divisibility-aware fallback. A mesh axis is used at most once — later
    dims lose (enables 'shard heads if divisible, else the seq dim' specs)."""
    entries = []
    used = []
    for i, name in enumerate(axes):
        dim = None if shape is None else shape[i]
        e = _mesh_axes_for(name, mesh, dim, used=tuple(used))
        if e is not None:
            used.extend(e if isinstance(e, tuple) else (e,))
        entries.append(e)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_from_spec(spec_tree, shape_tree, mesh):
    """Map a logical-axis spec tree + matching shape tree (arrays or
    ShapeDtypeStructs) to NamedShardings."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, str) or a is None for a in x)

    def one(axes, arr):
        return NamedSharding(mesh, logical_to_pspec(axes, mesh, arr.shape))

    return jax.tree_util.tree_map(one, spec_tree, shape_tree, is_leaf=is_axes)


def batch_sharding(mesh, rank=2, extra=None):
    """Inputs: leading dim over (pod, data); rest replicated.
    extra: logical names for trailing dims."""
    axes = ["batch"] + [None] * (rank - 1)
    if extra:
        axes[1:1 + len(extra)] = list(extra)
    return NamedSharding(mesh, logical_to_pspec(tuple(axes), mesh))


def replicated(mesh):
    return NamedSharding(mesh, P())


_ACTIVE_MESH = None


def set_active_mesh(mesh):
    """Declare the mesh used by subsequent traces so `constrain` can resolve
    logical activation shardings (dryrun/train set this; tests leave None)."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def constrain(x, axes, mesh=None):
    """Activation sharding constraint by logical names (no-op outside mesh)."""
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, logical_to_pspec(axes, mesh, x.shape))
    except Exception:
        return x
