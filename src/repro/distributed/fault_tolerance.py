"""Fault tolerance & straggler mitigation hooks (DESIGN.md §3).

On a real multi-pod deployment the failure signal is a NCCL/ICI timeout or a
coordinator heartbeat loss; here the same control flow is exercised through
`FailureInjector` (tests raise at a chosen step) and the train loop's
catch → restore-from-checkpoint → replay path. The pieces:

- FailureInjector: deterministic failure at step k (or probabilistic).
- StragglerMonitor: per-step wall-time watermarks; steps slower than
  `threshold ×` the running median are flagged (the mitigation at scale is
  re-scheduling the slow host's data shard / evicting the host; the monitor
  is the detector both would share).
- elastic_mesh_shape: given the surviving chip count, pick the largest mesh
  this framework's sharding rules can use (power-of-two data axis, fixed
  model axis), for restart-with-fewer-chips (elastic scaling).
"""
from __future__ import annotations

import time


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps=(), rng=None, prob=0.0):
        self.fail_at = set(fail_at_steps)
        self.prob = prob
        self.rng = rng
        self._fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.prob and self.rng is not None and self.rng.random() < self.prob:
            raise SimulatedFailure(f"random injected failure at step {step}")


class StragglerMonitor:
    def __init__(self, threshold=2.0, window=32):
        self.threshold = threshold
        self.window = window
        self.times = []
        self.flagged = []

    def record(self, step: int, seconds: float):
        self.times.append(seconds)
        recent = sorted(self.times[-self.window:])
        median = recent[len(recent) // 2]
        if len(self.times) >= 5 and seconds > self.threshold * median:
            self.flagged.append((step, seconds, median))
            return True
        return False


class StepTimer:
    def __init__(self):
        self.t0 = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def elastic_mesh_shape(n_devices: int, model_parallel: int = 16,
                       multi_pod: bool = False):
    """Largest mesh expressible with the surviving devices.

    Keeps the model axis fixed (parameter shardings stay valid) and shrinks
    the data/pod axes — restart resizes only the batch sharding.
    """
    if n_devices < model_parallel:
        # Degenerate survival: shrink model axis to the largest divisor.
        m = 1
        while m * 2 <= n_devices:
            m *= 2
        return ((1, m) if not multi_pod else (1, 1, m),
                ("data", "model") if not multi_pod else ("pod", "data", "model"))
    rest = n_devices // model_parallel
    if multi_pod and rest >= 2:
        pods = 2
        data = rest // pods
        return (pods, data, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")
