"""Fault tolerance & straggler mitigation hooks (DESIGN.md §3).

On a real multi-pod deployment the failure signal is a NCCL/ICI timeout or a
coordinator heartbeat loss; here the same control flow is exercised through
`FailureInjector` (tests raise at a chosen step) and the train loop's
catch → restore-from-checkpoint → replay path. The pieces:

- FailureInjector: deterministic failure at step k (or probabilistic) for
  the TRAIN loop, plus a virtual-time fault schedule (`ReplicaFault`) for
  the SERVING control plane (serve.elastic): kill or slow a replica at a
  chosen virtual-clock time mid-trace, deterministically.
- StragglerMonitor: wall-time watermarks; samples slower than `threshold ×`
  the running median are flagged (the mitigation at scale is re-scheduling
  the slow host's data shard / evicting the host; the monitor is the
  detector both paths share — the serving path feeds it per-batch
  actual/nominal service ratios so mixed bucket sizes don't skew the
  median).
- elastic_mesh_shape: given the surviving chip count, pick the largest mesh
  this framework's sharding rules can use (power-of-two data axis, fixed
  model axis), for restart-with-fewer-chips (elastic scaling).
"""
from __future__ import annotations

import dataclasses
import time


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """One injected serving fault, scheduled on the VIRTUAL clock.

    `slot` indexes the serving pool's *active replica list* at fire time
    (not a raw engine id), so a fault plan stays meaningful whatever the
    autoscaler did before it fires — the same seeded run always kills the
    same replica at the same virtual second, which is what makes injected
    failures replayable bit-for-bit.
    """
    at_s: float            # virtual fire time
    kind: str              # "kill" | "slowdown"
    slot: int = 0          # index into the active replica list at fire time
    factor: float = 4.0    # service-time multiplier (kind="slowdown")

    def __post_init__(self):
        assert self.kind in ("kill", "slowdown"), self.kind


class FailureInjector:
    """Deterministic failure injection for both execution modes.

    Train loop: `maybe_fail(step)` raises SimulatedFailure at the chosen
    steps (or probabilistically) — the catch/restore/replay path's trigger.
    Serving: construct with `faults=(ReplicaFault(...), ...)` and poll
    `due(now)` / `next_fault_s()` from the virtual-clock event loop — faults
    fire in (at_s, slot) order, each exactly once, and `fired` records the
    sequence for the replay signature.
    """

    def __init__(self, fail_at_steps=(), rng=None, prob=0.0, faults=()):
        self.fail_at = set(fail_at_steps)
        self.prob = prob
        self.rng = rng
        self._fired = set()
        self.faults = tuple(sorted(faults, key=lambda f: (f.at_s, f.slot)))
        self.fired = []
        self._next = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.prob and self.rng is not None and self.rng.random() < self.prob:
            raise SimulatedFailure(f"random injected failure at step {step}")

    # -- virtual-time serving API -------------------------------------------

    def next_fault_s(self):
        """Fire time of the next unfired fault (None when exhausted) — an
        event-loop candidate, so a fault can fire in an otherwise idle gap."""
        if self._next < len(self.faults):
            return self.faults[self._next].at_s
        return None

    def due(self, now_s: float):
        """Pop every fault with at_s <= now_s, in schedule order."""
        out = []
        while (self._next < len(self.faults)
               and self.faults[self._next].at_s <= now_s):
            f = self.faults[self._next]
            self._next += 1
            self.fired.append(f)
            out.append(f)
        return out

    def reset_faults(self):
        """Rewind the serving schedule (replay runs reuse one injector)."""
        self._next = 0
        self.fired = []
        return self


class StragglerMonitor:
    def __init__(self, threshold=2.0, window=32):
        self.threshold = threshold
        self.window = window
        self.times = []
        self.flagged = []

    def record(self, step: int, seconds: float):
        self.times.append(seconds)
        recent = sorted(self.times[-self.window:])
        median = recent[len(recent) // 2]
        if len(self.times) >= 5 and seconds > self.threshold * median:
            self.flagged.append((step, seconds, median))
            return True
        return False


class StepTimer:
    def __init__(self):
        self.t0 = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def elastic_mesh_shape(n_devices: int, model_parallel: int = 16,
                       multi_pod: bool = False):
    """Largest mesh expressible with the surviving devices.

    Keeps the model axis fixed (parameter shardings stay valid) and shrinks
    the data/pod axes — restart resizes only the batch sharding.
    """
    if n_devices < model_parallel:
        # Degenerate survival: shrink model axis to the largest divisor.
        m = 1
        while m * 2 <= n_devices:
            m *= 2
        return ((1, m) if not multi_pod else (1, 1, m),
                ("data", "model") if not multi_pod else ("pod", "data", "model"))
    rest = n_devices // model_parallel
    if multi_pod and rest >= 2:
        pods = 2
        data = rest // pods
        return (pods, data, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")
