"""Collective helpers for shard_map regions: compressed cross-pod reduce.

`compressed_psum` is the wire-level version of the int8 error-feedback
gradient compression (DESIGN.md §3): each participant quantizes its local
shard to int8 + one fp32 scale, the reduction runs over int32 accumulators
(4× fewer wire bytes than fp32, 2× fewer than bf16), and the quantization
residual is returned for error-feedback accumulation at the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x, axis_name):
    """Inside shard_map: int8-compressed psum over `axis_name`.

    Returns (reduced, residual): `reduced` ≈ psum(x); `residual` = x - Q(x)
    is the local quantization error for error-feedback (add it to the next
    step's gradient before compressing again).
    """
    q, scale = _quantize_int8(x.astype(jnp.float32))
    deq = q.astype(jnp.float32) * scale
    residual = x.astype(jnp.float32) - deq
    # int32 accumulation of the shared-exponent int8 payloads: scales differ
    # per participant, so the reduction is sum of (q_i * scale_i) — modeled
    # as psum of the dequantized payload; wire bytes = 1 B/elt + O(1).
    reduced = jax.lax.psum(deq, axis_name)
    return reduced.astype(x.dtype), residual.astype(x.dtype)


def psum_bytes(shape, dtype, compressed=False):
    """Wire-byte accounting used by the roofline/energy reports."""
    import numpy as np

    n = int(np.prod(shape))
    return n * (1 if compressed else np.dtype(dtype).itemsize)
