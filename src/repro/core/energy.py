"""Analytic energy / latency model (paper Tab. 1 + Eyeriss-style data movement).

Two cost views, never conflated (DESIGN.md §2):

1. **ShiftAdd-ASIC energy view** — unit energies from the paper's Tab. 1
   (45 nm CMOS) plus Horowitz ISSCC'14-style data-movement costs. This is what
   reproduces the paper's energy tables (Tab. 3, Fig. 3).
2. **Stock-TPU roofline view** — v5e peak numbers used by the §Roofline terms
   and by the latency-aware MoE coefficients α_i.

All energies in pJ, times in seconds, sizes in bytes.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (single source of truth; roofline.py imports these)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
PEAK_OPS_INT8 = 394e12        # int8 MXU ops/s per chip (2x bf16)
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (~50 GB/s)
HBM_PER_CHIP = 16 * 1024**3   # 16 GiB

# ---------------------------------------------------------------------------
# Paper Tab. 1 — unit energy (pJ) per op, 45 nm CMOS
# ---------------------------------------------------------------------------
MULT_PJ = {"fp32": 3.7, "fp16": 0.9, "int32": 3.1, "int8": 0.2}
ADD_PJ = {"fp32": 1.1, "fp16": 0.4, "int32": 0.1, "int8": 0.03}
SHIFT_PJ = {"int32": 0.13, "int16": 0.057, "int8": 0.024}

# Horowitz ISSCC'14: DRAM ≈ 640 pJ / 32-bit word; on-chip SRAM ≈ 5 pJ / 32-bit.
DRAM_PJ_PER_BYTE = 160.0
SRAM_PJ_PER_BYTE = 1.25

_BYTES = {"fp32": 4, "fp16": 2, "bf16": 2, "int32": 4, "int16": 2, "int8": 1}


@dataclasses.dataclass
class OpEnergy:
    """Energy breakdown of one logical op (a matmul-shaped contraction)."""

    compute_pj: float
    dram_pj: float

    @property
    def total_pj(self) -> float:
        return self.compute_pj + self.dram_pj

    def __add__(self, other: "OpEnergy") -> "OpEnergy":
        return OpEnergy(self.compute_pj + other.compute_pj, self.dram_pj + other.dram_pj)


def _movement_pj(m, k, n, a_bytes, b_bytes, o_bytes):
    """One-pass DRAM traffic model: read A (m,k), read B (k,n), write O (m,n)."""
    return DRAM_PJ_PER_BYTE * (m * k * a_bytes + k * n * b_bytes + m * n * o_bytes)


def matmul_energy(m, k, n, dtype="fp16") -> OpEnergy:
    """Dense MatMul / Linear: m*k*n MACs (mult + add each)."""
    macs = m * k * n
    compute = macs * (MULT_PJ[dtype if dtype != "bf16" else "fp16"]
                      + ADD_PJ[dtype if dtype != "bf16" else "fp16"])
    b = _BYTES[dtype]
    return OpEnergy(compute, _movement_pj(m, k, n, b, b, b))


def add_matmul_energy(m, k, n, acc_dtype="int32") -> OpEnergy:
    """Paper's Add layer: one operand binarized ⇒ accumulation only.

    m*k*n additions at the accumulator dtype; binary operand moves 1 B/element
    (int8 storage; the bit-packed variant would be k*n/8).
    """
    compute = m * k * n * ADD_PJ[acc_dtype]
    return OpEnergy(compute, _movement_pj(m, k, n, _BYTES["fp16"], _BYTES["int8"], _BYTES["fp16"]))


def shift_matmul_energy(m, k, n, dtype="int8") -> OpEnergy:
    """Paper's Shift layer: weights are s*2^P ⇒ per-MAC a shift + an add.

    Weights move 1 packed byte/element; activations int/fp16.
    """
    macs = m * k * n
    compute = macs * (SHIFT_PJ[dtype] + ADD_PJ["int32"])
    return OpEnergy(compute, _movement_pj(m, k, n, _BYTES["fp16"], _BYTES["int8"], _BYTES["fp16"]))


# ---------------------------------------------------------------------------
# Latency model for expert coefficients α_i and the dispatcher capacities.
# Roofline max(compute, memory) on the stock-TPU view.
# ---------------------------------------------------------------------------

def linear_latency_estimate(tokens: int, d_in: int, d_out: int, kind: str) -> float:
    """Seconds to run a `tokens x d_in @ d_in x d_out` linear of a given kind.

    kind: "mult" (bf16 dense) | "shift" (packed-int8 weights) | "add" (binary operand).
    The *relative* values are what matter for α_i; they encode exactly the
    paper's observation that Shift's win is data movement.
    """
    flops = 2.0 * tokens * d_in * d_out
    if kind == "mult":
        w_bytes = d_in * d_out * 2
        t_c = flops / PEAK_FLOPS_BF16
    elif kind == "shift":
        w_bytes = d_in * d_out * 1           # packed int8
        t_c = flops / PEAK_OPS_INT8          # int8 MXU path
    elif kind == "add":
        w_bytes = d_in * d_out * 1
        t_c = flops / PEAK_OPS_INT8
    else:
        raise ValueError(kind)
    act_bytes = tokens * (d_in + d_out) * 2
    t_m = (w_bytes + act_bytes) / HBM_BW
    return max(t_c, t_m)


def mlp_latency_estimate(tokens: int, d_model: int, d_hidden: int, kind: str) -> float:
    """Two-linear MLP expert latency (the paper's MoE experts)."""
    return (linear_latency_estimate(tokens, d_model, d_hidden, kind)
            + linear_latency_estimate(tokens, d_hidden, d_model, kind))


def expert_latencies(tokens: int, d_model: int, d_hidden: int, kinds) -> list:
    return [mlp_latency_estimate(tokens, d_model, d_hidden, k) for k in kinds]


# Nominal token count at which expert latencies are evaluated for the α_i
# coefficients and capacity splits. It only fixes the compute/memory-bound
# regime; single source of truth so the dispatcher (nn/blocks,
# core/moe_primitives) and the energy model (serve/vision) can never use
# different regimes for "the same" split.
NOMINAL_MOE_TOKENS = 1024


def inverse_latency_weights(latencies) -> list:
    """Normalized 1/latency weights — the latency-aware token split."""
    inv = [1.0 / l for l in latencies]
    return [w / sum(inv) for w in inv]
