"""Deployment freeze — one-time param-tree pass that hoists every per-forward
decode out of the serving hot loop (ISSUE 3 tentpole).

The stage-2 shiftadd model pays three per-call taxes the dense model doesn't:

1. every ShiftLinear forward fake-quantizes its fp32 latent (or decodes its
   packed int8) back to s·2^P — log2/round/clip/ldexp over every weight,
   every call;
2. the binary attention runs through STE machinery built for training;
3. the MoE recomputes its capacity split bookkeeping at every trace.

`prepare_inference` walks the param tree ONCE at engine-build time and
materializes a `DeployPlan`:

- **shift weights** are decoded to their exact s·2^P value (impl="xla": a
  plain `w_deploy` operand for the dense dot — the hoisted twin of
  `ref.shift_matmul_ref`'s per-call `po2_weight_from_packed`) or packed to
  the int8 kernel format (impl="pallas"/"interpret": the Pallas kernel
  decodes in VMEM, which is already free). Both decodes are bit-exact, so
  frozen inference has EXACT logit parity with unfrozen inference.
- **MoE capacities/offsets** are precomputed into each
  `MoEPrimitives.capacity_plan` memo for the PER-IMAGE token counts the
  serving dispatch routes over (one routing group per batch row — ISSUE 5).
  Tokens-per-image is a property of the model geometry, not of the bucket,
  so one warmed count covers every bucket and the plan is identical for an
  image no matter which co-batch it arrives in.

The plan's `params` tree is what the serving engine's jitted forward closes
over; `ShiftLinear.__call__` recognizes the frozen leaves, so `infer` paths
consume the plan with no signature changes anywhere in the stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp

from repro.core import quant


def _is_shift_leaf(tree) -> bool:
    return isinstance(tree, dict) and ("w_latent" in tree or "w_packed" in tree)


def _freeze_shift_leaf(leaf, impl: str):
    """One ShiftLinear param dict → its deployment form for `impl`.

    xla: decode once to the exact s·2^P fp32 weight (what the unfrozen
      forward recomputes per call — `po2_quantize_ste` forward value /
      `po2_weight_from_packed`, both bit-exact powers of two).
    pallas/interpret: pack once to the int8 kernel format (1 B/weight HBM
      traffic; the kernel reassembles bf16 exponents in VMEM).
    """
    if impl == "xla":
        if "w_latent" in leaf:
            sign, p = quant.po2_quantize(leaf["w_latent"])
            w = quant.po2_value(sign, p, jnp.float32)
        else:
            w = quant.po2_weight_from_packed(leaf["w_packed"], jnp.float32)
        out = {"w_deploy": w}
    else:
        out = {"w_packed": (leaf["w_packed"] if "w_packed" in leaf
                            else quant.pack_from_dense(leaf["w_latent"]))}
    if "bias" in leaf:
        out["bias"] = leaf["bias"]
    return out


@dataclasses.dataclass(frozen=True)
class DeployPlan:
    """Frozen inference artifacts for one (model, params) pair.

    params: the frozen param tree — same structure as the live tree, with
      every ShiftLinear subtree replaced by its deployment form. The serving
      engine's jitted forward closes over this tree as constants.
    impl: kernel implementation the decode targeted ("xla"|"pallas"|"interpret").
    frozen_linears: how many shift subtrees were decoded/packed.
    moe_layers: how many MoE feeds had capacity plans warmed.
    token_counts: PER-IMAGE token counts the capacity plans were warmed for
      (the serving dispatch routes one group per batch row, so these are
      tokens-per-image — e.g. `cfg.n_patches` for the ViT engine — not
      flattened co-batch group sizes).
    tune: optional kernels.autotune.TuneTable the frozen program's kernel
      calls consume (threaded by the engine to every infer; hashable, so the
      jit cache keys on it). None → module-default block caps.
    """

    params: Any
    impl: str
    frozen_linears: int = 0
    moe_layers: int = 0
    token_counts: Tuple[int, ...] = ()
    tune: Any = None


def freeze_params(params, impl: str):
    """Walk a param tree, freezing every shift subtree. Returns (tree, count)."""
    count = 0

    def walk(tree):
        nonlocal count
        if _is_shift_leaf(tree):
            count += 1
            return _freeze_shift_leaf(tree, impl)
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            seq = [walk(v) for v in tree]
            return tuple(seq) if isinstance(tree, tuple) else seq
        return tree

    return walk(params), count


def prepare_inference(model, params, impl=None, token_counts=(),
                      tune=None) -> DeployPlan:
    """Build the DeployPlan for `model` + `params` (ISSUE 3 tentpole entry).

    model: anything with an optional `blocks` list whose block feeds may be
      `MoEPrimitives` (ShiftAddViT, TransformerBlock stacks, ...). Only the
      param tree is required; the model is consulted to warm MoE capacity
      plans for `token_counts` (PER-IMAGE token counts — the serving
      dispatch plans capacity per batch row) so dispatch trace time pays no
      capacity math either.
    """
    from repro.core.moe_primitives import MoEPrimitives
    from repro.kernels import ops

    impl = impl or ops.default_impl()
    assert impl in ("xla", "pallas", "interpret"), impl
    frozen, n_frozen = freeze_params(params, impl)

    moe_layers = 0
    token_counts = tuple(sorted(set(int(t) for t in token_counts)))
    for blk in getattr(model, "blocks", []):
        feed = getattr(blk, "feed", None)
        if isinstance(feed, MoEPrimitives):
            moe_layers += 1
            for t in token_counts:
                feed.capacity_plan(t)
    return DeployPlan(params=frozen, impl=impl, frozen_linears=n_frozen,
                      moe_layers=moe_layers, token_counts=token_counts,
                      tune=tune)
