"""Plain dense linear — the `Mult.` primitive (and the substrate default).

Kept in `core` so the heterogeneous MoE can pair it against ShiftLinear without
import cycles; `repro.nn` re-exports it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class Dense:
    """y = x @ W + b, with truncated-normal init scaled by fan-in."""

    def __init__(self, in_features, out_features, use_bias=True,
                 dtype=jnp.float32, param_dtype=jnp.float32, name="dense"):
        self.in_features = int(in_features)
        self.out_features = int(out_features)
        self.use_bias = use_bias
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.name = name

    def init(self, key):
        std = self.in_features ** -0.5
        w = std * jax.random.truncated_normal(
            key, -2.0, 2.0, (self.in_features, self.out_features), jnp.float32)
        params = {"kernel": w.astype(self.param_dtype)}
        if self.use_bias:
            params["bias"] = jnp.zeros((self.out_features,), self.param_dtype)
        return params

    def __call__(self, params, x):
        y = jnp.dot(x.astype(self.dtype), params["kernel"].astype(self.dtype))
        if self.use_bias:
            y = y + params["bias"].astype(self.dtype)
        return y
