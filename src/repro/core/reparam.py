"""Dense → ShiftAdd reparameterization of pretrained checkpoints (paper §4).

The paper's deployment story: start from pretrained weights, *reparameterize*
(not train from scratch), finetune in two stages:

  stage 1: attention — MSA → (binary-)linear attention; MatMuls → Add layers.
           (Attention math has no weights; the conversion is a policy flip +
           optional shift-reparam of the four projections.)
  stage 2: MLPs — dense MLPs → Shift layers or the MoE-of-primitives
           (Mult expert initialized FROM the pretrained MLP, Shift expert
           from its power-of-two projection).

These helpers are structure-agnostic tree rewriters; model classes declare
which named subtrees are projections vs MLPs (see repro.nn.transformer).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core import quant


def is_dense_leaf(subtree) -> bool:
    return isinstance(subtree, dict) and "kernel" in subtree


def dense_to_shift(subtree, mode="latent"):
    """{"kernel", bias?} → ShiftLinear params (latent or packed)."""
    assert is_dense_leaf(subtree), subtree.keys()
    if mode == "latent":
        out = {"w_latent": subtree["kernel"]}
    else:
        out = {"w_packed": quant.pack_from_dense(subtree["kernel"])}
    if "bias" in subtree:
        out["bias"] = subtree["bias"]
    return out


def shift_to_packed(subtree):
    """ShiftLinear latent params → packed deployment params."""
    out = {"w_packed": quant.pack_from_dense(subtree["w_latent"])}
    if "bias" in subtree:
        out["bias"] = subtree["bias"]
    return out


def dense_mlp_to_moe(mlp_params, expert_kinds=("mult", "shift"), up="up", down="down",
                     router_init=None):
    """Pretrained dense MLP → MoE-of-primitives params.

    The Mult expert inherits the pretrained weights verbatim; the Shift expert
    inherits their latent copy (so its first forward is the po2 projection of
    the pretrained weights — the paper's warm start).
    """
    experts = []
    for kind in expert_kinds:
        experts.append({
            "up": dict(mlp_params[up]) if kind == "mult"
            else dense_to_shift(mlp_params[up]),
            "down": dict(mlp_params[down]) if kind == "mult"
            else dense_to_shift(mlp_params[down]),
        })
    d_model = mlp_params[up]["kernel"].shape[0]
    if router_init is None:
        router_init = jnp.zeros((d_model, len(expert_kinds)), jnp.float32)
    return {"router": {"kernel": router_init}, "experts": experts}


def rewrite_tree(params, rules, _path=""):
    """Apply (regex, fn) rules to named subtrees; first match wins.

    `fn` receives the subtree and returns its replacement. Paths are
    slash-joined dict keys, e.g. "blocks/attn/q_proj".
    """
    for pattern, fn in rules:
        if re.fullmatch(pattern, _path):
            return fn(params)
    if isinstance(params, dict):
        return {k: rewrite_tree(v, rules, f"{_path}/{k}" if _path else k)
                for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        seq = [rewrite_tree(v, rules, f"{_path}/{i}") for i, v in enumerate(params)]
        return type(params)(seq) if isinstance(params, tuple) else seq
    return params


def count_reparameterized(params):
    """Diagnostics: how many leaves are shift-latent / packed / dense kernels."""
    counts = {"dense": 0, "shift_latent": 0, "shift_packed": 0}

    def walk(t):
        if isinstance(t, dict):
            if "kernel" in t:
                counts["dense"] += 1
            if "w_latent" in t:
                counts["shift_latent"] += 1
            if "w_packed" in t:
                counts["shift_packed"] += 1
            for v in t.values():
                walk(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v)

    walk(params)
    return counts
