"""ShiftAddPolicy — which components of a model are reparameterized, and how.

This is the paper's contribution exposed as a first-class framework feature:
every architecture config carries a policy; model builders, the reparam
converter, the dry-run and the serving path all consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShiftAddPolicy:
    """Per-component reparameterization policy (paper §4).

    attention:
      - "dense": original softmax attention (MSA / GQA / MLA ...).
      - "linear": linear attention, Q(KᵀV) order (paper stage-1a).
      - "binary_linear": linear attention with Q/K mapped to binary codes in
        Hamming space — MatMuls become additions (paper stage-1b, the `Add` layer).
    projections:
      - "dense": q/k/v/o projections stay multiplications.
      - "shift": q/k/v/o projections become `s * 2^P` shift layers.
    mlp:
      - "dense": original MLP.
      - "shift": all MLP linears become shift layers (paper shows accuracy drop).
      - "moe_primitives": the paper's heterogeneous MoE — each token routed to a
        Mult expert or a Shift expert (paper stage-2, §4.2).
    """

    attention: str = "dense"
    projections: str = "dense"
    mlp: str = "dense"
    # Expert kinds for the heterogeneous MoE, fastest-last not required; latency
    # coefficients are derived analytically per expert (core.energy).
    moe_experts: Tuple[str, ...] = ("mult", "shift")
    # Train router with the latency-aware LL-loss and use latency-aware
    # capacities at dispatch time.
    latency_aware: bool = True
    # λ in  L = L_CLS + λ (L_IMP + L_LOAD); paper uses 0.01 everywhere.
    balance_loss_weight: float = 0.01
    # Parallel DWConv on the V branch of linear attention (paper Fig. 1b).
    dwconv_v: bool = True
    # Deployment mode: shift weights stored packed int8 (1 B/weight) instead
    # of trainable fp32 latents — the serving format (paper App. A: the win
    # is data movement). Train with deploy=False, freeze, serve deploy=True.
    deploy: bool = False

    def proj_linear(self) -> str:
        if self.projections == "dense":
            return "dense"
        return "shift_packed" if self.deploy else "shift"

    def mlp_linear(self) -> str:
        if self.mlp == "dense":
            return "dense"
        return "shift_packed" if self.deploy else "shift"

    def __post_init__(self):
        assert self.attention in ("dense", "linear", "binary_linear"), self.attention
        assert self.projections in ("dense", "shift"), self.projections
        assert self.mlp in ("dense", "shift", "moe_primitives"), self.mlp
        for e in self.moe_experts:
            assert e in ("mult", "shift"), e

    @property
    def is_dense(self) -> bool:
        return (
            self.attention == "dense"
            and self.projections == "dense"
            and self.mlp == "dense"
        )


# Canonical policies used throughout tests / benchmarks / dry-run.
DENSE = ShiftAddPolicy()
# Paper's full recipe (Tab. 4 bottom rows: LA + Quant-Add + MoE(Both)).
SHIFTADD = ShiftAddPolicy(
    attention="binary_linear", projections="shift", mlp="moe_primitives"
)
# Deployment form of the full recipe: packed int8 shift weights.
SHIFTADD_DEPLOY = ShiftAddPolicy(
    attention="binary_linear", projections="shift", mlp="moe_primitives",
    deploy=True)
# Stage-1 only (LA + Add, projections/MLP untouched).
STAGE1 = ShiftAddPolicy(attention="binary_linear")
# Aggressive all-shift (paper shows the accuracy drop; we keep it for ablations).
ALL_SHIFT = ShiftAddPolicy(attention="binary_linear", projections="shift", mlp="shift")
