"""Latency-aware load-balancing loss (paper §4.2, Eq. 4).

    L_IMP  = SCV({ α_i · Σ_x p_i(x) })          (importance: gate mass)
    L_LOAD = SCV({ α_i · Σ_x q_i(x) })          (load: top-1 assignment prob)
    α_i    = Lat_i / Σ_j Lat_j                  (latency-aware coefficients)

SCV is the squared coefficient of variation. q_i(x) is the *smooth* probability
that expert i wins the (noisy) top-1, following Shazeer et al. '17 [48] — a
normal-CDF proxy that keeps the load term differentiable.

Minimizing SCV(α_i · load_i) drives load_i ∝ 1/α_i ∝ 1/Lat_i: faster experts
receive more tokens, which is exactly the paper's synchronization argument —
parallel experts finish at the same time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def squared_coeff_variation(x, eps=1e-9):
    """SCV(x) = Var(x) / Mean(x)^2 over the expert axis (last)."""
    mean = jnp.mean(x, axis=-1)
    var = jnp.var(x, axis=-1)
    return var / (jnp.square(mean) + eps)


def latency_coefficients(latencies):
    """α_i = Lat_i / Σ_j Lat_j  (paper's definition)."""
    lat = jnp.asarray(latencies, jnp.float32)
    return lat / jnp.sum(lat)


def importance_loss(probs, alpha):
    """L_IMP. probs: (..., tokens, experts) router softmax; alpha: (experts,)."""
    importance = jnp.sum(probs, axis=-2)  # (..., experts)
    return jnp.mean(squared_coeff_variation(importance * alpha))


def _normal_cdf(x):
    # float32 constant, not the weak-typed `jnp.sqrt(2.0)` — weak scalars
    # escaping a function boundary trip the serving audit's JX003 rule.
    return 0.5 * (1.0 + jax.lax.erf(x / np.sqrt(2.0, dtype=np.float32)))


def smooth_top1_prob(clean_logits, noise_std=1.0):
    """q_i(x) = P(p_i + ε ≥ p_j + ε_j, ∀ j ≠ i) — smooth noisy-top-1 proxy [48].

    Uses the normal-CDF of the margin between expert i's logit and the max of
    the *other* experts' logits. Differentiable everywhere.
    """
    # Exactly ONE winner per token, ties broken deterministically toward the
    # lowest index (argmax's first-occurrence rule — the same winner the
    # dispatch argmax picks). A value test `clean_logits >= max` would mark
    # every tied expert as the winner: each tied non-argmax expert then
    # measures its margin against a max that still contains itself (second
    # masks only the argmax slot), a self-referential zero with ZERO gradient
    # — the router could never learn to break a tie — and the load estimate
    # counts several "winners" per token.
    arg = jnp.argmax(clean_logits, axis=-1)
    is_top = jax.nn.one_hot(arg, clean_logits.shape[-1], dtype=bool)
    # For the winner the relevant margin is vs the runner-up. (Computed by
    # masking out the winner rather than sorting — sort's gradient is broken
    # on this jaxlib and a masked max is cheaper anyway.) Losers measure vs
    # the winner's own logit (gathered, so the gradient couples the pair).
    second = jnp.max(jnp.where(is_top, -jnp.inf, clean_logits), axis=-1, keepdims=True)
    winner = jnp.take_along_axis(clean_logits, arg[..., None], axis=-1)
    margin = jnp.where(is_top, clean_logits - second, clean_logits - winner)
    # Harden against upstream divergence: inf logits give inf-inf = NaN
    # margins; the CDF saturates beyond ~±6σ anyway.
    margin = jnp.clip(jnp.nan_to_num(margin, posinf=30.0, neginf=-30.0),
                      -30.0, 30.0)
    noise = jnp.maximum(jnp.asarray(noise_std, jnp.float32),
                        np.float32(1e-6))  # non-weak floor (audit JX003)
    return _normal_cdf(margin / noise)


def load_loss(clean_logits, alpha, noise_std=1.0):
    """L_LOAD with the smooth load estimator."""
    q = smooth_top1_prob(clean_logits, noise_std)  # (..., tokens, experts)
    load = jnp.sum(q, axis=-2)
    return jnp.mean(squared_coeff_variation(load * alpha))


def latency_aware_moe_loss(router_logits, probs, latencies, noise_std=1.0):
    """λ-free combined MoE aux loss: L_IMP + L_LOAD (caller applies λ).

    router_logits / probs: (..., tokens, experts); latencies: per-expert
    latency estimates (seconds or any consistent unit).
    """
    alpha = latency_coefficients(latencies)
    return importance_loss(probs, alpha) + load_loss(router_logits, alpha, noise_std)
