"""The paper's primary contribution: mixture of multiplication primitives.

- :mod:`repro.core.quant` — STE binary / power-of-two quantizers + int8 packing
- :mod:`repro.core.shift_linear` — ``W = s * 2^P`` shift-reparameterized linear
- :mod:`repro.core.add_attention` — binary-code (Hamming) linear attention, Q(KᵀV)
- :mod:`repro.core.moe_primitives` — heterogeneous {Mult, Shift} token-routed MoE
- :mod:`repro.core.losses` — latency-aware load-balancing loss (SCV importance + load)
- :mod:`repro.core.reparam` — two-stage dense→ShiftAdd checkpoint conversion
- :mod:`repro.core.energy` — analytic 45nm op/data-movement energy model (paper Tab. 1)
- :mod:`repro.core.policy` — ShiftAddPolicy: per-component reparameterization switch
"""

from repro.core.policy import ShiftAddPolicy
from repro.core.quant import (
    ste,
    binarize_ste,
    po2_quantize_ste,
    pack_po2,
    unpack_po2,
    po2_weight_from_packed,
)
from repro.core.losses import (
    squared_coeff_variation,
    importance_loss,
    load_loss,
    latency_aware_moe_loss,
)
from repro.core.shift_linear import ShiftLinear
from repro.core.add_attention import binary_linear_attention, BinaryLinearAttention
from repro.core.moe_primitives import MoEPrimitives
