"""Quantizers for the two multiplication primitives (paper §4.1, Fig. 2).

Shift weights:   W_S = s * 2^P,  s = sign(W) ∈ {-1,+1},  P = round(log2|W|)
Add operands:    binary codes b = sign(x) ∈ {-1,+1} (vanilla binarization [27];
                 the paper shows this beats kernelized hashing in its framework)

Both are trained with a straight-through estimator (STE, [69]); for deployment
shift weights are *packed one int8 per weight*:

    bit 7    : sign   (1 = negative)
    bits 0-6 : P + 64 (P ∈ [-64, +63])

so weight HBM traffic is 1 B/weight (vs 2 B bf16 / 4 B fp32) — the data-movement
saving the paper measures on GPUs (App. A) realized TPU-natively.  The bf16
power-of-two value is re-assembled *bit-exactly* from the exponent field:

    bf16 bits = sign << 15 | (P + 127) << 7        (mantissa = 0  ⇒  exactly 2^P)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# P range representable both by the int8 packing and the bf16 exponent field.
P_MIN = -64
P_MAX = 63
# bf16 exponent bias.
_BF16_BIAS = 127


def ste(quantized, latent):
    """Straight-through estimator: forward `quantized`, gradient to `latent`."""
    return latent + jax.lax.stop_gradient(quantized - latent)


# ---------------------------------------------------------------------------
# Binary (Add) quantization
# ---------------------------------------------------------------------------

def binarize(x, scale_axis=None):
    """Vanilla binarization: (sign(x), scale) with scale = mean(|x|).

    scale_axis=None gives a per-tensor scale (paper: "layer-wise Quant."); an
    int/tuple gives per-channel scales. Returns (b, scale) with b ∈ {-1,+1},
    same dtype as x (use `.astype(jnp.int8)` for storage).
    """
    scale = jnp.mean(jnp.abs(x), axis=scale_axis, keepdims=scale_axis is not None)
    # Non-weak branch values: `jnp.where(x, 1.0, -1.0)` yields a WEAK-typed
    # array (`.astype(x.dtype)` preserves weakness), and weak values crossing
    # a jit boundary are a retrace hazard the serving audit (JX003) rejects.
    one = jnp.ones((), x.dtype)
    b = jnp.where(x >= 0, one, -one)
    return b, scale.astype(x.dtype)


def binarize_ste(x, scale_axis=None, with_scale=True):
    """Fake-quantized binarization with STE for training.

    Forward value is `scale * sign(x)` (or plain sign(x) when with_scale=False);
    gradients flow straight through to x.
    """
    b, scale = binarize(x, scale_axis)
    q = b * scale if with_scale else b
    return ste(q, x)


# ---------------------------------------------------------------------------
# Power-of-two (Shift) quantization
# ---------------------------------------------------------------------------

def po2_quantize(w, p_min=P_MIN, p_max=P_MAX):
    """Round |w| to the nearest power of two: returns (sign, P).

    sign ∈ {-1,+1} (zeros get +1 and P=p_min, i.e. the smallest magnitude —
    DeepShift-PS has no exact-zero representation and no scaling factor).
    """
    one = jnp.ones((), w.dtype)        # non-weak branches (see binarize)
    sign = jnp.where(w < 0, -one, one)
    mag = jnp.maximum(jnp.abs(w.astype(jnp.float32)), 2.0 ** (p_min - 1))
    p = jnp.clip(jnp.round(jnp.log2(mag)), p_min, p_max).astype(jnp.int32)
    return sign, p


def po2_value(sign, p, dtype=jnp.float32):
    """Reconstruct s * 2^P (reference path; kernels use the exponent-bit
    trick). ldexp, not exp2 — exp2 is inexact at extreme exponents on CPU."""
    return jnp.ldexp(sign.astype(jnp.float32), p).astype(dtype)


def po2_quantize_ste(w, p_min=P_MIN, p_max=P_MAX):
    """Fake-quantize latent weights to s*2^P with STE (training forward path)."""
    sign, p = po2_quantize(w, p_min, p_max)
    return ste(po2_value(sign, p, w.dtype), w)


# ---------------------------------------------------------------------------
# int8 packing  (deployment format; 1 byte per weight)
# ---------------------------------------------------------------------------

def pack_po2(sign, p):
    """Pack (sign ∈ {-1,+1}, P ∈ [-64,63]) into one int8 per weight."""
    neg = (sign < 0).astype(jnp.uint8)
    biased = (p.astype(jnp.int32) - P_MIN).astype(jnp.uint8)  # [0, 127]
    return (jnp.left_shift(neg, 7) | biased).astype(jnp.uint8).view(jnp.int8)


def unpack_po2(packed):
    """Inverse of pack_po2: int8 → (sign fp32 ∈ {-1,+1}, P int32)."""
    u = packed.view(jnp.uint8).astype(jnp.int32)
    neg = jnp.right_shift(u, 7)
    p = (u & 0x7F) + P_MIN
    sign = 1.0 - 2.0 * neg.astype(jnp.float32)
    return sign, p


def po2_weight_from_packed(packed, dtype=jnp.bfloat16):
    """Assemble s*2^P from packed int8 via bf16 exponent-bit construction.

    This is the XLA twin of what the Pallas kernel does in VMEM: pure integer
    ops + bitcast, no exp2. Exactly representable because bf16 has an 8-bit
    exponent with bias 127 and we zero the mantissa.
    """
    u = packed.view(jnp.uint8).astype(jnp.uint16)
    sign_bit = jnp.left_shift(u >> 7, 15)
    p = (u & 0x7F).astype(jnp.int32) + P_MIN
    exp_field = (p + _BF16_BIAS).astype(jnp.uint16)
    bits = (sign_bit | jnp.left_shift(exp_field, 7)).astype(jnp.uint16)
    w = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
    return w.astype(dtype)


def pack_from_dense(w):
    """dense fp weight → packed int8 shift weight (deployment conversion)."""
    sign, p = po2_quantize(w)
    return pack_po2(sign, p)
