"""ShiftLinear — the paper's `Shift` layer:  y = x @ (s * 2^P) + b.

Two parameter modes:

- ``mode="latent"`` (training): a latent fp32 weight is power-of-two
  fake-quantized with an STE on every forward (DeepShift-Q-style latent
  training; the paper's DeepShift-PS sign/P training is equivalent under STE
  and this form converts losslessly to it).
- ``mode="packed"`` (deployment): weights are 1 packed int8 per element
  (sign | P+64). The forward uses the shift_matmul path — on TPU the Pallas
  kernel, elsewhere the XLA twin that assembles bf16 via exponent bits.

No scaling factor (paper App. E: DeepShift-PS, no scale). Bias stays fp32 —
it is O(d) and irrelevant to both traffic and energy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


class ShiftLinear:
    def __init__(self, in_features, out_features, use_bias=True,
                 dtype=jnp.float32, param_dtype=jnp.float32,
                 mode="latent", name="shift_linear"):
        assert mode in ("latent", "packed")
        self.in_features = int(in_features)
        self.out_features = int(out_features)
        self.use_bias = use_bias
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.mode = mode
        self.name = name

    def init(self, key):
        std = self.in_features ** -0.5
        w = std * jax.random.truncated_normal(
            key, -2.0, 2.0, (self.in_features, self.out_features), jnp.float32)
        if self.mode == "latent":
            params = {"w_latent": w.astype(self.param_dtype)}
        else:
            params = {"w_packed": quant.pack_from_dense(w)}
        if self.use_bias:
            params["bias"] = jnp.zeros((self.out_features,), self.param_dtype)
        return params

    def init_from_dense(self, dense_params):
        """Reparameterize a pretrained Dense layer's params (paper stage 2)."""
        w = dense_params["kernel"]
        if self.mode == "latent":
            params = {"w_latent": w.astype(self.param_dtype)}
        else:
            params = {"w_packed": quant.pack_from_dense(w)}
        if self.use_bias:
            bias = dense_params.get("bias")
            params["bias"] = (jnp.zeros((self.out_features,), self.param_dtype)
                              if bias is None else bias.astype(self.param_dtype))
        return params

    def freeze(self, params):
        """latent → packed int8 deployment params."""
        out = {"w_packed": quant.pack_from_dense(params["w_latent"])}
        if self.use_bias:
            out["bias"] = params["bias"]
        return out

    # Serving entry points thread kernel selection explicitly (engine →
    # blocks → ops); nn.layers.call_linear keys on this class attribute.
    accepts_impl = True

    def __call__(self, params, x, impl=None, tune=None):
        x = x.astype(self.dtype)
        if "w_deploy" in params:
            # Deployment-frozen XLA path (core.deploy.prepare_inference): the
            # s·2^P weight was decoded ONCE at engine build; the forward is a
            # plain dot — no per-call fake-quant / packed-decode in the jitted
            # program. Value-identical to both unfrozen paths below (the
            # decode is bit-exact), so frozen inference has exact logit parity.
            y = jnp.dot(x, params["w_deploy"].astype(self.dtype))
        elif "w_latent" in params:
            w_q = quant.po2_quantize_ste(params["w_latent"]).astype(self.dtype)
            y = jnp.dot(x, w_q)
        else:
            from repro.kernels import ops  # lazy: kernels import core

            # impl/tune arrive threaded from the serving engine; impl=None
            # (ad-hoc callers) falls back to ops.default_impl() inside the
            # wrapper. The w_deploy/w_latent branches above have no kernel
            # selection, so the kwargs are intentionally unused there.
            y = ops.shift_matmul(x, params["w_packed"], impl, tune)
        if self.use_bias:
            y = y + params["bias"].astype(self.dtype)
        return y
