"""Heterogeneous mixture of multiplication primitives (paper §4.2).

Experts are *unequal*: a powerful `Mult.` expert (dense linears) and a cheap
`Shift` expert (power-of-two linears). A learned router sends each token to
its top-1 expert; the latency-aware load-balancing loss (core.losses) trains
the router so the token split matches the experts' speed ratio.

TPU adaptation of the paper's TVM/Nimble dynamic dispatch (DESIGN.md §2):
**static capacity dispatch** (GShard/Switch one-hot einsums) with
**latency-aware capacities** — expert i's capacity ∝ 1/Lat_i, the static-shape
twin of the LL-loss objective. Experts run as independent sharded branches, so
the paper's "ideal parallelism" (modularized latency = max over experts) is
the native execution model under SPMD, not a simulation.

Training groups tokens across the flattened co-batch (`group_tokens`);
SERVING plans capacity per image row (`group_rows` + the memoized per-image
`capacity_plan`), so an image's routing — and therefore its logits — is
independent of whatever the scheduler co-batched it with (ISSUE 5 tentpole;
the batch-invariance property tier pins it).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import energy, losses
from repro.core.dense import Dense
from repro.core.shift_linear import ShiftLinear


def _act(name):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


class _MLPExpert:
    """Two-linear expert of a given primitive kind ("mult" | "shift")."""

    def __init__(self, d_model, d_hidden, kind, activation="gelu",
                 dtype=jnp.float32, param_dtype=jnp.float32):
        linear = Dense if kind == "mult" else ShiftLinear
        self.kind = kind
        self.up = linear(d_model, d_hidden, dtype=dtype, param_dtype=param_dtype)
        self.down = linear(d_hidden, d_model, dtype=dtype, param_dtype=param_dtype)
        self.activation = _act(activation)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"up": self.up.init(k1), "down": self.down.init(k2)}

    def spec(self, params):
        def lin(p, axes):
            return {k: (axes if k != "bias" else (axes[-1],)) for k in p}
        return {"up": lin(params["up"], ("embed", "mlp")),
                "down": lin(params["down"], ("mlp", "embed"))}

    accepts_impl = True

    def __call__(self, params, x, impl=None, tune=None):
        up, down = self.up, self.down
        if getattr(up, "accepts_impl", False):          # shift expert
            h = up(params["up"], x, impl=impl, tune=tune)
            return down(params["down"], self.activation(h), impl=impl,
                        tune=tune)
        return down(params["down"], self.activation(up(params["up"], x)))


class _LinearExpert:
    """Single-linear expert — for MoE applied to attention projections ("Both")."""

    def __init__(self, d_in, d_out, kind, dtype=jnp.float32, param_dtype=jnp.float32):
        linear = Dense if kind == "mult" else ShiftLinear
        self.kind = kind
        self.proj = linear(d_in, d_out, dtype=dtype, param_dtype=param_dtype)

    def init(self, key):
        return {"proj": self.proj.init(key)}

    def spec(self, params):
        return {"proj": {k: (("embed", "mlp") if k != "bias" else ("mlp",))
                         for k in params["proj"]}}

    accepts_impl = True

    def __call__(self, params, x, impl=None, tune=None):
        if getattr(self.proj, "accepts_impl", False):   # shift expert
            return self.proj(params["proj"], x, impl=impl, tune=tune)
        return self.proj(params["proj"], x)


class MoEPrimitives:
    """Token-routed mixture of {Mult, Shift} experts with latency-aware dispatch.

    Args:
      d_model: token dim.
      d_hidden: expert hidden dim (expert_type="mlp") or output dim ("linear").
      expert_kinds: e.g. ("mult", "shift") — the paper's pairing. Any number
        and mix of kinds is supported (the paper notes more unbalanced experts
        ⇒ larger LL-loss wins).
      capacity_factor: slack multiplier on the latency-proportional capacities.
      latency_aware: if False, capacities are uniform and α_i = 1/n (ablation
        arm of paper Tab. 7).
    """

    def __init__(self, d_model, d_hidden, expert_kinds=("mult", "shift"),
                 expert_type="mlp", activation="gelu", capacity_factor=1.25,
                 latency_aware=True, router_noise=1.0,
                 dtype=jnp.float32, param_dtype=jnp.float32, name="moe",
                 experts=None, latencies=None, capacity_ref_tokens=None):
        """If `experts` (list of init/apply modules) is given it overrides the
        built-in expert construction — used by repro.nn to pair the
        architecture's own MLP flavor (SwiGLU, channel-mix, ...) as the Mult
        expert against its Shift twin. `latencies` must then be supplied (or
        is estimated from the default MLP shape)."""
        self.d_model = int(d_model)
        self.d_hidden = int(d_hidden)
        self.expert_kinds = tuple(expert_kinds)
        self.n_experts = len(experts) if experts is not None else len(self.expert_kinds)
        self.capacity_factor = float(capacity_factor)
        self.latency_aware = latency_aware
        self.router_noise = router_noise
        self.dtype = dtype
        self.name = name
        self._capacity_plans = {}   # n_tokens → (caps, offsets) memo
        self.router = Dense(d_model, self.n_experts, use_bias=False,
                            dtype=jnp.float32, param_dtype=jnp.float32)
        if experts is not None:
            self.experts = list(experts)
        elif expert_type == "mlp":
            self.experts = [
                _MLPExpert(d_model, d_hidden, kind, activation, dtype, param_dtype)
                for kind in self.expert_kinds
            ]
        else:
            self.experts = [
                _LinearExpert(d_model, d_hidden, kind, dtype, param_dtype)
                for kind in self.expert_kinds
            ]
        # Per-expert latency estimates — used for α_i (LL-loss) and the static
        # capacity split. Explicit values (serving telemetry, caller override)
        # win; otherwise the analytic energy model is evaluated at
        # `capacity_ref_tokens` — the DEPLOYMENT per-group token count (a
        # ViT's per-image patch count), which sets the compute/memory-bound
        # regime. The α/capacity regime is a per-feed constant, never a
        # per-call function of the group size: one model must route
        # identically across group sizes (LM prefill routes a whole prompt,
        # decode routes single tokens — a size-dependent split would diverge
        # them), so callers that dispatch varying group sizes leave the ref
        # unset and get the NOMINAL_MOE_TOKENS fallback.
        self.capacity_ref_tokens = (None if capacity_ref_tokens is None
                                    else int(capacity_ref_tokens))
        self._explicit_latencies = None
        if latencies is not None:
            self.latencies = list(latencies)

    @property
    def latencies(self):
        """Per-expert latencies backing α_i and capacities — the feed's
        regime constant. Reads return the explicit override when one was set
        (telemetry table / caller), else the analytic model at
        `capacity_ref_tokens` (falling back to `energy.NOMINAL_MOE_TOKENS`
        when no deployment token count was pinned)."""
        return self.latencies_at(None)

    @latencies.setter
    def latencies(self, value):
        """Setting latencies (e.g. dropping in measured telemetry) invalidates
        the memoized capacity plans — engines must be (re)built afterwards so
        their frozen programs see the new split."""
        self._explicit_latencies = (None if value is None
                                    else [float(v) for v in value])
        self._capacity_plans.clear()

    def latencies_at(self, n_tokens=None):
        """Latencies at a per-group token count — the single source of truth
        for α_i and the capacity split. Explicit telemetry latencies are
        measured at serving geometry already and are returned as-is; the
        analytic fallback is evaluated at `n_tokens`, defaulting to the
        feed's `capacity_ref_tokens` regime (serving buckets run 196-token
        per-image groups, not the 1024-token nominal regime) and then to
        NOMINAL_MOE_TOKENS."""
        if self._explicit_latencies is not None:
            return list(self._explicit_latencies)
        if n_tokens is None:
            n_tokens = self.capacity_ref_tokens or energy.NOMINAL_MOE_TOKENS
        return energy.expert_latencies(int(n_tokens), self.d_model,
                                       self.d_hidden, self.expert_kinds)

    # -- parameters ---------------------------------------------------------
    def init(self, key):
        keys = jax.random.split(key, self.n_experts + 1)
        return {
            "router": self.router.init(keys[0]),
            "experts": [e.init(k) for e, k in zip(self.experts, keys[1:])],
        }

    def spec(self, params):
        return {
            "router": {k: ("embed", None) for k in params["router"]},
            "experts": [e.spec(p) for e, p in zip(self.experts, params["experts"])],
        }

    # -- capacity schedule ---------------------------------------------------
    def _capacity_weights(self):
        # Regime latencies, NOT a function of the group size being planned:
        # caps(n) and caps(m) must be the same split at different scales or
        # mixed-group dispatch (LM prefill vs decode) routes inconsistently.
        if self.latency_aware:
            return energy.inverse_latency_weights(self.latencies_at(None))
        return [1.0 / self.n_experts] * self.n_experts

    def capacities(self, n_tokens: int):
        """Static per-expert capacities; latency-aware split sends more tokens
        to faster experts (inverse-latency weights).

        Invariant: capacity_factor >= 1.0 ⇒ sum(caps) >= n_tokens. Per-term
        ceil usually gets there on its own, but the guarantee is structural,
        not a float-rounding accident: any deficit left after the
        min(c, n_tokens) clamp is topped back up, largest-weight experts
        first, so small groups can never silently shrink total capacity below
        the token count.
        """
        weights = self._capacity_weights()
        caps = [min(int(math.ceil(self.capacity_factor * n_tokens * w)), n_tokens)
                for w in weights]
        if self.capacity_factor >= 1.0:
            deficit = n_tokens - sum(caps)
            for i in sorted(range(self.n_experts), key=lambda j: -weights[j]):
                if deficit <= 0:
                    break
                bump = min(deficit, n_tokens - caps[i])
                caps[i] += bump
                deficit -= bump
        return caps

    def capacity_plan(self, n_tokens: int):
        """Memoized (caps, offsets) for a per-group token count — the static
        capacity math hoisted out of every trace. At serve time the group IS
        one image row (`nn.dispatch.group_rows`), so `n_tokens` is the
        tokens-PER-IMAGE count and the plan is the per-image capacity split:
        every image gets the same static caps regardless of what it is
        co-batched with. `core.deploy`'s prepare_inference warms this for
        the serving geometry at engine-build time; cold lookups still
        compute (and memoize) on first trace."""
        plan = self._capacity_plans.get(n_tokens)
        if plan is None:
            caps = self.capacities(n_tokens)
            offsets = [0]
            for c in caps:
                offsets.append(offsets[-1] + c)
            plan = (tuple(caps), tuple(offsets[:-1]))
            self._capacity_plans[n_tokens] = plan
        return plan

    # -- forward ------------------------------------------------------------
    def _run_experts(self, params, buf, daux, caps, s):
        """Run each expert on its static row segment of the dispatch buffer
        and combine back to (G, S, d). Heterogeneous experts are independent
        branches — parallel under SPMD, the paper's "ideal parallelism"
        natively (DESIGN.md §2)."""
        from repro.nn.dispatch import combine

        outs = []
        off = 0
        for i, expert in enumerate(self.experts):
            seg = buf[:, off:off + caps[i], :]
            outs.append(expert(params["experts"][i], seg))
            off += caps[i]
        expert_out = jnp.concatenate(outs, axis=1)               # (G, total, d)
        return combine(expert_out, daux, s, self.d_model)

    @staticmethod
    def _gates(select_logits, clean_logits):
        """THE gating rule, single home for train and serving: top-1 on
        `select_logits` (noisy while training, clean at inference), gate from
        the clean softmax. Returns (probs (G,S,E), top1 (G,S), gate (G,S,1))."""
        probs = jax.nn.softmax(clean_logits, axis=-1)
        top1 = jnp.argmax(select_logits, axis=-1)
        gate = jnp.take_along_axis(probs, top1[..., None], axis=-1)
        return probs, top1, gate

    def _route_dispatch(self, params, xg, select_logits, clean_logits, stats):
        """Training routing: `_gates` then sort-based capacity dispatch. The
        serving path (`infer`) consumes the same `_gates` via `_route_infer`
        with the gather-ordered dispatch."""
        from repro.nn.dispatch import dispatch

        s = xg.shape[1]
        probs, top1, gate = self._gates(select_logits, clean_logits)
        caps, _ = self.capacity_plan(s)
        buf, daux = dispatch(xg.astype(self.dtype), top1[..., None],
                             gate.astype(jnp.float32), caps, stats=stats)
        return probs, top1, caps, buf, daux

    def _route_infer(self, params, xg):
        """Clean-logit argmax routing for serving (no noise, no rng): the
        shared `_gates` rule with clean logits on both slots. Returns
        (top1 (G,S), gate (G,S))."""
        clean_logits = self.router(params["router"], xg.astype(jnp.float32))
        _, top1, gate = self._gates(clean_logits, clean_logits)
        return top1, gate[..., 0].astype(jnp.float32)

    def _dispatch_tokens(self, params, x, grouping="image"):
        """Shared serving front half: group → route (clean argmax) →
        gather-ordered dispatch. Returns (buf, info, segments, ungroup) with
        `segments` the per-expert static views of the buffer. Single home so
        `infer` and the breakdown probe `dispatch_only` can never diverge on
        the dispatch they measure/serve.

        grouping="image" (the serving default) plans capacity PER BATCH ROW
        (`nn.dispatch.group_rows`): each image competes only with itself for
        expert slots, so per-image outputs are independent of co-batching —
        the batch-invariance contract. grouping="flat" is the legacy
        flattened-co-batch grouping (`group_tokens`), kept ONLY as the A/B
        arm of the dispatch-cost breakdown benchmark."""
        from repro.nn.dispatch import (dispatch_infer, group_rows,
                                       group_tokens)

        assert grouping in ("image", "flat"), grouping
        group = group_rows if grouping == "image" else group_tokens
        xg, ungroup = group(x, self.d_model)
        _, s, _ = xg.shape
        top1, gate = self._route_infer(params, xg)
        caps, offsets = self.capacity_plan(s)
        buf, info = dispatch_infer(xg.astype(self.dtype), top1, gate, caps)
        segments = [buf[:, off:off + cap, :]
                    for off, cap in zip(offsets, caps)]
        return buf, info, segments, ungroup

    # Serving threads kernel impl/tune through to the shift experts.
    accepts_impl = True

    def infer(self, params, x, impl=None, tune=None):
        """Deterministic inference dispatch — the serving fast path.

        Routes on clean-logit argmax (no router noise, no rng) with static
        latency-aware capacities planned PER IMAGE ROW (one routing group
        per batch row, capacities from the per-image token count), and
        computes none of the aux/LL-loss statistics. Dispatch is the
        gather-ordered segment path (nn.dispatch.dispatch_infer): no
        scatter-into-zeros, experts consume per-expert static views, the
        combine is a per-token gather — and the capacity/offset math comes
        from the memoized `capacity_plan` (warmed by core.deploy at engine
        build). Two calls on the same input produce identical outputs, and
        a given image's output is bit-identical regardless of which
        neighbors it is batched with, its row position, or batch padding
        (no token ever competes with another image's tokens for capacity).
        Returns y only.
        """
        from repro.nn.dispatch import combine_infer

        _, info, segments, ungroup = self._dispatch_tokens(params, x)
        outs = [expert(params["experts"][i], seg, impl=impl, tune=tune)
                if getattr(expert, "accepts_impl", False)
                else expert(params["experts"][i], seg)
                for i, (expert, seg) in enumerate(zip(self.experts, segments))]
        return ungroup(combine_infer(outs, info)).astype(x.dtype)

    def dispatch_only(self, params, x, grouping="image"):
        """Routing + dispatch + combine with identity experts — isolates the
        dispatch machinery's cost for the component-breakdown benchmark.
        grouping="flat" measures the legacy flattened-co-batch dispatch so
        the per-image refactor's hot-path cost stays visible in the bench
        trajectory (BENCH_vit.json's dispatch rows)."""
        from repro.nn.dispatch import combine_infer

        _, info, segments, ungroup = self._dispatch_tokens(params, x,
                                                           grouping=grouping)
        return ungroup(combine_infer(segments, info)).astype(x.dtype)

    def __call__(self, params, x, train=True, rng=None):
        """x: (..., d_model). Tokens are routed in sharded groups
        (repro.nn.dispatch) with latency-aware per-expert capacities.

        Returns (y, aux) where aux carries the LL-loss ingredients and
        dispatch statistics (paper Fig. 6 visualizations read these).
        """
        from repro.nn.dispatch import group_tokens

        xg, ungroup = group_tokens(x, self.d_model)
        g, s, _ = xg.shape

        clean_logits = self.router(params["router"], xg.astype(jnp.float32))
        if train and rng is not None and self.router_noise > 0:
            noisy = clean_logits + self.router_noise * jax.random.normal(
                rng, clean_logits.shape)
        else:
            noisy = clean_logits
        probs, top1, caps, buf, daux = self._route_dispatch(
            params, xg, noisy, clean_logits, stats=True)
        y = ungroup(self._run_experts(params, buf, daux, caps, s)).astype(x.dtype)

        # latency_aware=False is the paper's baseline arm (Tab. 7 ablation):
        # homogeneous treatment — uniform α — rather than no balance at all.
        # α is evaluated at the feed's regime token count (capacity_ref_
        # tokens) so the loss and the capacity split (same `latencies_at`)
        # always agree on the regime, independent of this call's group size.
        loss_lat = (jnp.asarray(self.latencies_at(None)) if self.latency_aware
                    else jnp.ones((self.n_experts,)))
        alpha = losses.latency_coefficients(loss_lat)
        balance = losses.latency_aware_moe_loss(
            clean_logits, probs, loss_lat, self.router_noise)
        aux = {
            "balance_loss": balance,
            "probs": probs.reshape(g * s, self.n_experts),
            "logits": clean_logits.reshape(g * s, self.n_experts),
            "top1": top1.reshape(g * s),
            "tokens_per_expert": daux["tokens_per_expert"],
            "drop_fraction": daux["drop_fraction"],
            "alpha": alpha,
            "capacities": jnp.asarray(caps, jnp.int32),
        }
        return y, aux
