"""Binary-code linear attention — the paper's `Add`-reparameterized attention.

Order is exchanged to Q(KᵀV) for linear complexity (paper §4.1), then Q and K
are mapped to binary codes in Hamming space. The similarity kernel is

    sim(q, k) = (b_q · b_k + d) / (2d)  ∈ [0, 1]            (b ∈ {-1,+1}^d)

i.e. the fraction of matching bits (1 − normalized Hamming distance). It is
non-negative, so the linear-attention normalizer is strictly positive — this
is the stability property Ecoformer's kernelized hashing buys, obtained here
with *vanilla* binarization (which the paper shows works better, Tab. 4 obs. 2).

Every MatMul against b_q / b_k is a ±1 contraction ⇒ pure additions — the
paper's MatAdd. The (2d) factor cancels between numerator and denominator.

Forms provided:
- bidirectional (encoder / ViT): two einsums over global sums.
- causal chunked (decoder training / prefill): scan over chunks with a running
  (d_k × d_v) state — the same dataflow the Pallas kernel implements in VMEM.
- decode step: O(1)-state recurrent update — what makes `long_500k` feasible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import binarize_ste


def _featurize(q, k, feature="binary"):
    """Map q/k to the kernel feature space.

    "binary": hard ±1 Hamming codes (STE-differentiable) with offset d — the
      paper's Add-reparameterized attention.
    "elu1": φ(x) = elu(x)+1 (Katharopoulos linear attention) with offset 0 —
      the paper's plain linear-attention stage (Tab. 4 "Linear Attn" rows).

    Returns (fq, fk, offset); the attention weight is fq·fk + offset ≥ 0.
    """
    if feature == "binary":
        return (binarize_ste(q, with_scale=False),
                binarize_ste(k, with_scale=False),
                float(q.shape[-1]))
    if feature == "elu1":
        return jax.nn.elu(q) + 1.0, jax.nn.elu(k) + 1.0, 0.0
    raise ValueError(feature)


def binary_linear_attention(q, k, v, *, causal=False, chunk=128, train=True,
                            feature="binary", return_state=False,
                            lengths=None):
    """q, k: (B, H, N, Dk); v: (B, H, N, Dv) → (B, H, N, Dv).

    With return_state=True (causal only) also returns the final recurrent
    carry {"kv", "ksum", "vsum", "count"} in the init_decode_state layout —
    the chunked-prefill handoff into the O(1) decode path.

    lengths (B,) int32, causal only: per-row valid prompt length for
    end-padded batches. Keys/values at positions >= lengths[b] are masked out
    of the carry and the counts, so the returned state is exactly the state
    of the unpadded row — outputs at padded positions are garbage (they are
    never read: padding sits strictly in every real position's causal
    future).
    """
    if causal:
        return _causal_chunked(q, k, v, chunk=chunk, train=train,
                               feature=feature, return_state=return_state,
                               lengths=lengths)
    if return_state or lengths is not None:
        raise ValueError("return_state/lengths require causal=True (there is "
                         "no recurrent carry in the bidirectional form)")
    return _bidirectional(q, k, v, train=train, feature=feature)


def _bidirectional(q, k, v, train=True, feature="binary"):
    n = q.shape[-2]
    bq, bk, d = _featurize(q, k, feature)
    kv = jnp.einsum("bhnd,bhne->bhde", bk, v)           # MatAdd (±1 operand)
    ksum = jnp.sum(bk, axis=-2)                          # (B,H,Dk)
    vsum = jnp.sum(v, axis=-2)                           # (B,H,Dv)
    num = jnp.einsum("bhnd,bhde->bhne", bq, kv) + d * vsum[:, :, None, :]
    den = jnp.einsum("bhnd,bhd->bhn", bq, ksum) + jnp.asarray(d * n, q.dtype)
    return num / (den[..., None] + 1e-6)


def _causal_chunked(q, k, v, *, chunk=128, train=True, feature="binary",
                    return_state=False, lengths=None):
    b, h, n, dk_dim = q.shape
    dv = v.shape[-1]
    if n % chunk != 0:
        pad = chunk - n % chunk
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = q.shape[-2] // chunk
    bq, bk, dk = _featurize(q, k, feature)
    if lengths is not None:
        # Per-row valid prompt lengths (bucketed end-padded prefill): masked
        # key positions would featurize to nonzero codes and poison the carry.
        valid = (jnp.arange(q.shape[-2])[None, :]
                 < lengths[:, None]).astype(q.dtype)[:, None, :, None]
        bk = bk * valid
        v = v * valid
    elif q.shape[-2] != n:
        # Padded key positions would featurize to nonzero codes (sign(0)=+1,
        # elu(0)+1=1) and poison the carry; zero them out. Valid outputs are
        # untouched (padding is strictly in the causal future of every real
        # position), so this is safe unconditionally.
        valid = (jnp.arange(q.shape[-2]) < n).astype(q.dtype)[None, None, :, None]
        bk = bk * valid
        v = v * valid

    # (nc, B, H, chunk, D) chunked views for scan.
    def to_chunks(x):
        return x.reshape(b, h, nc, chunk, x.shape[-1]).transpose(2, 0, 1, 3, 4)

    bq_c, bk_c, v_c = to_chunks(bq), to_chunks(bk), to_chunks(v)
    mask = jnp.tril(jnp.ones((chunk, chunk), q.dtype))          # includes self
    pos_in_chunk = jnp.arange(1, chunk + 1, dtype=q.dtype)      # causal count

    def step(carry, xs):
        kv_s, ksum_s, vsum_s, cnt = carry
        bq_i, bk_i, v_i = xs
        # Inter-chunk (history) terms: running-state contractions.
        num = jnp.einsum("bhcd,bhde->bhce", bq_i, kv_s) + dk * vsum_s[:, :, None, :]
        den = jnp.einsum("bhcd,bhd->bhc", bq_i, ksum_s) + dk * cnt
        # Intra-chunk causal term.
        scores = jnp.einsum("bhcd,bhkd->bhck", bq_i, bk_i) + jnp.asarray(dk, q.dtype)
        scores = scores * mask
        num = num + jnp.einsum("bhck,bhke->bhce", scores, v_i)
        den = den + dk * pos_in_chunk  # Σ_{j≤i} d term for in-chunk positions
        den = den + jnp.einsum("bhcd,bhkd,ck->bhc", bq_i, bk_i, mask)
        out_i = num / (den[..., None] + 1e-6)
        # State update.
        kv_s = kv_s + jnp.einsum("bhcd,bhce->bhde", bk_i, v_i)
        ksum_s = ksum_s + jnp.sum(bk_i, axis=-2)
        vsum_s = vsum_s + jnp.sum(v_i, axis=-2)
        cnt = cnt + jnp.asarray(chunk, q.dtype)
        return (kv_s, ksum_s, vsum_s, cnt), out_i

    carry = (
        jnp.zeros((b, h, dk_dim, dv), q.dtype),
        jnp.zeros((b, h, dk_dim), q.dtype),
        jnp.zeros((b, h, dv), q.dtype),
        jnp.asarray(0.0, q.dtype),
    )
    (kv_f, ksum_f, vsum_f, _), out = jax.lax.scan(step, carry, (bq_c, bk_c, v_c))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, h, nc * chunk, dv)
    out = out[:, :, :n]
    if not return_state:
        return out
    # count is the number of *real* tokens per row (the scan's cnt includes
    # padding). Per-row so a packed decode batch can hold slots at different
    # positions (continuous batching).
    if lengths is not None:
        count = lengths.astype(q.dtype)
    else:
        count = jnp.full((b,), float(n), q.dtype)
    state = {"kv": kv_f, "ksum": ksum_f, "vsum": vsum_f, "count": count}
    return out, state


def init_decode_state(batch, heads, dk, dv, dtype=jnp.float32):
    """O(1) recurrent state for autoregressive decode (replaces the KV cache).

    Every leaf — including "count" — carries the batch axis, so admitting or
    evicting one request from a packed decode batch is a single-axis
    gather/scatter over the whole pytree (serve.lm.BucketedLMEngine).
    """
    return {
        "kv": jnp.zeros((batch, heads, dk, dv), dtype),
        "ksum": jnp.zeros((batch, heads, dk), dtype),
        "vsum": jnp.zeros((batch, heads, dv), dtype),
        "count": jnp.zeros((batch,), dtype),
    }


def binary_linear_attention_step(q_t, k_t, v_t, state, feature="binary"):
    """One decode step. q_t/k_t: (B,H,Dk), v_t: (B,H,Dv). Causal incl. self.

    Featurization goes through the same `_featurize` as the chunked path, so
    the decode step and prefill can never diverge on the code definition.
    """
    bq, bk, d = _featurize(q_t, k_t, feature)
    kv = state["kv"] + bk[..., :, None] * v_t[..., None, :]
    ksum = state["ksum"] + bk
    vsum = state["vsum"] + v_t
    count = state["count"] + 1.0
    num = jnp.einsum("bhd,bhde->bhe", bq, kv) + d * vsum
    den = jnp.einsum("bhd,bhd->bh", bq, ksum) + d * count[:, None]
    out = num / (den[..., None] + 1e-6)
    new_state = {"kv": kv, "ksum": ksum, "vsum": vsum, "count": count}
    return out, new_state


class BinaryLinearAttention:
    """Config wrapper so model code can treat attention math uniformly."""

    def __init__(self, causal=False, chunk=128, feature="binary"):
        self.causal = causal
        self.chunk = chunk
        self.feature = feature

    def __call__(self, q, k, v, train=True):
        return binary_linear_attention(
            q, k, v, causal=self.causal, chunk=self.chunk, train=train,
            feature=self.feature)
