"""Serving-contract static analysis (ISSUE 6 tentpole).

Three passes over the frozen serving surface, one CLI
(``python -m repro.analysis.check``), one CI gate:

- **jaxpr_audit** — builds the jaxpr of every frozen serving entry point
  (each `BucketedViTEngine` bucket program across the sweep policies, the LM
  prefill / scan-fused decode) and statically asserts the invariants PRs 3-5
  enforce only at runtime: no host callbacks, no float64 / weak-type values
  crossing jaxpr boundaries, identical dtype signatures across buckets (the
  recompile-hazard class), declared buffer donation actually consumed, and a
  determinism allowlist (no rng, no float scatter-adds) on `infer` paths.
- **kernel_contracts** — the kernel × bucket-geometry compatibility matrix:
  for every Pallas kernel in `repro.kernels` at every bucket geometry,
  classify the cell tile_aligned / pad_and_slice / vmem_overflow, with the
  VMEM-residency estimate and the roofline cost terms. The table is the
  search-space validator for the ROADMAP autotune layer.
- **lint** — AST jit-hazard lint over `src/repro`: host ops (`np.*`,
  `.item()`, `float()`) reachable from jitted functions, trace-time mutable
  state, rng threading into `infer*` functions, jit wrappers missing
  donation on cache/state-shaped arguments.

Findings share one schema (`findings.Finding`); suppression is explicit and
reviewable (inline ``# lint: allow(RULE reason)`` for AST findings, the
`findings.ALLOWLIST` table for pass-level ones).
"""
from repro.analysis.findings import Finding  # noqa: F401
