"""Pass 3: AST jit-hazard lint over src/repro.

Finds Python-side hazards that jaxprs cannot show (they happen at trace
time or poison the trace cache) by walking each module's AST, building the
set of functions reachable from a jit root, and checking:

=====  ===========================================================
LT001  `np.*(param)` — a numpy call applied to a traced argument
       inside a jit-reachable function (silent host fallback or
       TracerError at call time)
LT002  host sync inside a jit-reachable function: `.item()`,
       `.tolist()`, or `float()/int()/bool()` applied to a traced
       argument
LT003  rng threading into an `infer*` function (a named rng/key
       parameter or a `jax.random.*` call) — infer paths are
       contractually deterministic (see jaxpr_audit JX006)
LT004  trace-time mutable state: assignment to `self.*` or a
       `global`/`nonlocal` statement inside a jit-reachable
       function (runs once per TRACE, not per call)
LT005  `jax.jit` wrapper whose wrapped function takes a cache/
       state-shaped parameter without donating it (the serving
       convention: decode caches and train states are donated)
=====  ===========================================================

Jit roots: functions decorated with `jax.jit` (bare or via
functools.partial), function names/lambdas passed to `jax.jit(...)` calls,
and bodies handed to `lax.scan` / `while_loop` / `fori_loop` / `cond` /
`switch` / `map`. Reachability propagates through same-module calls by name.

Suppression is inline and auditable: a ``# lint: allow(RULE reason)``
comment on the flagged line or the line directly above waives exactly that
rule at that site (e.g. the engine's trace-time compile counter, which is
deliberate and pinned by the zero-recompile gates).
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.findings import Finding

RULES = {
    "LT001": "numpy call on a traced argument in jitted code",
    "LT002": "host sync (.item()/float()) in jitted code",
    "LT003": "rng threaded into an infer* function",
    "LT004": "trace-time mutable state in jitted code",
    "LT005": "jit wrapper missing donation on a cache/state argument",
}

DONATABLE_PARAMS = ("cache", "state", "opt_state")
RNG_PARAM_NAMES = ("rng", "key", "prng_key", "rng_key", "rngs")
LOOP_BODY_FUNS = {"scan", "while_loop", "fori_loop", "cond", "switch", "map",
                  "associative_scan"}
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\((LT\d{3})\b")


def _dotted(node):
    """'jax.jit'-style dotted name of a Name/Attribute chain, or ''. """
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _param_names(fn):
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    return names, [p.arg for p in a.kwonlyargs]


class _ModuleLint:
    def __init__(self, tree, relpath, source_lines):
        self.tree = tree
        self.relpath = relpath
        self.lines = source_lines
        self.findings = []
        # name → [FunctionDef] for every def anywhere in the module; names
        # collide across scopes but for reachability that only over-approximates.
        self.defs = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, []).append(node)

    # -- reporting ----------------------------------------------------------

    def _allowed(self, rule, lineno):
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                m = _ALLOW_RE.search(self.lines[ln - 1])
                if m and m.group(1) == rule:
                    return True
        return False

    def report(self, rule, node, message):
        if not self._allowed(rule, node.lineno):
            self.findings.append(Finding(
                rule=rule, pass_name="lint",
                where=f"{self.relpath}:{node.lineno}", message=message))

    # -- jit roots & reachability ------------------------------------------

    def _is_jit_decorator(self, dec):
        name = _dotted(dec)
        if name.endswith("jit"):
            return True
        if isinstance(dec, ast.Call):
            callee = _dotted(dec.func)
            if callee.endswith("jit"):
                return True
            if callee.endswith("partial") and dec.args:
                return _dotted(dec.args[0]).endswith("jit")
        return False

    def _resolve_callable(self, node):
        """A function-valued expression → (FunctionDef|Lambda|None)."""
        if isinstance(node, ast.Lambda):
            return node
        if isinstance(node, ast.Name) and node.id in self.defs:
            return self.defs[node.id][-1]
        if isinstance(node, ast.Call):
            factory = self._resolve_callable(node.func)
            if isinstance(factory, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(factory):
                    if isinstance(sub, ast.Return) and sub.value is not None:
                        return self._resolve_callable(sub.value)
        return None

    def jit_roots(self):
        roots = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(self._is_jit_decorator(d) for d in node.decorator_list):
                    roots.append(node)
            elif isinstance(node, ast.Call):
                callee = _dotted(node.func)
                fn_args = []
                if callee.endswith("jit") and node.args:
                    fn_args = [node.args[0]]
                elif callee.split(".")[-1] in LOOP_BODY_FUNS:
                    fn_args = [a for a in node.args
                               if isinstance(a, (ast.Lambda, ast.Name))]
                for a in fn_args:
                    fn = self._resolve_callable(a)
                    if fn is not None:
                        roots.append(fn)
        return roots

    def reachable(self):
        seen, work = [], self.jit_roots()
        while work:
            fn = work.pop()
            if any(fn is s for s in seen):
                continue
            seen.append(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = _dotted(node.func)
                    base = callee.split(".")[0]
                    if base in self.defs and "." not in callee:
                        work.append(self.defs[base][-1])
        return seen

    # -- rules --------------------------------------------------------------

    def _static_params(self, fn):
        """Params declared static in the function's jit decorator — those
        are Python values at trace time, not tracers."""
        static = set()
        pos, _ = ([p.arg for p in fn.args.args], None) \
            if not isinstance(fn, ast.Lambda) else ([], None)
        for dec in getattr(fn, "decorator_list", []):
            if not (isinstance(dec, ast.Call) and self._is_jit_decorator(dec)):
                continue
            for kw in dec.keywords:
                if kw.arg not in ("static_argnames", "static_argnums"):
                    continue
                try:
                    val = ast.literal_eval(kw.value)
                except (ValueError, SyntaxError):
                    continue
                vals = (val,) if isinstance(val, (str, int)) else tuple(val)
                for v in vals:
                    if isinstance(v, str):
                        static.add(v)
                    elif isinstance(v, int) and v < len(pos):
                        static.add(pos[v])
        return static

    def check_function(self, fn):
        if isinstance(fn, ast.Lambda):
            params = {p.arg for p in fn.args.args}
            body_nodes = list(ast.walk(fn.body))
        else:
            pos, kwo = _param_names(fn)
            params = (set(pos) | set(kwo)) - {"self"} - self._static_params(fn)
            body_nodes = [n for stmt in fn.body for n in ast.walk(stmt)]
        for node in body_nodes:
            # Nested defs are separate reachability targets; don't re-lint
            # their bodies against the OUTER function's params.
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee.startswith(("np.", "numpy.")):
                    traced = [a.id for a in node.args
                              if isinstance(a, ast.Name) and a.id in params]
                    if traced:
                        self.report("LT001", node,
                                    f"`{callee}({traced[0]}, ...)` applies "
                                    "numpy to a traced argument inside "
                                    "jit-reachable code")
                if callee in ("float", "int", "bool") and node.args:
                    a = node.args[0]
                    if isinstance(a, ast.Name) and a.id in params:
                        self.report("LT002", node,
                                    f"`{callee}({a.id})` forces a host sync "
                                    "on a traced argument in jitted code")
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("item", "tolist")):
                    self.report("LT002", node,
                                f"`.{node.func.attr}()` forces a host sync "
                                "inside jit-reachable code")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        self.report("LT004", node,
                                    f"assignment to `self.{t.attr}` inside "
                                    "jit-reachable code runs at trace time, "
                                    "not per call")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                self.report("LT004", node,
                            "global/nonlocal mutation inside jit-reachable "
                            "code runs at trace time, not per call")

    def check_infer_rng(self):
        for fns in self.defs.values():
            for fn in fns:
                if not fn.name.lstrip("_").startswith("infer"):
                    continue
                pos, kwo = _param_names(fn)
                for p in pos + kwo:
                    if p in RNG_PARAM_NAMES:
                        self.report("LT003", fn,
                                    f"`{fn.name}` takes rng parameter "
                                    f"`{p}` — infer paths are deterministic "
                                    "by contract")
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call):
                        callee = _dotted(node.func)
                        if (callee.startswith(("jax.random.", "random."))
                                and not callee.startswith("random.Random")):
                            self.report("LT003", node,
                                        f"`{callee}` sampled inside "
                                        f"`{fn.name}` — infer paths are "
                                        "deterministic by contract")

    def check_jit_donation(self):
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and _dotted(node.func).endswith("jit") and node.args):
                continue
            fn = self._resolve_callable(node.args[0])
            if fn is None:
                continue
            if isinstance(fn, ast.Lambda):
                pos = [p.arg for p in fn.args.args]
            else:
                pos, _ = _param_names(fn)
            want = [i for i, p in enumerate(pos) if p in DONATABLE_PARAMS]
            if not want:
                continue
            donate_kw = next((kw.value for kw in node.keywords
                              if kw.arg in ("donate_argnums", "donate_argnames")),
                             None)
            if donate_kw is None:
                self.report("LT005", node,
                            f"jit of `{getattr(fn, 'name', '<lambda>')}` "
                            f"does not donate `{pos[want[0]]}` (argnum "
                            f"{want[0]}) — serving convention donates "
                            "cache/state buffers")
                continue
            try:
                declared = ast.literal_eval(donate_kw)
            except (ValueError, SyntaxError):
                continue   # dynamic expression — out of static reach
            declared = ({declared} if isinstance(declared, int)
                        else set(declared) if isinstance(declared, (tuple, list))
                        else None)
            if declared is None:
                continue
            for i in want:
                if i not in declared and pos[i] not in declared:
                    self.report("LT005", node,
                                f"jit donates {sorted(declared)} but not "
                                f"`{pos[i]}` (argnum {i})")

    def run(self):
        for fn in self.reachable():
            self.check_function(fn)
        self.check_infer_rng()
        self.check_jit_donation()
        return self.findings


def lint_source(source: str, relpath: str):
    tree = ast.parse(source)
    return _ModuleLint(tree, relpath, source.splitlines()).run()


def lint_file(path: str, root: str = None):
    with open(path) as fh:
        source = fh.read()
    rel = os.path.relpath(path, root) if root else path
    return lint_source(source, rel)


def run(root=None):
    """Lint every module under src/repro → (findings, n_files)."""
    if root is None:
        import repro
        root = os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__)))
    findings, n = [], 0
    for dirpath, dirnames, filenames in os.walk(os.path.join(root, "repro")):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                findings += lint_file(os.path.join(dirpath, fname), root)
                n += 1
    return findings, n
