"""Pass 1: static jaxpr audit of the frozen serving entry points.

Builds the jaxpr of every serving program — each `BucketedViTEngine` bucket
program across the sweep policies (frozen arm at every `DEFAULT_BUCKETS`
geometry, live A/B arm at one), every reserve engine of the elastic warm
pools (parked spares included, both the dense primary and the shiftadd
degrade arm — the surface the zero-recompile invariant counts), the LM
`prefill` + scan-fused decode loop,
and the continuous-batching `BucketedLMEngine` program set (bucket-shaped
prefill, scan-fused decode chunk, admit/evict slot scatters — surfaced by
the engine as `engine.programs`) — via `jax.make_jaxpr` over
`ShapeDtypeStruct`s (no compile, no execution) and checks the contracts
PRs 3-5 otherwise enforce only at runtime:

=====  ==========================================================
JX001  host callback / debug print primitive in a serving program
JX002  float64 value materialized (x64 promotion leak)
JX003  weak-typed value crossing a jaxpr boundary (entry or
       pjit/scan/cond outvar) — the retrace-on-dtype hazard class
JX004  dtype signature differs across bucket programs of one
       policy (the recompile hazard PR 4 fixed by hand)
JX005  declared buffer donation not consumed by the lowering
       (donated input aliases no output — dead weight + warnings)
JX006  rng primitive on a deterministic `infer` path
JX007  floating-point scatter-add on a deterministic path
       (nondeterministic accumulation order on parallel backends)
JX008  a warm-pool reserve engine traces a different program than
       engine 0 at the same bucket (replacement not a drop-in)
=====  ==========================================================

Each audit builds its OWN engines/models — never hand it a warmed engine
whose `trace_count` a zero-recompile gate is watching, because tracing the
bucket programs increments the counter.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis.ir import eqn_source, iter_eqns, subjaxprs

RULES = {
    "JX001": "host callback / debug print in serving program",
    "JX002": "float64 value materialized",
    "JX003": "weak-typed value crossing a jaxpr boundary",
    "JX004": "dtype signature differs across bucket programs",
    "JX005": "declared buffer donation not consumed",
    "JX006": "rng primitive on a deterministic infer path",
    "JX007": "float scatter-add on a deterministic path",
    "JX008": "program differs across warm-pool reserve engines",
}

CALLBACK_PRIMITIVES = frozenset({
    "debug_callback", "pure_callback", "io_callback", "outside_call",
    "host_callback_call", "debug_print",
})

RNG_PRIMITIVES = frozenset({
    "random_bits", "random_wrap", "random_unwrap", "random_seed",
    "random_fold_in", "random_gamma", "threefry2x32", "rng_bit_generator",
})


def _f(rule, where, message):
    return Finding(rule=rule, where=where, message=message, pass_name="jaxpr")


def _is_weak(aval) -> bool:
    return bool(getattr(aval, "weak_type", False))


def _is_f64(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and dt in (jnp.float64, jnp.complex128)


def audit_closed_jaxpr(closed, where, *, deterministic=True):
    """Audit one serving program (a ClosedJaxpr): JX001/2/3/6/7."""
    findings = []
    for eqn, path in iter_eqns(closed):
        name = eqn.primitive.name
        loc = f"{where} [{path or 'entry'} @ {eqn_source(eqn)}]"
        if name in CALLBACK_PRIMITIVES:
            findings.append(_f("JX001", loc, f"host callback `{name}` in a "
                               "serving program (host round-trip per call)"))
        if deterministic and name in RNG_PRIMITIVES:
            findings.append(_f("JX006", loc, f"rng primitive `{name}` on a "
                               "deterministic infer path"))
        for var in eqn.outvars:
            if _is_f64(var.aval):
                findings.append(_f("JX002", loc, f"`{name}` materializes "
                                   f"{var.aval.dtype} — float64 promotion "
                                   "leak (x64 must stay off in serving)"))
                break
        if deterministic and name == "scatter-add":
            if any(jnp.issubdtype(getattr(v.aval, "dtype", jnp.int32),
                                  jnp.floating) for v in eqn.outvars):
                findings.append(_f("JX007", loc, "floating-point scatter-add "
                                   "— accumulation order is nondeterministic "
                                   "on parallel backends"))
        # Weak types are only a hazard when they ESCAPE a jaxpr: a weak
        # literal broadcast consumed in place is benign, but a weak outvar of
        # a pjit/scan/entry re-keys the jit cache of whoever consumes it.
        if any(True for _ in subjaxprs(eqn)):
            for var in eqn.outvars:
                if _is_weak(var.aval):
                    findings.append(_f("JX003", loc, f"`{name}` returns a "
                                       f"weak-typed {var.aval.dtype} across "
                                       "a jaxpr boundary (retrace hazard)"))
                    break
    for var in closed.jaxpr.outvars:
        aval = getattr(var, "aval", None)
        if aval is not None and _is_weak(aval):
            findings.append(_f("JX003", f"{where} [entry outvar]",
                               f"serving program returns a weak-typed "
                               f"{aval.dtype} (retrace hazard downstream)"))
    return findings


def dtype_signature(closed):
    """Hashable dtype fingerprint of a program, for cross-bucket comparison.

    (input dtypes, output dtypes, sorted set of every dtype materialized
    anywhere in the program) — shapes excluded on purpose: buckets legally
    differ in batch, never in dtype (that is the recompile hazard).
    """
    ins = tuple(str(v.aval.dtype) for v in closed.jaxpr.invars)
    outs = tuple(str(v.aval.dtype) for v in closed.jaxpr.outvars)
    body = set()
    for eqn, _ in iter_eqns(closed):
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            if dt is not None:
                body.add(str(dt))
    return (ins, outs, tuple(sorted(body)))


def check_donation(fn, donate_argnums, args, where):
    """JX005: lower `fn` with the declared donation and verify consumption.

    A consumed donation shows up as `tf.aliasing_output` attrs in the
    lowered StableHLO (CPU included); an unconsumable one additionally
    raises jax's "donated buffers were not usable" warning. Both are
    checked, so the rule works even if the warning text drifts.
    """
    findings = []
    if not donate_argnums:
        return findings
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        lowered = jax.jit(fn, donate_argnums=tuple(donate_argnums)).lower(*args)
        text = lowered.as_text()
    for w in caught:
        msg = str(w.message)
        if "donated" in msg.lower():
            findings.append(_f("JX005", where,
                               f"declared donation not consumed: {msg.splitlines()[0]}"))
    if "tf.aliasing_output" not in text and not findings:
        findings.append(_f("JX005", where,
                           f"donate_argnums={tuple(donate_argnums)} declared "
                           "but no input-output aliasing in the lowering"))
    return findings


# ---------------------------------------------------------------------------
# Entry-point inventory: ViT serving engines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AuditedProgram:
    where: str
    n_eqns: int


def audit_vit_serving(base_cfg=None, policies=None, buckets=None):
    """Audit every BucketedViTEngine bucket program across the sweep arms.

    Frozen arm at every bucket (the serving default; also the JX004
    cross-bucket signature comparison), live A/B arm at the smallest bucket
    (its weak/callback/rng hazards are geometry-independent, and the live
    forward is where per-call decode code like core.quant actually runs).
    Returns (findings, audited) — `audited` is the program inventory the
    tests assert coverage on.
    """
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve.vision import (BucketedViTEngine, DEFAULT_BUCKETS,
                                    SWEEP_POLICIES, build_policy_model)
    from repro.core.policy import DENSE

    base_cfg = base_cfg or ViTConfig()
    policies = tuple(policies or SWEEP_POLICIES)
    buckets = tuple(buckets or DEFAULT_BUCKETS)
    findings, audited = [], []

    dense_model = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = jax.eval_shape(dense_model.init, jax.random.PRNGKey(0))
    # convert_from needs real leaves (it inspects values when packing), so
    # materialize zeros of the right shapes — cheaper than a real init and
    # dtype-faithful, which is all a static audit needs.
    dense_params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), dense_params)

    img_shape = (base_cfg.image_size, base_cfg.image_size,
                 base_cfg.in_channels)
    for name in policies:
        model, params = build_policy_model(base_cfg, name, dense_model,
                                           dense_params)
        engine = BucketedViTEngine(model, params, buckets=buckets,
                                   freeze=True)
        signatures = {}
        for b in engine.buckets:
            where = f"vit/{name}/frozen/bucket={b}"
            spec = jax.ShapeDtypeStruct((b,) + img_shape, jnp.float32)
            closed = jax.make_jaxpr(engine._call)(spec)
            findings += audit_closed_jaxpr(closed, where)
            signatures[b] = dtype_signature(closed)
            audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
        ref_bucket = engine.buckets[0]
        for b, sig in signatures.items():
            if sig != signatures[ref_bucket]:
                findings.append(_f(
                    "JX004", f"vit/{name}/frozen/bucket={b}",
                    f"dtype signature differs from bucket={ref_bucket} — "
                    "bucketed programs must differ only in batch shape "
                    f"(got {sig} vs {signatures[ref_bucket]})"))
        where = f"vit/{name}/frozen/donation"
        findings += check_donation(
            engine._fwd, engine.donate_argnums,
            (jax.ShapeDtypeStruct((ref_bucket,) + img_shape, jnp.float32),),
            where)

        live = BucketedViTEngine(model, params, buckets=(buckets[0],),
                                 freeze=False)
        where = f"vit/{name}/live/bucket={live.buckets[0]}"
        spec = jax.ShapeDtypeStruct((live.buckets[0],) + img_shape,
                                    jnp.float32)
        closed = jax.make_jaxpr(live._call)(spec)
        findings += audit_closed_jaxpr(closed, where)
        audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
    return findings, audited


# ---------------------------------------------------------------------------
# Entry-point inventory: elastic warm-pool reserve engines
# ---------------------------------------------------------------------------

def audit_elastic_serving(base_cfg=None, *, max_replicas=2, spares=1,
                          buckets=None):
    """Audit every reserve engine of the elastic warm pools, both arms.

    The elastic control plane's zero-recompile invariant counts jit traces
    over EVERY reserve engine — parked spares included — of BOTH pools
    (dense primary, shiftadd degrade), so the audited surface here is
    exactly that inventory: one entry per arm × reserve engine × bucket
    (primary carries max_replicas + spares engines, the degrade arm one,
    mirroring elastic_sweep). Each program gets the standard per-program
    rules, plus JX008 — a cross-ENGINE extension of JX004's cross-bucket
    signature check: every reserve engine of an arm must trace the same
    dtype signature AND equation count per bucket, because a warm-pool
    attach/kill replacement that serves a different program than the
    replica it replaced would silently break both the zero-recompile gate
    and bit-identical replay.
    """
    from repro.core.policy import DENSE
    from repro.nn.vit import ShiftAddViT, ViTConfig
    from repro.serve.elastic import ElasticWarmPool
    from repro.serve.vision import DEFAULT_BUCKETS, build_policy_model

    base_cfg = base_cfg or ViTConfig()
    buckets = tuple(buckets or DEFAULT_BUCKETS)
    findings, audited = [], []

    dense_model = ShiftAddViT(dataclasses.replace(base_cfg, policy=DENSE))
    dense_params = jax.eval_shape(dense_model.init, jax.random.PRNGKey(0))
    dense_params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), dense_params)
    sa_model, sa_params = build_policy_model(base_cfg, "shiftadd",
                                             dense_model, dense_params)
    img_shape = (base_cfg.image_size, base_cfg.image_size,
                 base_cfg.in_channels)

    arms = (
        ("primary", ElasticWarmPool(dense_model, dense_params,
                                    max_replicas=max_replicas, spares=spares,
                                    buckets=buckets, freeze=True)),
        ("degrade", ElasticWarmPool(sa_model, sa_params, max_replicas=1,
                                    spares=0, buckets=buckets, freeze=True)),
    )
    for arm_name, pool in arms:
        # fingerprints[bucket] = (dtype signature, n_eqns) of engine 0 —
        # the reference every other reserve engine must reproduce.
        fingerprints = {}
        for eid, engine in enumerate(pool.engines):
            for b in engine.buckets:
                where = f"elastic/{arm_name}/engine={eid}/bucket={b}"
                spec = jax.ShapeDtypeStruct((b,) + img_shape, jnp.float32)
                closed = jax.make_jaxpr(engine._call)(spec)
                findings += audit_closed_jaxpr(closed, where)
                audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
                fp = (dtype_signature(closed), len(closed.jaxpr.eqns))
                if b not in fingerprints:
                    fingerprints[b] = fp
                elif fp != fingerprints[b]:
                    findings.append(_f(
                        "JX008", where,
                        f"engine {eid} traces a different program than "
                        f"engine 0 at bucket={b} (signature/eqn-count "
                        f"{fp} vs {fingerprints[b]}) — a warm-pool "
                        "replacement would not be a drop-in replica"))
        pool.close()
    return findings, audited


# ---------------------------------------------------------------------------
# Entry-point inventory: LM prefill / scan-fused decode
# ---------------------------------------------------------------------------

def _tiny_lm(policy):
    from repro.configs.base import ModelConfig
    from repro.nn.model import LanguageModel

    kw = {} if policy is None else {"policy": policy}
    cfg = ModelConfig(name="audit-lm", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", scan_layers=True, remat="none", **kw)
    return LanguageModel(cfg)


def audit_lm_serving(batch=2, prompt_len=13, gen_len=8):
    """Audit LM serving: chunked prefill + the scan-fused greedy decode loop.

    Tiny 2-layer models (the audit is about program structure, not weights)
    over the dense and stage-1 (binary linear attention + shift projection)
    arms. The decode loop is audited at temperature=0 — THE deterministic
    serving arm; sampling arms legitimately use rng and are out of scope.
    Cache donation (argnum 2 on both entry points, per serve.decode.generate)
    must actually be consumed: the cache is the one serving buffer whose
    donation pays for itself every token.
    """
    from repro.core.policy import STAGE1
    from repro.serve.decode import make_decode_loop, make_prefill

    findings, audited = [], []
    max_len = prompt_len + gen_len
    for name, policy in (("dense", None), ("stage1", STAGE1)):
        model = _tiny_lm(policy)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        cache = jax.eval_shape(
            lambda m=model: m.init_cache(batch, max_len=max_len))
        prompts = jax.ShapeDtypeStruct((batch, prompt_len), jnp.int32)

        prefill = make_prefill(model)
        where = f"lm/{name}/prefill"
        closed = jax.make_jaxpr(prefill)(params, prompts, cache)
        findings += audit_closed_jaxpr(closed, where)
        audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
        findings += check_donation(prefill, (2,), (params, prompts, cache),
                                   f"{where}/donation")

        loop = make_decode_loop(model, temperature=0.0)
        logits0 = jax.ShapeDtypeStruct((batch, model.cfg.vocab_size),
                                       jnp.float32)
        keys = jax.ShapeDtypeStruct((gen_len, 2), jnp.uint32)
        where = f"lm/{name}/decode"
        closed = jax.make_jaxpr(loop)(params, logits0, cache, keys)
        findings += audit_closed_jaxpr(closed, where)
        audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
        findings += check_donation(loop, (2,), (params, logits0, cache, keys),
                                   f"{where}/donation")
    return findings, audited


def audit_lm_continuous(n_slots=2, prompt_bucket=8, max_len=24, chunk=4):
    """Audit the `BucketedLMEngine` continuous-batching program set.

    The engine surfaces its raw traced fns as `engine.programs` (prefill,
    decode_chunk, admit, evict) and its declared donations as
    `engine.donate_argnums` precisely so this pass can audit what serving
    jits. The WHOLE set is deterministic serving (greedy argmax — no
    sampling arm), so JX006 applies to every program, and the cache pytree
    is donated at every point it is consumed (the slot array is the one
    buffer continuous batching rewrites on every admit/evict/chunk), so
    JX005 verifies each program's declared donation actually aliases.
    """
    from repro.core.policy import STAGE1
    from repro.serve.lm import BucketedLMEngine

    findings, audited = [], []
    for name, policy in (("dense", None), ("stage1", STAGE1)):
        model = _tiny_lm(policy)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params)
        engine = BucketedLMEngine(model, params, n_slots=n_slots,
                                  prompt_buckets=(prompt_bucket,),
                                  chunk=chunk, max_len=max_len)
        cache = jax.eval_shape(lambda: model.init_cache(n_slots, max_len))
        row = jax.eval_shape(lambda: model.init_cache(1, max_len))
        toks = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
        ptoks = jax.ShapeDtypeStruct((1, prompt_bucket), jnp.int32)
        length = jax.ShapeDtypeStruct((1,), jnp.int32)
        first = jax.ShapeDtypeStruct((1,), jnp.int32)
        slot = jax.ShapeDtypeStruct((), jnp.int32)
        args_by_program = {
            "prefill": (params, ptoks, length, row),
            "decode_chunk": (params, toks, cache),
            "admit": (cache, toks, row, first, slot),
            "evict": (cache, slot),
        }
        for pname, args in args_by_program.items():
            fn = engine.programs[pname]
            where = f"lm/{name}/continuous/{pname}"
            closed = jax.make_jaxpr(fn)(*args)
            findings += audit_closed_jaxpr(closed, where)
            audited.append(AuditedProgram(where, len(closed.jaxpr.eqns)))
            donate_key = "decode" if pname == "decode_chunk" else pname
            findings += check_donation(fn,
                                       engine.donate_argnums[donate_key],
                                       args, f"{where}/donation")
    return findings, audited


def run(base_cfg=None):
    """The full pass: (findings, audited-program inventory)."""
    f_vit, a_vit = audit_vit_serving(base_cfg)
    f_el, a_el = audit_elastic_serving(base_cfg)
    f_lm, a_lm = audit_lm_serving()
    f_lmc, a_lmc = audit_lm_continuous()
    return (f_vit + f_el + f_lm + f_lmc,
            a_vit + a_el + a_lm + a_lmc)
