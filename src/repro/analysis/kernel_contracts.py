"""Pass 2: Pallas kernel contract checker — the kernel × geometry matrix.

For every Pallas kernel in `repro.kernels` at every `DEFAULT_BUCKETS` batch
geometry of the serving ViT, compute the contract cell the ROADMAP autotune
layer needs as its search-space validator:

- **block geometry**: the exact (bm, bn, bk / chunk) the `kernels.ops`
  wrappers would pick (shared helpers `ops.sublane_block`/`ops.lane_block` —
  the table models the code, it does not re-guess it), the resulting grid,
  and how much each dimension is padded.
- **classification**: `tile_aligned` (no padding anywhere), `pad_and_slice`
  (the kernel zero-pads to the tile grid and slices back — correct but
  wasted MACs/bandwidth; the expected state at the CIFAR-scale geometry,
  e.g. shift_matmul pads K 128→512), or `vmem_overflow` (the per-grid-step
  working set exceeds the VMEM budget — the kernel will not fit; the ONLY
  classification that is a Finding, rule KC001).
- **roofline terms**: padded-volume compute time against the int8/bf16 MXU
  peak and HBM traffic time, using the same peak/bandwidth constants as
  `benchmarks/roofline.py` (`repro.core.energy`), plus the fraction of MACs
  spent on padding — the number the autotune layer minimizes.

VMEM accounting: every in/out BlockSpec block counts TWICE (Pallas
double-buffers pipelined blocks), scratch once, against a 16 MiB/core
budget (the v4/v5 figure from the Pallas TPU guide).
"""
from __future__ import annotations

import dataclasses
import os
import re

from repro.analysis.findings import Finding
from repro.core.energy import HBM_BW, PEAK_FLOPS_BF16, PEAK_OPS_INT8

RULES = {"KC001": "kernel working set exceeds the VMEM budget"}

VMEM_BUDGET_BYTES = 16 * 2 ** 20
F32 = 4  # activation / accumulator bytes


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class Cell:
    """One kernel × site × bucket contract entry (a row of the table)."""
    kernel: str
    site: str
    bucket: int
    geometry: dict         # true problem sizes
    blocks: dict           # chosen block sizes
    grid: tuple
    padded: dict           # padded problem sizes
    classification: str    # tile_aligned | pad_and_slice | vmem_overflow
    vmem_bytes: int
    vmem_frac: float
    pad_mac_waste: float   # fraction of executed MACs that hit padding
    t_compute_s: float
    t_memory_s: float
    bound: str             # compute | memory

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _finish(kernel, site, bucket, geometry, blocks, grid, padded, vmem,
            flops_padded, flops_true, hbm_bytes, peak):
    t_c = flops_padded / peak
    t_m = hbm_bytes / HBM_BW
    overflow = vmem > VMEM_BUDGET_BYTES
    aligned = all(padded[k] == geometry[k] for k in padded)
    return Cell(
        kernel=kernel, site=site, bucket=bucket, geometry=geometry,
        blocks=blocks, grid=tuple(grid), padded=padded,
        classification=("vmem_overflow" if overflow
                        else "tile_aligned" if aligned else "pad_and_slice"),
        vmem_bytes=int(vmem), vmem_frac=vmem / VMEM_BUDGET_BYTES,
        pad_mac_waste=1.0 - flops_true / max(flops_padded, 1.0),
        t_compute_s=t_c, t_memory_s=t_m,
        bound="compute" if t_c >= t_m else "memory")


# ---------------------------------------------------------------------------
# Per-kernel cell models (mirroring each wrapper's block selection exactly)
# ---------------------------------------------------------------------------

def matmul_cell(kernel, site, bucket, g, m, k, n, *, w_bytes, adapt_bn,
                packed_k=False, blocks=None):
    """shift_matmul / add_matmul / add_matmul_packed share one dataflow:
    grid (G, M/bm, N/bn, K/bk) with an (bm, bn) f32 VMEM accumulator.

    blocks=None models the wrappers' UNTUNED defaults; a dict of tuned caps
    (bm/bn/bk or bk8) replays exactly the resolution `kernels.ops` applies to
    a TuneTable hit (`sublane_block`/`lane_block`/`kdim_block` covers), so
    the autotuner's cost oracle and the launched grid can never diverge."""
    from repro.kernels import add_matmul as _addmm
    from repro.kernels import add_matmul_packed as _pk
    from repro.kernels import ops
    from repro.kernels import shift_matmul as _shiftmm

    mod = {"shift_matmul": _shiftmm, "add_matmul": _addmm,
           "add_matmul_packed": _pk}[kernel]
    if blocks is None:
        bm = ops.sublane_block(m, mod.BM)
        bn = ops.lane_block(n, mod.BN) if adapt_bn else mod.BN
        bk = mod.BK8 * 8 if packed_k else mod.BK
    else:
        bm = ops.sublane_block(m, blocks.get("bm", mod.BM))
        bn = ops.lane_block(n, blocks.get("bn", mod.BN))
        if packed_k:
            bk = 8 * ops.packed_kdim_block(-(-k // 8),
                                           blocks.get("bk8", mod.BK8))
        else:
            bk = ops.kdim_block(k, blocks.get("bk", mod.BK))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    grid_mnk = (mp // bm, np_ // bn, kp // bk)
    grid = grid_mnk if g == 1 and kernel == "shift_matmul" else (g,) + grid_mnk
    # Weight-block bytes: packed kernels hold K/8 rows per logical K block.
    wk_rows = bk // 8 if packed_k else bk
    vmem = (2 * (bm * bk * F32 + wk_rows * bn * w_bytes + bm * bn * F32)
            + bm * bn * F32)
    # HBM traffic: x re-read per N-tile, weights re-read per M-tile, out once.
    hbm = g * (mp * kp * F32 * grid_mnk[1]
               + (kp // 8 if packed_k else kp) * np_ * w_bytes * grid_mnk[0]
               + mp * np_ * F32)
    return _finish(kernel, site, bucket,
                   {"g": g, "m": m, "k": k, "n": n},
                   {"bm": bm, "bn": bn, "bk": bk},
                   grid, {"m": mp, "k": kp, "n": np_},
                   vmem, 2.0 * g * mp * kp * np_, 2.0 * g * m * k * n, hbm,
                   PEAK_OPS_INT8)


def linear_attention_cell(bucket, g, n, dk, dv, *, blocks=None):
    """Chunked causal kernel: grid (G, N/chunk); carry (dk, dv) in VMEM.

    blocks={"chunk": c} overrides the VMEM-residency chunk the same way a
    TuneTable hit does in `ops.binary_linear_attention_fused`.

    The MAC law counts every contraction the kernel executes per chunk — the
    inter-chunk terms bq@KV and bkᵀ@v AND the intra-chunk causal pair
    s = bq@bkᵀ, s@v (each chunk² · head-dim). The old law modeled only the
    inter-chunk 4·n·dk·dv terms, so at the 196-token serving geometry
    (chunk = n) it under-counted the executed MACs by more than half and the
    pad-waste the tuner minimizes drifted from what the wrapper launches."""
    from repro.kernels import linear_attention as _linattn

    chunk = min((blocks or {}).get("chunk", _linattn.CHUNK), n)
    dkp, dvp = _ceil_to(dk, 128), _ceil_to(dv, 128)
    np_ = _ceil_to(n, chunk)
    grid = (g, np_ // chunk)
    vmem = (2 * (2 * chunk * dkp * F32 + chunk * dvp * F32   # q, k | v
                 + chunk * dvp * F32)                        # out
            + (dkp * dvp + dkp + dvp) * F32                  # carry scratch
            + chunk * chunk * F32)                           # intra-chunk S
    hbm = g * ((2 * np_ * dkp + 2 * np_ * dvp) * F32)

    def flops(nn, c, a, b):
        # 2 flops/MAC × (bq@KV + bkᵀ@v: nn·a·b each; bq@bkᵀ: nn·c·a; s@v:
        # nn·c·b) summed over the nn/c chunk steps.
        return 2.0 * g * (2.0 * nn * a * b + nn * c * (a + b))

    return _finish("linear_attention", "causal_attn", bucket,
                   {"g": g, "n": n, "dk": dk, "dv": dv},
                   {"chunk": chunk},
                   grid, {"n": np_, "dk": dkp, "dv": dvp},
                   vmem, flops(np_, chunk, dkp, dvp),
                   flops(n, min(chunk, n), dk, dv), hbm,
                   PEAK_FLOPS_BF16)


def bidir_attention_cell(bucket, g, n, dk, dv):
    """Fused bidirectional kernel: whole sequence per grid step in VMEM."""
    from repro.kernels import bidir_linear_attention as _bidir

    dkp, dvp = _ceil_to(dk, 128), _ceil_to(dv, 128)
    np_ = _ceil_to(n, 8)
    vmem = 2 * (2 * np_ * dkp * F32 + 2 * np_ * dvp * F32)   # q, k | v, out
    over_cap = np_ > _bidir.MAX_FUSED_N
    cell = _finish("bidir_linear_attention", "encoder_attn", bucket,
                   {"g": g, "n": n, "dk": dk, "dv": dv},
                   {"n_block": np_},
                   (g,), {"n": np_, "dk": dkp, "dv": dvp},
                   vmem, 4.0 * g * np_ * dkp * dvp, 4.0 * g * n * dk * dv,
                   g * (2 * np_ * dkp + 2 * np_ * dvp) * F32,
                   PEAK_FLOPS_BF16)
    if over_cap:   # the kernel refuses these shapes outright
        cell.classification = "vmem_overflow"
    return cell


# ---------------------------------------------------------------------------
# The serving geometry: ViTConfig × DEFAULT_BUCKETS
# ---------------------------------------------------------------------------

MATMUL_KERNELS = ("shift_matmul", "add_matmul", "add_matmul_packed")


def serving_sites(cfg, b) -> list:
    """Every kernel's serving call-site geometry at batch-bucket b, as plain
    dicts — the single source both `cells_for_bucket` (the contract table)
    and `kernels.autotune` (the search-space enumerator) iterate, so the
    tuner can never tune a geometry the table doesn't model.

    Site geometries come from the ShiftAddViT serving path: projections see
    (B·N_patches, d) token matrices; the binary attention matmuls group over
    B·heads with per-head (dh) feature dims; MoE experts see at most the
    full token load (the per-image capacity split only shrinks M).
    """
    n, d, f, h = cfg.n_patches, cfg.d_model, cfg.d_ff, cfg.n_heads
    dh = d // h
    toks = b * n
    return [
        dict(kernel="shift_matmul", site="qkvo_proj", g=1, m=toks, k=d, n=d,
             w_bytes=1, adapt_bn=False),
        dict(kernel="shift_matmul", site="moe_shift_up", g=1, m=toks, k=d,
             n=f, w_bytes=1, adapt_bn=False),
        dict(kernel="shift_matmul", site="moe_shift_down", g=1, m=toks, k=f,
             n=d, w_bytes=1, adapt_bn=False),
        dict(kernel="add_matmul", site="ktv", g=b * h, m=dh, k=n, n=dh,
             w_bytes=1, adapt_bn=True),
        dict(kernel="add_matmul", site="q_ktv", g=b * h, m=n, k=dh, n=dh,
             w_bytes=1, adapt_bn=True),
        dict(kernel="add_matmul_packed", site="ktv", g=b * h, m=dh, k=n,
             n=dh, w_bytes=1, adapt_bn=True, packed_k=True),
        dict(kernel="add_matmul_packed", site="q_ktv", g=b * h, m=n, k=dh,
             n=dh, w_bytes=1, adapt_bn=True, packed_k=True),
        dict(kernel="linear_attention", site="causal_attn", g=b * h, n=n,
             dk=dh, dv=dh),
        dict(kernel="bidir_linear_attention", site="encoder_attn", g=b * h,
             n=n, dk=dh, dv=dh),
    ]


def cell_for_site(site_spec: dict, bucket: int, blocks=None) -> Cell:
    """One `serving_sites` entry → its contract Cell, optionally under tuned
    block caps (the autotuner's cost oracle)."""
    s = dict(site_spec)
    kernel, site = s.pop("kernel"), s.pop("site")
    if kernel in MATMUL_KERNELS:
        return matmul_cell(kernel, site, bucket, s["g"], s["m"], s["k"],
                           s["n"], w_bytes=s["w_bytes"],
                           adapt_bn=s["adapt_bn"],
                           packed_k=s.get("packed_k", False), blocks=blocks)
    if kernel == "linear_attention":
        return linear_attention_cell(bucket, s["g"], s["n"], s["dk"],
                                     s["dv"], blocks=blocks)
    assert kernel == "bidir_linear_attention", kernel
    return bidir_attention_cell(bucket, s["g"], s["n"], s["dk"], s["dv"])


def cells_for_bucket(cfg, b) -> list:
    """Every kernel's serving call sites at batch-bucket b (untuned blocks)."""
    return [cell_for_site(spec, b) for spec in serving_sites(cfg, b)]


def pallas_kernel_names() -> set:
    """Module names under repro.kernels that define a pallas_call — the
    coverage ground truth the tests hold the table against."""
    import repro.kernels as pkg

    root = os.path.dirname(pkg.__file__)
    names = set()
    for fname in sorted(os.listdir(root)):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(root, fname)) as fh:
            if re.search(r"\bpl\.pallas_call\b", fh.read()):
                names.add(fname[:-3])
    return names


def run(base_cfg=None, buckets=None):
    """The full pass → (findings, table-rows).

    Only `vmem_overflow` cells are findings (KC001): pad_and_slice is the
    documented slow path, not a contract violation — the table records it so
    the autotune layer can hunt aligned geometries.
    """
    from repro.nn.vit import ViTConfig
    from repro.serve.vision import DEFAULT_BUCKETS

    cfg = base_cfg or ViTConfig()
    buckets = tuple(buckets or DEFAULT_BUCKETS)
    rows, findings = [], []
    for b in buckets:
        for cell in cells_for_bucket(cfg, b):
            rows.append(cell)
            if cell.classification == "vmem_overflow":
                findings.append(Finding(
                    rule="KC001", pass_name="kernels",
                    where=f"{cell.kernel}/{cell.site}/bucket={b}",
                    message=(f"working set {cell.vmem_bytes / 2**20:.1f} MiB "
                             f"exceeds the {VMEM_BUDGET_BYTES / 2**20:.0f} "
                             f"MiB VMEM budget (blocks {cell.blocks})")))
    covered = {c.kernel for c in rows}
    missing = pallas_kernel_names() - covered
    for name in sorted(missing):
        findings.append(Finding(
            rule="KC001", pass_name="kernels", where=f"kernels/{name}",
            message="Pallas kernel has no contract-table entry — add its "
                    "cell model to analysis.kernel_contracts"))
    return findings, rows


def format_table(rows) -> str:
    """Human-readable kernel × bucket grid (one line per cell)."""
    head = (f"{'kernel':<22} {'site':<15} {'bucket':>6} {'class':<14} "
            f"{'vmem':>9} {'waste':>6} {'bound':>8}")
    lines = [head, "-" * len(head)]
    for c in rows:
        lines.append(
            f"{c.kernel:<22} {c.site:<15} {c.bucket:>6} "
            f"{c.classification:<14} {c.vmem_bytes / 2**20:>7.2f}Mi "
            f"{c.pad_mac_waste:>5.0%} {c.bound:>8}")
    return "\n".join(lines)
