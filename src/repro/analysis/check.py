"""`python -m repro.analysis.check` — the serving-contract static gate.

Runs the three passes (jaxpr serving audit, Pallas kernel contract checker,
AST jit-hazard lint), prints every finding, writes the kernel × geometry
contract table artifact, and — with ``--fail-on-findings`` — exits 1 on any
finding that is not allowlisted. This is the CI `static-analysis` job; it is
also registered in benchmarks/run.py's rows contract via
benchmarks/check_analysis.py.

No compilation and no kernel execution happens here: the audit stops at
`jax.make_jaxpr`/`.lower()`, the contract table is arithmetic over the
wrappers' block-selection rules, and the lint is pure AST. The whole gate
runs in seconds on a CPU-only runner.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.analysis import jaxpr_audit, kernel_contracts, lint
from repro.analysis.findings import split_allowlisted

DEFAULT_TABLE = os.path.join("artifacts", "analysis", "ANALYSIS_contracts.json")

PASSES = ("jaxpr", "kernels", "lint")


def run_passes(passes=PASSES):
    """→ (findings, info dict with the contract rows + inventories)."""
    findings, info = [], {}
    if "jaxpr" in passes:
        f, audited = jaxpr_audit.run()
        findings += f
        info["audited_programs"] = [dataclasses.asdict(a) for a in audited]
    if "kernels" in passes:
        f, rows = kernel_contracts.run()
        findings += f
        info["contract_rows"] = rows
    if "lint" in passes:
        f, n_files = lint.run()
        findings += f
        info["linted_files"] = n_files
    return findings, info


def write_table(rows, path):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "vmem_budget_bytes": kernel_contracts.VMEM_BUDGET_BYTES,
        "classifications": ["tile_aligned", "pad_and_slice", "vmem_overflow"],
        "cells": [c.row() for c in rows],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Serving-contract static analyzer (jaxpr audit + kernel "
                    "contracts + jit-hazard lint)")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 if any non-allowlisted finding remains")
    ap.add_argument("--table", default=DEFAULT_TABLE,
                    help=f"contract-table artifact path (default {DEFAULT_TABLE})")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help="comma-separated subset of: jaxpr,kernels,lint")
    args = ap.parse_args(argv)

    passes = tuple(p for p in args.passes.split(",") if p)
    unknown = set(passes) - set(PASSES)
    if unknown:
        ap.error(f"unknown pass(es): {sorted(unknown)}")

    t0 = time.time()
    findings, info = run_passes(passes)
    active, waived = split_allowlisted(findings)

    if "contract_rows" in info:
        rows = info["contract_rows"]
        print(kernel_contracts.format_table(rows))
        path = write_table(rows, args.table)
        n_over = sum(c.classification == "vmem_overflow" for c in rows)
        n_pad = sum(c.classification == "pad_and_slice" for c in rows)
        print(f"\ncontract table: {len(rows)} cells "
              f"({n_pad} pad_and_slice, {n_over} vmem_overflow) → {path}")
    if "audited_programs" in info:
        print(f"jaxpr audit: {len(info['audited_programs'])} serving "
              "programs traced")
    if "linted_files" in info:
        print(f"lint: {info['linted_files']} modules walked")

    for f in waived:
        print(f"ALLOWED  {f.format()}")
    for f in active:
        print(f"FINDING  {f.format()}")
    status = "FAIL" if (active and args.fail_on_findings) else "OK"
    print(f"\n{status}: {len(active)} active finding(s), {len(waived)} "
          f"allowlisted, in {time.time() - t0:.1f}s")
    return 1 if (active and args.fail_on_findings) else 0


if __name__ == "__main__":
    sys.exit(main())
