"""Shared finding schema + allowlist for the static-analysis passes.

A `Finding` is one violated serving contract, pinned to a source location or
a serving entry point. The CLI (`repro.analysis.check`) prints them and, with
``--fail-on-findings``, fails CI on any finding that is not allowlisted.

Two suppression mechanisms, both explicit and reviewable:

- **inline** (AST lint only): a ``# lint: allow(RULE reason)`` comment on the
  flagged line or the line above it. The reason is part of the comment so the
  waiver is auditable at the use site (e.g. the engine's trace-time compile
  counter).
- **ALLOWLIST** (any pass): a ``(rule, where_substring, reason)`` row below.
  Used for findings that have no single source line (jaxpr-level facts).
  Keep it short; every row is a standing debt.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str        # e.g. "JX003" — stable rule id, documented in README
    where: str       # "path/file.py:line" or "vit/shiftadd/bucket=8"
    message: str     # one line: what contract is violated and by what
    pass_name: str   # "jaxpr" | "kernels" | "lint"

    def format(self) -> str:
        return f"[{self.pass_name}:{self.rule}] {self.where}: {self.message}"


# (rule, where-substring, reason). A finding is allowlisted when its rule
# matches exactly and `where_substring in finding.where`.
ALLOWLIST: tuple = (
)


def split_allowlisted(findings, allowlist=None):
    """Partition findings into (active, allowlisted) under the ALLOWLIST."""
    allowlist = ALLOWLIST if allowlist is None else allowlist
    active, waived = [], []
    for f in findings:
        if any(rule == f.rule and where in f.where
               for rule, where, _reason in allowlist):
            waived.append(f)
        else:
            active.append(f)
    return active, waived
