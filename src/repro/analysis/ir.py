"""Shared IR inspection utilities: HLO-text parsing + jaxpr walking + the
jax 0.4.x `cost_analysis` compat shim.

Two consumers (kept deliberately in one place — ISSUE 6 satellite):

- `repro.launch.hlo_analysis` — the trip-count-aware roofline profiler
  parses post-compile HLO text through `parse_hlo`/`symbol_table`.
- `repro.analysis.jaxpr_audit` — the serving-contract audit walks jaxprs
  (`iter_eqns`) and lowered StableHLO (donation aliasing), and normalizes
  `compiled.cost_analysis()` through `xla_cost_dict`.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Tuple

import jax.core as jax_core

# ---------------------------------------------------------------------------
# HLO text parsing (shapes, instructions, computations)
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
CALLS_RE = re.compile(r"(?:calls|condition|body|to_apply)=%?([\w\.\-]+)")


def parse_shapes(type_str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def nbytes(type_str) -> int:
    total = 0
    for dt, shape in parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str       # raw tail of the line (operands + attrs)
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    param_types: Dict[str, str]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and not line.strip().startswith("%constant"):
            params = {}
            for p in hdr.group(2).split(","):
                p = p.strip()
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
            cur = Computation(hdr.group(1), [], params)
            comps[cur.name] = cur
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4), line))
    return comps


def symbol_table(comps) -> Dict[str, str]:
    """Global name → type-string table across all computations."""
    table = {}
    for c in comps.values():
        for name, t in c.param_types.items():
            table[name] = t
        for ins in c.instrs:
            table[ins.name] = ins.result_type
    return table


def operand_names(rest: str) -> List[str]:
    """The leading %refs before the closing paren of an HLO op call."""
    depth = 0
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        if ch == ")":
            if depth == 0:
                break
            depth -= 1
        token += ch
    return re.findall(r"%([\w\.\-]+)", token)


# ---------------------------------------------------------------------------
# compiled.cost_analysis() compat (jax ≤0.4.x returns a list, newer a dict)
# ---------------------------------------------------------------------------

def xla_cost_dict(compiled_or_cost) -> dict:
    """Normalize `compiled.cost_analysis()` to one flat dict.

    Accepts either the compiled executable or the raw cost_analysis result.
    jax ≤0.4.x returns a list with one entry per computation (the entry
    program first); newer jax returns the dict directly; some versions
    return None for unsupported backends.
    """
    cost = compiled_or_cost
    if hasattr(cost, "cost_analysis"):
        cost = cost.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def subjaxprs(eqn) -> Iterator:
    """All jaxprs appearing in an eqn's params (scan/while/cond/pjit/...)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jax_core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax_core.Jaxpr):
                yield v


def iter_eqns(jaxpr, path="") -> Iterator[Tuple[object, str]]:
    """DFS over every eqn of a jaxpr and all nested sub-jaxprs.

    Yields (eqn, path) where path is the '/'-joined chain of enclosing
    higher-order primitives (e.g. "scan/pjit"). Accepts a Jaxpr or
    ClosedJaxpr.
    """
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, path
        sub_path = f"{path}/{eqn.primitive.name}" if path else eqn.primitive.name
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, sub_path)


def eqn_source(eqn) -> str:
    """Best-effort 'file.py:line' of the user frame that emitted an eqn."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return "?"
        fname = frame.file_name
        for marker in ("/src/", "/site-packages/"):
            if marker in fname:
                fname = fname.split(marker)[-1]
        return f"{fname}:{frame.start_line}"
    except Exception:  # pragma: no cover - source info is advisory
        return "?"


def aval_nbytes(aval) -> int:
    """Byte size of a ShapedArray-like aval (0 for abstract tokens)."""
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    if dtype is None or shape is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n * dtype.itemsize
