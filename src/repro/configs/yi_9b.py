"""yi-9b — dense llama-arch GQA [arXiv:2403.04652; hf].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    d_head=128,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=5_000_000.0,   # Yi long-context base
    norm="rmsnorm",
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, dtype="float32")
