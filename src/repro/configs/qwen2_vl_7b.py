"""qwen2-vl-7b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Per the assignment the vision frontend is a STUB: input_specs() feeds
precomputed patch embeddings (B, N, d_model) with (t, h, w) M-RoPE position
ids; only the transformer backbone is modeled. Sections 16/24/24 over the
64 frequency pairs of head_dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    d_head=128,
    mlp_kind="swiglu",
    rope="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    qkv_bias=True,
    input_mode="embeddings",
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, mrope_sections=(4, 6, 6), dtype="float32")
