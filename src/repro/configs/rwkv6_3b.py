"""rwkv6-3b — "Finch": attention-free, data-dependent decay
[arXiv:2404.05892; hf].

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536; head size 64 (40 heads).
DESIGN.md §Arch-applicability: the paper's attention reparameterization is
inapplicable (the WKV recurrence is already an additive linear-attention
form); shift / MoE-of-primitives apply to all projections and the channel mix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv_head_size
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    mlp_kind="mlp",       # channel-mix is used instead (block kind rwkv6)
    block_pattern=("rwkv6",),
    rope="none",
    norm="layernorm",
    rwkv_head_size=64,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
    vocab_size=512, rwkv_head_size=64, dtype="float32")
