"""qwen3-moe-30b-a3b — 128-expert top-8 MoE with QK-norm
[hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
head_dim 128 (decoupled from d_model: q proj 2048→4096).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    d_head=128,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared_experts=0),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=64, vocab_size=512, dtype="float32",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64))
