"""codeqwen1.5-7b — qwen1.5-arch, full-head KV (GQA kv=32 = MHA), qkv bias
[hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    d_head=128,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    qkv_bias=True,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab_size=512, dtype="float32")
