from repro.configs.base import (
    ModelConfig,
    MLAConfig,
    MoEConfig,
    TrainConfig,
)
from repro.configs.registry import get_config, list_archs, ARCHS
from repro.configs import shapes
