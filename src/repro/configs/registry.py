"""--arch registry: every assigned architecture + the paper's own ViT configs."""
from __future__ import annotations

from repro.configs import (
    codeqwen15_7b,
    command_r_35b,
    hubert_xlarge,
    minicpm3_4b,
    phi35_moe_42b,
    qwen2_vl_7b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    rwkv6_3b,
    yi_9b,
)
from repro.core import policy as policies

ARCHS = {
    "yi-9b": yi_9b,
    "command-r-35b": command_r_35b,
    "codeqwen1.5-7b": codeqwen15_7b,
    "minicpm3-4b": minicpm3_4b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "hubert-xlarge": hubert_xlarge,
    "rwkv6-3b": rwkv6_3b,
}

POLICIES = {
    "dense": policies.DENSE,
    "shiftadd": policies.SHIFTADD,
    "shiftadd_deploy": policies.SHIFTADD_DEPLOY,
    "stage1": policies.STAGE1,
    "all_shift": policies.ALL_SHIFT,
}


def list_archs():
    return sorted(ARCHS.keys())


def get_config(arch: str, policy: str | None = None, reduced: bool = False):
    """Look up an architecture config; optionally reduced (smoke-test scale)
    and/or re-policied (the paper's reparameterization switch)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = ARCHS[arch]
    cfg = mod.REDUCED if reduced else mod.CONFIG
    if policy is not None:
        cfg = cfg.with_policy(POLICIES[policy] if isinstance(policy, str) else policy)
    return cfg
