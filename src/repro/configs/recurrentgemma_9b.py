"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000.
Griffin layout: (recurrent, recurrent, local-attn) tiled; window 2048;
GeGLU MLPs; d_rnn = d_model; temporal conv width 4. 38 = 12 cycles + 2
remainder recurrent blocks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    d_head=256,
    mlp_kind="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    rope="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    d_rnn=4096,
    conv1d_width=4,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=1, d_head=32,
    d_ff=256, vocab_size=512, window=8, d_rnn=128, dtype="float32")
