"""Config dataclasses. One ModelConfig fully determines a model; every
assigned architecture is a ModelConfig instance in configs/<arch>.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.policy import ShiftAddPolicy


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2-style; MiniCPM3 uses this)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Token-choice top-k MoE (the *architecture's* MoE, e.g. Qwen3 / Phi-3.5;
    orthogonal to the paper's MoE-of-primitives which lives in the policy)."""

    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 768           # per-expert hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None   # default d_model // n_heads
    mlp_kind: str = "swiglu"       # swiglu | geglu | mlp
    # Block layout. A pattern tuple is tiled over the depth; e.g.
    # ("rglru", "rglru", "local_attn") is RecurrentGemma's 2:1 layout.
    block_pattern: Tuple[str, ...] = ("attn",)
    causal: bool = True
    window: Optional[int] = None   # sliding window for "local_attn" blocks
    rope: str = "rope"             # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # Qwen2-VL t/h/w split
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    use_bias: bool = False
    qkv_bias: bool = False         # Qwen-style bias on q/k/v only
    tie_embeddings: bool = False
    parallel_block: bool = False   # GPT-J / Command-R parallel attn+FFN
    # "tokens": input ids -> embedding table. "embeddings": the modality
    # frontend is a stub; input_specs() feeds precomputed frame/patch
    # embeddings of width d_model (assignment rule for [audio]/[vlm]).
    input_mode: str = "tokens"
    # RWKV6 head size (d_model must divide).
    rwkv_head_size: int = 64
    # Beyond-paper §Perf: chunked (GLA-style) WKV — N/8 sequential steps of
    # MXU-shaped chunk matmuls instead of N per-token updates (train/prefill).
    rwkv_chunked: bool = False
    # RG-LRU recurrent width (RecurrentGemma uses d_rnn = d_model).
    d_rnn: Optional[int] = None
    conv1d_width: int = 4
    # The paper's technique, as a first-class switch.
    policy: ShiftAddPolicy = ShiftAddPolicy()
    # Capacity slack of the MoE-of-primitives dispatcher (paper §4.2 TPU
    # adaptation). Large values ⇒ no token drops (used by equivalence tests).
    moe_primitives_capacity: float = 1.25
    # Deployment per-group token count of the MoE-of-primitives dispatcher —
    # the regime its analytic α/capacity latencies are evaluated in (a ViT
    # dispatches one image row of n_patches tokens per group). None (LMs:
    # prefill groups a whole prompt, decode a single token) keeps the
    # nominal-regime constant so the split never varies with group size.
    moe_capacity_ref_tokens: Optional[int] = None
    # Decode KV-cache storage: "model" (activation dtype) or "int8"
    # (per-token-per-head scales; halves cache HBM — in the spirit of the
    # paper's quantized operands, KIVI-style).
    kv_cache_dtype: str = "model"
    # Compilation / memory controls.
    scan_layers: bool = True
    remat: str = "full"            # none | full | dots_saveable
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def pattern_for_depth(self) -> Tuple[str, ...]:
        """The per-layer block kinds for the full depth."""
        p = self.block_pattern
        reps = -(-self.n_layers // len(p))
        return (p * reps)[: self.n_layers]

    def with_policy(self, policy: ShiftAddPolicy) -> "ModelConfig":
        return dataclasses.replace(self, policy=policy)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter-count estimate (embedding + blocks), used for MODEL_FLOPS.
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        dh = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.input_mode == "embeddings":
            emb = v * d  # output head only
        total = emb
        for kind in self.pattern_for_depth():
            if kind in ("attn", "local_attn"):
                if self.mla is not None:
                    m = self.mla
                    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
            elif kind == "rglru":
                dr = self.d_rnn or d
                total += 2 * d * dr + dr * d + 2 * dr * dr // 16  # proj + gates (block-diag/8)
            elif kind == "rwkv6":
                total += 6 * d * d  # r,k,v,g,w LoRA-ish + out (estimate; exact
                # counts come from jax.eval_shape over the real param tree)
            # MLP / MoE per block:
            if self.moe is not None:
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                total += self.moe.n_experts * mult * d * self.moe.d_expert
                total += self.moe.n_shared_experts * mult * d * self.moe.d_expert
                total += d * self.moe.n_experts
            else:
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                total += mult * d * f
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        per_layer_all = self.moe.n_experts * mult * d * self.moe.d_expert
        per_layer_active = (self.moe.top_k + self.moe.n_shared_experts) * mult * d * self.moe.d_expert
        return self.param_count() - self.n_layers * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-5     # paper App. E: finetune base lr
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.05
    grad_clip_norm: float = 1.0
    global_batch: int = 256
    seq_len: int = 4096
    microbatch: Optional[int] = None   # grad-accumulation chunk (per-step)
    balance_loss_weight: float = 0.01  # λ for L_IMP + L_LOAD (paper: 0.01)
    grad_compression: str = "none"     # none | int8_ef (cross-pod reduce)
    # §Perf lever: cast the param tree to the compute dtype once inside the
    # loss (before any FSDP all-gather) so collectives move bf16, not f32.
    cast_params: str = "none"          # none | compute_dtype
    # §Perf lever: constrain the microbatch gradient accumulator to the
    # parameter shardings (forces reduce-scatter of dW partials instead of
    # replicating them over the data axis).
    constrain_grad_acc: bool = False
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
