"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=6400 vocab=32064.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    d_head=128,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    norm="layernorm",
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=6400, n_shared_experts=0),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=128, vocab_size=512, dtype="float32",
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128))
