"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447; unverified].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit prediction).
Per the assignment the conv waveform frontend is a STUB: input_specs() feeds
precomputed frame embeddings (B, T, 1280). Encoder-only ⇒ decode shapes are
skipped (no decode step). HuBERT's conv positional embedding is part of the
stubbed frontend; the backbone runs position-free (rope="none").
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    d_head=80,
    mlp_kind="mlp",
    causal=False,
    rope="none",
    norm="layernorm",
    use_bias=True,
    input_mode="embeddings",
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab_size=64, dtype="float32")
