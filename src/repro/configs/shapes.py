"""The assigned input-shape grid and ShapeDtypeStruct input specs per cell.

Shapes (LM-family, seq_len × global_batch):
  train_4k     4,096 × 256   → lowers train_step
  prefill_32k  32,768 × 32   → lowers prefill_step (full forward, no grads)
  decode_32k   32,768 × 128  → lowers serve_step (1 new token, 32k cache)
  long_500k    524,288 × 1   → lowers serve_step (sub-quadratic state only)

Cell rules (DESIGN.md §5):
- encoder-only archs (hubert) skip decode shapes;
- `long_500k` requires sub-quadratic attention: native for ssm/hybrid; for
  pure-attention archs the cell runs under the ShiftAdd binary-linear policy
  (O(1) recurrent state) — the paper's technique is what makes it feasible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import SHIFTADD


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: ShapeSpec
    skip: bool = False
    reason: str = ""
    # Policy the cell is lowered under (None = the config's own policy).
    policy_override: Optional[object] = None


def plan_cell(cfg, shape_name: str) -> CellPlan:
    shape = SHAPES[shape_name]
    if cfg.is_encoder and shape.kind == "decode":
        return CellPlan(cfg.name, shape, skip=True,
                        reason="encoder-only arch has no decode step")
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.policy.attention in ("linear", "binary_linear"))
        if not sub_quadratic:
            # Paper's technique makes the cell feasible: O(1) linear-attn state.
            return CellPlan(cfg.name, shape, policy_override=SHIFTADD)
    return CellPlan(cfg.name, shape)


def all_cells(cfg):
    return {name: plan_cell(cfg, name) for name in SHAPES}


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (no allocation) for every step function input
# ---------------------------------------------------------------------------

def _positions_spec(cfg, batch, seq):
    if cfg.rope == "mrope":
        return jax.ShapeDtypeStruct((batch, 3, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg, shape_name: str):
    """Stand-in inputs for the step function of this cell.

    train/prefill: {"inputs", "labels", "positions"}.
    decode: {"inputs_t"} — the persistent cache/state is created inside the
    serve_step donor (see launch.dryrun) from cfg + shape.
    """
    shape = SHAPES[shape_name]
    b, n = shape.global_batch, shape.seq_len
    dt = cfg.activation_dtype
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            inputs = jax.ShapeDtypeStruct((b, n), jnp.int32)
        else:
            inputs = jax.ShapeDtypeStruct((b, n, cfg.d_model), dt)
        return {
            "inputs": inputs,
            "labels": jax.ShapeDtypeStruct((b, n), jnp.int32),
            "positions": _positions_spec(cfg, b, n),
        }
    # decode: one new token; cache covers seq_len history.
    if cfg.input_mode == "tokens":
        inputs_t = jax.ShapeDtypeStruct((b,), jnp.int32)
    else:
        inputs_t = jax.ShapeDtypeStruct((b, cfg.d_model), dt)
    return {"inputs_t": inputs_t}
