"""command-r-35b — dense GQA, parallel-block, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
Cohere layout: parallel attention+FFN residual, LayerNorm (no bias in
projections), tied embeddings, 256k vocabulary (the TP-embedding stress case).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    d_head=128,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, dtype="float32")
