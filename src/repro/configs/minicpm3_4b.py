"""minicpm3-4b — MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448. MLA ranks per the HF
config: q_lora 768, kv_lora 256, qk nope/rope head dims 64/32, v head dim 64.
Decode caches the *compressed* latent (B, L, 256+32) — the MLA memory win —
and uses the absorbed-matmul decode form (repro.nn.attention.MLAttention).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    mlp_kind="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    tie_embeddings=True,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, dtype="float32",
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16))
