"""AdamW (decoupled weight decay) + warmup-cosine schedule + global-norm clip.

Self-contained (no optax in the image). The optimizer is a (init, update)
pair over arbitrary pytrees; moments are stored fp32 regardless of param
dtype. int-dtype leaves (packed shift weights) are held frozen automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr, warmup_steps, total_steps, final_frac=0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return lr


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _trainable(x):
    return jnp.issubdtype(x.dtype, jnp.inexact)


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: object
    v: object


@dataclasses.dataclass
class Optimizer:
    init: Callable
    update: Callable


def adamw(learning_rate, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.05,
          clip_norm=1.0):
    """learning_rate: float or schedule fn(step) -> lr."""
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        zeros = lambda p: (jnp.zeros(p.shape, jnp.float32) if _trainable(p)
                           else jnp.zeros((), jnp.float32))
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params):
        count = state.count + 1
        if clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale) if _trainable(g) else g,
                grads)
        lr = lr_fn(count)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            if not _trainable(p):
                return p, m, v
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            new_p = p32 - lr * (step + weight_decay * p32)
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(count=count, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)
