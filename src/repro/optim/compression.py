"""int8 error-feedback gradient compression (cross-pod reduce, DESIGN.md §3).

Models the compressed data-parallel exchange: each gradient leaf is quantized
to int8 with one fp32 scale before crossing the pod axis; the quantization
residual is carried in an error-feedback buffer and added to the next step's
gradient (Seide et al. '14 / DGC-style), which keeps convergence unbiased in
the long run. Wire bytes drop 4× vs fp32 (2× vs bf16).

Applied as a gradient transformation in the train step; the true in-collective
form (quantize → int accumulate inside psum) lives in
distributed.collectives.compressed_psum for shard_map regions and is
exercised by tests/test_distributed.py on a multi-device mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _trainable(x):
    return jnp.issubdtype(x.dtype, jnp.inexact)


def int8_error_feedback():
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: (jnp.zeros(p.shape, jnp.float32) if _trainable(p)
                       else jnp.zeros((), jnp.float32)),
            params)

    def apply(grads, ef):
        def one(g, e):
            if not _trainable(g):
                return g, e
            x = g.astype(jnp.float32) + e
            scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(x / scale), -127, 127)
            deq = q * scale
            return deq.astype(g.dtype), x - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return init, apply
