from repro.optim.optimizer import adamw, cosine_schedule, global_norm
from repro.optim.compression import int8_error_feedback
