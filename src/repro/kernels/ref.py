"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (shape/dtype sweeps
in tests/test_kernels_*.py). Deliberately naive; no fusion, no chunking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import po2_weight_from_packed


def shift_matmul_ref(x, w_packed, out_dtype=None):
    """x: (M, K) float; w_packed: (K, N) int8 (sign|P+64). y = x @ (s * 2^P)."""
    w = po2_weight_from_packed(w_packed, jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), w)
    return y.astype(out_dtype or x.dtype)


def add_matmul_ref(x, b, out_dtype=None):
    """x: (G, M, K) float; b: (G, K, N) int8 in {-1, 0, +1}. y = x @ b.

    A MatMul against a ±1 operand — semantically pure accumulation (the
    paper's MatAdd). Zeros are allowed (they encode padding / skipped weights).
    """
    y = jnp.einsum("gmk,gkn->gmn", x.astype(jnp.float32), b.astype(jnp.float32))
    return y.astype(out_dtype or x.dtype)


def binary_linear_attention_ref(q, k, v, causal=True):
    """Naive quadratic oracle of the Hamming-kernel linear attention.

    q, k: (B, H, N, Dk) float; v: (B, H, N, Dv).
    sim(i,j) = (b_qi . b_kj + d) / (2d); out_i = sum_j sim v_j / sum_j sim.
    The (2d) cancels; this oracle keeps the raw (b.b + d) weights.
    """
    d = q.shape[-1]
    n = q.shape[-2]
    bq = jnp.where(q >= 0, 1.0, -1.0)
    bk = jnp.where(k >= 0, 1.0, -1.0)
    scores = jnp.einsum("bhnd,bhmd->bhnm", bq, bk) + d
    if causal:
        mask = jnp.tril(jnp.ones((n, n)))
        scores = scores * mask
    out = jnp.einsum("bhnm,bhme->bhne", scores, v.astype(jnp.float32))
    den = jnp.sum(scores, axis=-1, keepdims=True)
    return (out / (den + 1e-6)).astype(v.dtype)


def binary_linear_attention_state_ref(q, k, v):
    """Final recurrent carry after consuming the whole sequence.

    Matches core.add_attention.init_decode_state layout: the state a chunked
    prefill must hand to binary_linear_attention_step for token N+1.
    """
    n = k.shape[-2]
    bk = jnp.where(k >= 0, 1.0, -1.0).astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    return {
        "kv": jnp.einsum("bhnd,bhne->bhde", bk, v32),
        "ksum": jnp.sum(bk, axis=-2),
        "vsum": jnp.sum(v32, axis=-2),
        "count": jnp.asarray(float(n), jnp.float32),
    }
