"""Pallas TPU kernel: fused chunked causal binary linear attention.

The paper's stage-1 attention (linear order Q(KᵀV) + binary Q/K codes) as one
fused kernel. The O(n) rewrite leaves a (d_k × d_v) running state; the fusion
keeps that state **resident in VMEM across the whole sequence** — HBM sees
each q/k/v chunk exactly once and each output chunk exactly once. This is the
TPU-native version of what the paper's TVM kernels buy on GPU: the win is
data movement, not multiplier counts.

Per (batch*head) g and chunk i (grid (G, N/C), chunk axis sequential):

    bq, bk    = sign(q_i), sign(k_i)                 (binarize fused, ±1)
    num       = bq @ KV  + d * 1·vsum                (inter-chunk, state)
    den       = bq @ ksum + d * (i*C)
    S         = tril(bq @ bkᵀ + d)                   (intra-chunk causal)
    out_i     = (num + S @ v_i) / (den + rowsum(S))
    KV       += bkᵀ @ v_i;  ksum += Σbk;  vsum += Σv (state update)

Head dims are zero-masked up to the true d_k/d_v so the wrapper may pad to
lane alignment without changing the Hamming kernel's `+d` offsets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu
from repro.kernels.tpu_compat import CompilerParams as _CompilerParams


CHUNK = 256


def _make_kernel(dk_true: int, chunk: int, n_true: int, return_state: bool):
    def kernel(q_ref, k_ref, v_ref, o_ref, *rest):
        if return_state:
            kv_out, ksum_out, vsum_out, kv_ref, ksum_ref, vsum_ref = rest
        else:
            kv_ref, ksum_ref, vsum_ref = rest
        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():
            kv_ref[...] = jnp.zeros_like(kv_ref)
            ksum_ref[...] = jnp.zeros_like(ksum_ref)
            vsum_ref[...] = jnp.zeros_like(vsum_ref)

        q = q_ref[0].astype(jnp.float32)              # (C, dk_pad)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)              # (C, dv_pad)
        dk_pad = q.shape[-1]
        dv_pad = v.shape[-1]
        # Binarize; zero the padded feature lanes so they drop out of dots.
        lane = jax.lax.broadcasted_iota(jnp.int32, (chunk, dk_pad), 1)
        valid = (lane < dk_true).astype(jnp.float32)
        bq = jnp.where(q >= 0, 1.0, -1.0) * valid
        bk = jnp.where(k >= 0, 1.0, -1.0) * valid
        # Zero padded sequence positions (tail chunk): their k/v must not
        # enter the carry, and causal masking keeps them out of real outputs.
        row_k = jax.lax.broadcasted_iota(jnp.int32, (chunk, dk_pad), 0)
        bk = bk * (i * chunk + row_k < n_true).astype(jnp.float32)
        row_v = jax.lax.broadcasted_iota(jnp.int32, (chunk, dv_pad), 0)
        v = v * (i * chunk + row_v < n_true).astype(jnp.float32)

        d = jnp.float32(dk_true)
        cnt_prev = (i * chunk).astype(jnp.float32)
        # Inter-chunk terms from the running state.
        num = jnp.dot(bq, kv_ref[...], preferred_element_type=jnp.float32)
        num += d * vsum_ref[...]                      # (1, dv) broadcasts
        den = jnp.sum(bq * ksum_ref[...], axis=-1) + d * cnt_prev  # (C,)
        # Intra-chunk causal term.
        s = jnp.dot(bq, bk.T, preferred_element_type=jnp.float32) + d
        row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        s = jnp.where(col <= row, s, 0.0)
        num += jnp.dot(s, v, preferred_element_type=jnp.float32)
        den += jnp.sum(s, axis=-1)
        o_ref[0] = (num / (den[:, None] + 1e-6)).astype(o_ref.dtype)
        # State update (after emitting this chunk's outputs).
        kv_ref[...] += jnp.dot(bk.T, v, preferred_element_type=jnp.float32)
        ksum_ref[...] += jnp.sum(bk, axis=0, keepdims=True)
        vsum_ref[...] += jnp.sum(v, axis=0, keepdims=True)
        if return_state:
            # Same (gg, 0) block every chunk step; the last write survives —
            # the final carry leaves VMEM exactly once per (batch*head).
            kv_out[0] = kv_ref[...]
            ksum_out[...] = ksum_ref[...]
            vsum_out[...] = vsum_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("dk_true", "chunk", "n_true",
                                              "interpret", "return_state"))
def binary_linear_attention_pallas(q, k, v, *, dk_true=None, chunk=CHUNK,
                                   n_true=None, interpret=False,
                                   return_state=False):
    """q,k: (G, N, Dk); v: (G, N, Dv); causal, includes self. N % chunk == 0.

    dk_true: the unpadded head dim (defaults to Dk) — see module docstring.
    n_true: the unpadded sequence length (defaults to N); positions beyond it
      are masked out of the carry so the wrapper may pad N to a chunk multiple.
    return_state: additionally emit the final recurrent carry
      (kv (G, Dk, Dv), ksum (G, 1, Dk), vsum (G, 1, Dv)) — the parallel-prefill
      handoff into the O(1) decode state.
    """
    g, n, dk = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0, (n, chunk)
    dk_true = dk if dk_true is None else int(dk_true)
    n_true = n if n_true is None else int(n_true)
    grid = (g, n // chunk)
    out_specs = pl.BlockSpec((1, chunk, dv), lambda gg, i: (gg, i, 0))
    out_shape = jax.ShapeDtypeStruct((g, n, dv), v.dtype)
    if return_state:
        out_specs = [
            out_specs,
            pl.BlockSpec((1, dk, dv), lambda gg, i: (gg, 0, 0)),
            pl.BlockSpec((1, dk), lambda gg, i: (gg, 0)),
            pl.BlockSpec((1, dv), lambda gg, i: (gg, 0)),
        ]
        out_shape = [
            out_shape,
            jax.ShapeDtypeStruct((g, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((g, dk), jnp.float32),
            jax.ShapeDtypeStruct((g, dv), jnp.float32),
        ]
    return pl.pallas_call(
        _make_kernel(dk_true, chunk, n_true, return_state),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda gg, i: (gg, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda gg, i: (gg, i, 0)),
            pl.BlockSpec((1, chunk, dv), lambda gg, i: (gg, i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
