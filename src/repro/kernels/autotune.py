"""Kernel autotune layer: search tile/packing/residency caps per kernel ×
serving geometry, persist the winners, and let the serving stack replay them.

The search space is seeded and pruned by the PR-6 contract table
(`repro.analysis.kernel_contracts`): every candidate is scored by the same
cell model the static analyzer emits — VMEM feasibility (double-buffered
blocks against the 16 MiB budget), roofline time max(t_compute, t_memory)
on the padded volumes, and pad-MAC waste — so a config the tuner picks is by
construction one the contract table classifies as launchable. On a TPU
backend the model-ranked shortlist is then *measured* through the real
`kernels.ops` wrappers (a one-entry tune table forces each candidate down
the exact serving path) and wall time picks the winner; off-TPU the model
ranking alone decides and the table records that reason — interpret-mode
timings would be meaningless.

What is tunable per kernel:

- shift_matmul / add_matmul: `bm`/`bn`/`bk` block caps (sublane / lane / K
  panel). The headline win at the serving geometry is `bk`: the untuned
  wrappers run the fixed K=512 panel, which pads the d_model=128 projections
  4× in K (the contract table's 0.75 pad-waste row).
- add_matmul_packed: `bm`/`bn` plus `bk8`, the code-packing panel width
  (packed rows of 8 logical K each; caps stay multiples of 16 so the x
  block's lane dim stays 128-aligned).
- linear_attention: `chunk`, the VMEM-residency chunk of the causal kernel.
- bidir_linear_attention: nothing — the fused kernel holds the whole
  sequence resident, so the tuner only records its VMEM feasibility.

Winning configs persist in TUNE_kernels.json (``TuneTable.save``/``load``)
keyed by exact kernel × geometry; `DeployPlan`/`BucketedViTEngine` thread
the loaded table to every `kernels.ops` call at freeze time, and a lookup
miss falls back to the module-default blocks — a stale table can never
break shapes (caps are re-resolved through the aligned-cover helpers) or
change semantics (blocks only partition the same padded dataflow).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time

SCHEMA_VERSION = 1

# Caps per kernel. Every value is a CAP, not a literal block: ops re-resolves
# through sublane_block/lane_block/kdim_block/packed_kdim_block covers, so any
# combination is shape-legal at any geometry; infeasible (VMEM) combinations
# are pruned by the contract-table oracle before ranking.
SEARCH_SPACE = {
    "shift_matmul": {"bm": (64, 128, 256), "bn": (128, 256),
                     "bk": (128, 256, 512)},
    "add_matmul": {"bm": (32, 64, 128), "bn": (128, 256),
                   "bk": (128, 256, 512)},
    "add_matmul_packed": {"bm": (32, 64, 128), "bn": (128, 256),
                          "bk8": (16, 32, 64)},
    "linear_attention": {"chunk": (64, 128, 256)},
    "bidir_linear_attention": {},
}

# Geometry keys each kernel's ops-wrapper lookup passes (must match the
# `_tuned(...)` call sites in kernels.ops exactly).
GEOMETRY_KEYS = {
    "shift_matmul": ("g", "m", "k", "n"),
    "add_matmul": ("g", "m", "k", "n"),
    "add_matmul_packed": ("g", "m", "k", "n"),
    "linear_attention": ("g", "n", "dk", "dv"),
}


def geometry_key(kernel: str, **geom) -> str:
    """Canonical string key for one kernel × exact geometry."""
    return "|".join([kernel] + [f"{k}={geom[k]}" for k in sorted(geom)])


@dataclasses.dataclass(frozen=True)
class TuneTable:
    """Immutable, hashable tune table.

    Hashability is load-bearing: the table rides in the `nondiff_argnums` of
    the `kernels.ops` custom-VJP wrappers, so jit caches key on it — two
    engines with different tables coexist without retrace collisions.

    entries: ((geometry_key, ((param, cap), ...)), ...) — sorted tuples.
    meta: ((key, value), ...) — provenance (backend, measured, reason, ...).
    """

    entries: tuple = ()
    meta: tuple = ()

    def __post_init__(self):
        # Derived lookup index; not a dataclass field, so hash/eq stay on the
        # canonical tuples.
        object.__setattr__(
            self, "_index", {k: dict(v) for k, v in self.entries})

    def lookup(self, kernel: str, **geom):
        """Tuned caps dict for this exact geometry, or None (→ defaults)."""
        return self._index.get(geometry_key(kernel, **geom))

    def __len__(self):
        return len(self.entries)

    @property
    def meta_dict(self) -> dict:
        return dict(self.meta)

    @staticmethod
    def from_dicts(entries: dict, meta: dict = None) -> "TuneTable":
        def _freeze(v):
            return tuple(v) if isinstance(v, list) else v

        ent = tuple(sorted(
            (k, tuple(sorted((p, int(c)) for p, c in v.items())))
            for k, v in entries.items()))
        mt = tuple(sorted((k, _freeze(v)) for k, v in (meta or {}).items()))
        return TuneTable(entries=ent, meta=mt)

    def to_json_dict(self) -> dict:
        def _thaw(v):
            return list(v) if isinstance(v, tuple) else v

        return {"schema": SCHEMA_VERSION,
                "meta": {k: _thaw(v) for k, v in self.meta},
                "entries": {k: dict(v) for k, v in self.entries}}

    def save(self, path: str, report=None):
        doc = self.to_json_dict()
        if report is not None:
            doc["report"] = report
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")

    @staticmethod
    def load(path: str) -> "TuneTable":
        with open(path) as fh:
            doc = json.load(fh)
        assert doc.get("schema") == SCHEMA_VERSION, doc.get("schema")
        return TuneTable.from_dicts(doc.get("entries", {}),
                                    doc.get("meta", {}))


def candidates(kernel: str) -> list:
    """Every cap combination in the kernel's search space (dicts; possibly
    the empty dict for feasibility-only kernels)."""
    space = SEARCH_SPACE.get(kernel, {})
    keys = sorted(space)
    if not keys:
        return [{}]
    return [dict(zip(keys, vals))
            for vals in itertools.product(*(space[k] for k in keys))]


def _site_geometry(spec: dict) -> dict:
    geom = {k: spec[k] for k in GEOMETRY_KEYS[spec["kernel"]]}
    if spec["kernel"] == "add_matmul_packed":
        # The packed wrapper's lookup sees x.shape[2] == 8 * packed-rows, and
        # pack_bits requires the caller to pad K to a multiple of 8 first —
        # so at e.g. the 196-token serving site the wrapper looks up k=200,
        # never k=196. Key the table at the k the lookup will actually carry
        # (the contract cell keeps the true k for honest pad-waste).
        geom["k"] = -(-geom["k"] // 8) * 8
    return geom


def rank_candidates(spec: dict, bucket: int) -> list:
    """Model-rank the feasible tile configs for one serving site.

    Returns [(caps, cell)] best-first: VMEM-overflowing configs are pruned,
    the rest sort by roofline time max(t_compute, t_memory), tie-broken by
    pad-MAC waste then VMEM pressure. Candidates whose caps resolve to the
    same launched blocks are deduplicated (first = best kept)."""
    from repro.analysis import kernel_contracts as kc

    scored = []
    for caps in candidates(spec["kernel"]):
        cell = kc.cell_for_site(spec, bucket, blocks=caps or None)
        if cell.classification == "vmem_overflow":
            continue
        cost = (max(cell.t_compute_s, cell.t_memory_s), cell.pad_mac_waste,
                cell.vmem_frac)
        scored.append((cost, caps, cell))
    scored.sort(key=lambda t: t[0])
    seen, uniq = set(), []
    for _, caps, cell in scored:
        resolved = tuple(sorted(cell.blocks.items()))
        if resolved in seen:
            continue
        seen.add(resolved)
        uniq.append((caps, cell))
    return uniq


def _measure_site(spec: dict, caps: dict, iters: int = 20) -> float:
    """Median wall time of one candidate through the REAL serving path: a
    one-entry tune table forces `caps` down the exact `kernels.ops` wrapper
    the engine calls. TPU only — interpret timings are meaningless."""
    import jax
    import jax.numpy as jnp

    from repro.core import quant
    from repro.kernels import ops
    from repro.kernels.add_matmul_packed import pack_bits

    kernel = spec["kernel"]
    table = TuneTable.from_dicts(
        {geometry_key(kernel, **_site_geometry(spec)): caps})
    key = jax.random.PRNGKey(0)
    if kernel == "shift_matmul":
        x = jax.random.normal(key, (spec["m"], spec["k"]))
        w = quant.pack_from_dense(
            0.05 * jax.random.normal(key, (spec["k"], spec["n"])))
        fn = lambda: ops.shift_matmul(x, w, "pallas", table)
    elif kernel == "add_matmul":
        x = jax.random.normal(key, (spec["g"], spec["m"], spec["k"]))
        b = (jax.random.randint(key, (spec["g"], spec["k"], spec["n"]), 0, 2,
                                jnp.int8) * 2 - 1).astype(jnp.int8)
        fn = lambda: ops.add_matmul(x, b, "pallas", table)
    elif kernel == "add_matmul_packed":
        # pack_bits requires 8-aligned K; drive the wrapper at the padded K
        # it will see in serving (matches the table key — see _site_geometry).
        kp = -(-spec["k"] // 8) * 8
        x = jax.random.normal(key, (spec["g"], spec["m"], kp))
        b = (jax.random.randint(key, (spec["g"], kp, spec["n"]), 0, 2,
                                jnp.int8) * 2 - 1).astype(jnp.int8)
        packed = pack_bits(b)
        fn = lambda: ops.add_matmul_bitpacked(x, packed, "pallas", table)
    else:
        assert kernel == "linear_attention", kernel
        g, n, dk, dv = spec["g"], spec["n"], spec["dk"], spec["dv"]
        q = jax.random.normal(key, (g, 1, n, dk))
        k = jax.random.normal(key, (g, 1, n, dk))
        v = jax.random.normal(key, (g, 1, n, dv))
        fn = lambda: ops.binary_linear_attention_fused(
            q, k, v, impl="pallas", tune=table)
    jax.block_until_ready(fn())                     # compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def autotune(base_cfg=None, buckets=None, measure=None, iters=20,
             shortlist=6):
    """Search every serving site × bucket; return (TuneTable, report rows).

    measure=None → auto: measure on TPU, model-rank elsewhere (the recorded
    `reason` says which). `shortlist` caps how many model-ranked candidates
    get wall-clock measured per site."""
    import jax

    from repro.analysis import kernel_contracts as kc
    from repro.nn.vit import ViTConfig
    from repro.serve.vision import DEFAULT_BUCKETS

    cfg = base_cfg or ViTConfig()
    buckets = tuple(buckets or DEFAULT_BUCKETS)
    backend = jax.default_backend()
    if measure is None:
        measure = backend == "tpu"
    entries, report = {}, []
    for b in buckets:
        for spec in kc.serving_sites(cfg, b):
            kernel = spec["kernel"]
            if not SEARCH_SPACE.get(kernel):
                cell = kc.cell_for_site(spec, b)
                report.append({
                    "kernel": kernel, "site": spec["site"], "bucket": b,
                    "geometry": cell.geometry, "winner": None,
                    "classification": cell.classification,
                    "note": "feasibility-only (no block tunables)"})
                continue
            geom = _site_geometry(spec)
            key = geometry_key(kernel, **geom)
            default_cell = kc.cell_for_site(spec, b)
            ranked = rank_candidates(spec, b)
            if not ranked:
                report.append({
                    "kernel": kernel, "site": spec["site"], "bucket": b,
                    "geometry": geom, "winner": None,
                    "classification": "vmem_overflow",
                    "note": "no feasible candidate in the search space"})
                continue
            measured_s = None
            if measure:
                timed = sorted(
                    (_measure_site(spec, caps, iters=iters), caps, cell)
                    for caps, cell in ranked[:shortlist])
                measured_s, caps, cell = timed[0]
            else:
                caps, cell = ranked[0]
            if key not in entries:       # same geometry can recur at bucket b
                entries[key] = caps
            report.append({
                "kernel": kernel, "site": spec["site"], "bucket": b,
                "geometry": geom, "winner": caps,
                "winner_blocks": cell.blocks,
                "default_blocks": default_cell.blocks,
                "classification": cell.classification,
                "t_model_s": max(cell.t_compute_s, cell.t_memory_s),
                "t_model_default_s": max(default_cell.t_compute_s,
                                         default_cell.t_memory_s),
                "pad_mac_waste": cell.pad_mac_waste,
                "pad_mac_waste_default": default_cell.pad_mac_waste,
                "measured_s": measured_s,
                "n_candidates": len(ranked)})
    reason = ("wall-clock measured through kernels.ops on TPU" if measure
              else f"model-ranked only (backend={backend}; interpret-mode "
                   "timings are not meaningful)")
    meta = {"backend": backend, "measured": bool(measure), "reason": reason,
            "buckets": list(buckets), "image_size": cfg.image_size,
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff}
    return TuneTable.from_dicts(entries, meta), report


def load_table(path: str):
    """TuneTable from a TUNE_kernels.json path, or None if absent/invalid —
    serving falls back to default blocks rather than failing to boot."""
    try:
        return TuneTable.load(path)
    except (OSError, ValueError, AssertionError):
        return None
