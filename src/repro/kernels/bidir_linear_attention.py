"""Pallas TPU kernel: fused bidirectional (encoder) binary linear attention.

The ViT serving form of the paper's Hamming-kernel attention. The causal
kernel (linear_attention.py) must scan chunks to respect the mask; the
encoder form has no mask, so the whole computation collapses into ONE fused
pass per (batch*head):

    bq, bk = sign(q), sign(k)                  (binarize fused in VMEM, ±1)
    KV     = bkᵀ @ v          ksum = Σ bk       vsum = Σ v
    out    = (bq @ KV + d·vsum) / (bq·ksum + d·n)

`core/add_attention._bidirectional` runs this as four separate full-precision
einsums through the STE machinery — each materializing its operands in HBM.
Here the codes never leave VMEM: HBM sees q/k/v once and out once, which is
the whole win (the contractions are ±1 adds; the paper's speedup is data
movement, not multiplier counts — same argument as the causal kernel).

Also hosts the XLA inference twin (`bidir_binary_attention_xla`): no STE
(inference has no gradient, so the straight-through machinery is dead
weight), and every ±1 contraction is done via the sign trick — with
m = 1[x ≥ 0] ∈ {0,1} and b = 2m − 1,

    b @ Y = 2·(m @ Y) − colsum(Y)

i.e. a masked add (popcount-style: accumulate only the rows the mask keeps)
plus a shared column sum, never materializing the ±1 codes.

Head dims are zero-masked up to the true d_k/d_v and sequence rows up to the
true n, so the ops.py wrapper may pad to lane/sublane alignment without
changing the Hamming kernel's `+d` offsets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_compat import CompilerParams as _CompilerParams

# Upper bound on padded sequence length: q, k, v, codes and out all live in
# VMEM simultaneously (~6 · N · 128 lanes · 4 B ≈ 12 MB at N=4096). Longer
# encoder sequences should go through the chunked causal kernel's dataflow.
MAX_FUSED_N = 4096


def _make_kernel(dk_true: int, n_true: int):
    def kernel(q_ref, k_ref, v_ref, o_ref):
        q = q_ref[0].astype(jnp.float32)              # (Np, dk_pad)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)              # (Np, dv_pad)
        n_pad, dk_pad = q.shape
        dv_pad = v.shape[-1]
        # Binarize; zero the padded feature lanes so they drop out of dots.
        lane = jax.lax.broadcasted_iota(jnp.int32, (n_pad, dk_pad), 1)
        lane_valid = (lane < dk_true).astype(jnp.float32)
        bq = jnp.where(q >= 0, 1.0, -1.0) * lane_valid
        bk = jnp.where(k >= 0, 1.0, -1.0) * lane_valid
        # Zero padded sequence rows: their k/v must not enter the global sums
        # (padded *query* rows produce garbage rows sliced off outside).
        row_k = jax.lax.broadcasted_iota(jnp.int32, (n_pad, dk_pad), 0)
        bk = bk * (row_k < n_true).astype(jnp.float32)
        row_v = jax.lax.broadcasted_iota(jnp.int32, (n_pad, dv_pad), 0)
        v = v * (row_v < n_true).astype(jnp.float32)

        d = jnp.float32(dk_true)
        # Phase 1: global accumulators (codes stay resident in VMEM).
        kv = jnp.dot(bk.T, v, preferred_element_type=jnp.float32)   # (dk, dv)
        ksum = jnp.sum(bk, axis=0, keepdims=True)                   # (1, dk)
        vsum = jnp.sum(v, axis=0, keepdims=True)                    # (1, dv)
        # Phase 2: emit every output row against the finished accumulators.
        num = jnp.dot(bq, kv, preferred_element_type=jnp.float32)
        num += d * vsum                                             # broadcasts
        den = jnp.sum(bq * ksum, axis=-1) + d * jnp.float32(n_true)  # (Np,)
        o_ref[0] = (num / (den[:, None] + 1e-6)).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("dk_true", "n_true", "interpret"))
def bidir_binary_attention_pallas(q, k, v, *, dk_true=None, n_true=None,
                                  interpret=False):
    """q, k: (G, N, Dk); v: (G, N, Dv) → (G, N, Dv). Non-causal.

    dk_true / n_true: the unpadded head dim / sequence length (default Dk/N);
    padded lanes and rows are masked out of the Hamming kernel inside VMEM so
    the wrapper may pad to tile alignment freely.
    """
    g, n, dk = q.shape
    dv = v.shape[-1]
    dk_true = dk if dk_true is None else int(dk_true)
    n_true = n if n_true is None else int(n_true)
    assert n <= MAX_FUSED_N, (
        f"fused bidirectional kernel holds the whole sequence in VMEM; "
        f"N={n} > {MAX_FUSED_N} — use the chunked causal kernel dataflow")
    return pl.pallas_call(
        _make_kernel(dk_true, n_true),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, n, dk), lambda gg: (gg, 0, 0)),
            pl.BlockSpec((1, n, dk), lambda gg: (gg, 0, 0)),
            pl.BlockSpec((1, n, dv), lambda gg: (gg, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, dv), lambda gg: (gg, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, dv), v.dtype),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, k, v)


def bidir_binary_attention_xla(q, k, v):
    """XLA inference twin of the fused kernel. q, k: (B, H, N, Dk); v: (B, H,
    N, Dv) → (B, H, N, Dv).

    No STE (nothing differentiates through serving), and the ±1 contractions
    use the sign trick (module docstring): the {0,1} masks are the only
    "codes" ever materialized, each contraction is a masked add, and the
    correction terms (colsum(KV), Σksum) are O(d·d) / O(d) — free next to the
    O(n·d²) contractions they replace.
    """
    out_dtype = v.dtype
    d = q.shape[-1]
    n = q.shape[-2]
    v32 = v.astype(jnp.float32)
    mq = (q >= 0).astype(jnp.float32)
    mk = (k >= 0).astype(jnp.float32)
    vsum = jnp.sum(v32, axis=-2)                                  # (B,H,Dv)
    # KV = bkᵀ v = 2·(mkᵀ v) − 1·vsum ; ksum = Σbk = 2·Σmk − n
    kv = 2.0 * jnp.einsum("bhnd,bhne->bhde", mk, v32) - vsum[:, :, None, :]
    ksum = 2.0 * jnp.sum(mk, axis=-2) - jnp.float32(n)            # (B,H,Dk)
    # bq @ KV = 2·(mq @ KV) − colsum(KV) ; bq·ksum = 2·(mq·ksum) − Σksum
    num = (2.0 * jnp.einsum("bhnd,bhde->bhne", mq, kv)
           - jnp.sum(kv, axis=-2)[:, :, None, :]
           + d * vsum[:, :, None, :])
    den = (2.0 * jnp.einsum("bhnd,bhd->bhn", mq, ksum)
           - jnp.sum(ksum, axis=-1)[..., None]
           + jnp.float32(d * n))
    return (num / (den[..., None] + 1e-6)).astype(out_dtype)
