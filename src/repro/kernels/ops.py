"""Public jit'd wrappers around the Pallas kernels.

Implementation selection (per-call `impl=` or process-wide default):

- "pallas"    — real TPU kernel (the deployment path).
- "interpret" — Pallas interpret mode: kernel body executed on CPU; used by
                the correctness tests against the ref.py oracles.
- "xla"       — semantics-identical pure-XLA twin with the *same storage
                format* (packed int8 weights, int8 binary operands). This is
                what the CPU dry-run lowers, so the roofline sees the real
                HBM layout (1 B/weight) without TPU codegen.

Default is "pallas" on TPU and "xla" elsewhere. Wrappers pick shape-adapted
block sizes and define custom VJPs (gradients flow to activations only —
packed operands are frozen deployment artifacts). Padding to tile multiples
lives in the kernels themselves (pad-and-slice), so arbitrary shapes — e.g.
the 197-token DeiT sequence — are first-class on every path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import po2_weight_from_packed
from repro.kernels import add_matmul as _addmm
from repro.kernels import linear_attention as _linattn
from repro.kernels import ref as _ref
from repro.kernels import shift_matmul as _shiftmm

_IMPL_OVERRIDE = None


def default_impl() -> str:
    """Implementation used by `impl=None` call sites: the explicit override
    (if `set_default_impl` was called) else the live backend — "pallas" on
    TPU, "xla" elsewhere. Deliberately NOT memoized: the old first-call cache
    meant an early import could pin the wrong backend for the whole process.
    Serving entry points (engine → blocks → ops) thread `impl` explicitly and
    never consult this; it exists for ad-hoc/test call sites only."""
    if _IMPL_OVERRIDE is not None:
        return _IMPL_OVERRIDE
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def set_default_impl(impl):
    """Set (or with None, clear) the process-wide `impl=None` fallback.

    This is a blunt instrument kept for ad-hoc experiments; the benchmark and
    serve CLIs pass `impl` explicitly down the engine stack instead, so two
    engines with different impls can coexist in one process."""
    assert impl is None or impl in ("pallas", "interpret", "xla")
    global _IMPL_OVERRIDE
    _IMPL_OVERRIDE = impl


from repro.kernels.tpu_compat import pad_to_multiple as _pad_to


def sublane_block(m: int, cap: int) -> int:
    """Shape-adapted M-block: the kernel's block size, shrunk to the
    sublane-aligned (multiple-of-8) cover of a small M. Single source of
    truth for the wrappers below AND repro.analysis.kernel_contracts (the
    contract table must model exactly the block geometry the wrappers pick)."""
    return min(cap, -(-m // 8) * 8)


def lane_block(n: int, cap: int) -> int:
    """Shape-adapted N-block: lane-aligned (multiple-of-128) cover of N."""
    return min(cap, -(-n // 128) * 128)


def kdim_block(k: int, cap: int) -> int:
    """Shape-adapted K-block. The K panel is the x-operand block's lane
    dimension, so caps must stay multiples of 128 — same law as lane_block,
    split out so tuned caps document which axis they constrain."""
    return min(cap, -(-k // 128) * 128)


def packed_kdim_block(k8: int, cap: int) -> int:
    """Shape-adapted packed-K block (add_matmul_bitpacked): k8 counts PACKED
    rows (8 logical K per row). The x block's lane dim is 8*bk8, so caps must
    be multiples of 16 (→ 128 logical K)."""
    return min(cap, -(-k8 // 16) * 16)


def _tuned(tune, kernel, **geom):
    """Tuned block caps for one kernel × geometry, or None for the module
    defaults. `tune` is anything with `.lookup(kernel, **geom) -> dict|None`
    (kernels.autotune.TuneTable); ops only duck-types it so the dependency
    stays one-way. Tuned caps are resolved through the aligned-cover helpers
    above, so a table entry can never produce an illegal block shape."""
    if tune is None:
        return None
    return tune.lookup(kernel, **geom)


# ---------------------------------------------------------------------------
# shift_matmul: y = x @ (s * 2^P), packed int8 weights
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def shift_matmul(x, w_packed, impl=None, tune=None):
    """x: (..., K) float; w_packed: (K, N) int8 → (..., N).

    tune: optional TuneTable (hashable — it rides in nondiff_argnums) whose
    entry for this geometry overrides the module-default block caps."""
    return _shift_matmul_fwd_impl(x, w_packed, impl, tune)


def _shift_matmul_fwd_impl(x, w_packed, impl, tune=None):
    impl = impl or default_impl()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if impl == "xla":
        y = _ref.shift_matmul_ref(x2, w_packed)
    else:
        m = x2.shape[0]
        n = w_packed.shape[-1]
        cfg = _tuned(tune, "shift_matmul", g=1, m=m, k=k, n=n)
        if cfg is None:
            # Untuned defaults: adapt only the M block (the contract table
            # replays exactly this law — see kernel_contracts.matmul_cell).
            bm, bn, bk = sublane_block(m, _shiftmm.BM), _shiftmm.BN, _shiftmm.BK
        else:
            bm = sublane_block(m, cfg.get("bm", _shiftmm.BM))
            bn = lane_block(n, cfg.get("bn", _shiftmm.BN))
            bk = kdim_block(k, cfg.get("bk", _shiftmm.BK))
        y = _shiftmm.shift_matmul_pallas(
            x2, w_packed, bm=bm, bn=bn, bk=bk, interpret=(impl == "interpret"))
    return y.reshape(*lead, -1)


def _shift_matmul_vjp_fwd(x, w_packed, impl, tune):
    return _shift_matmul_fwd_impl(x, w_packed, impl, tune), (w_packed,)


def _shift_matmul_vjp_bwd(impl, tune, res, g):
    (w_packed,) = res
    w = po2_weight_from_packed(w_packed, jnp.float32)
    gx = jnp.einsum("...n,kn->...k", g.astype(jnp.float32), w).astype(g.dtype)
    return (gx, None)


shift_matmul.defvjp(_shift_matmul_vjp_fwd, _shift_matmul_vjp_bwd)


# ---------------------------------------------------------------------------
# add_matmul: y = x @ b, b int8 in {-1, 0, +1}
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def add_matmul(x, b, impl=None, tune=None):
    """x: (G, M, K) float; b: (G, K, N) int8 → (G, M, N)."""
    return _add_matmul_fwd_impl(x, b, impl, tune)


def _add_matmul_fwd_impl(x, b, impl, tune=None):
    impl = impl or default_impl()
    if impl == "xla":
        return _ref.add_matmul_ref(x, b)
    g, m, k = x.shape
    n = b.shape[-1]
    cfg = _tuned(tune, "add_matmul", g=g, m=m, k=k, n=n) or {}
    bm = sublane_block(m, cfg.get("bm", _addmm.BM))
    bn = lane_block(n, cfg.get("bn", _addmm.BN))
    bk = kdim_block(k, cfg.get("bk", _addmm.BK)) if cfg else _addmm.BK
    return _addmm.add_matmul_pallas(x, b, bm=bm, bn=bn, bk=bk,
                                    interpret=(impl == "interpret"))


def _add_matmul_vjp_fwd(x, b, impl, tune):
    return _add_matmul_fwd_impl(x, b, impl, tune), (b,)


def _add_matmul_vjp_bwd(impl, tune, res, g):
    (b,) = res
    gx = jnp.einsum("gmn,gkn->gmk", g.astype(jnp.float32),
                    b.astype(jnp.float32)).astype(g.dtype)
    return (gx, None)


add_matmul.defvjp(_add_matmul_vjp_fwd, _add_matmul_vjp_bwd)


# ---------------------------------------------------------------------------
# bit-packed add_matmul (beyond-paper: 1 bit/element binary operand)
# ---------------------------------------------------------------------------

def add_matmul_bitpacked(x, packed, impl=None, tune=None):
    """x: (G, M, K) float; packed: (G, K//8, N) uint8 ±1 codes → (G, M, N).

    The tunable `bk8` is the code-packing panel width: how many PACKED rows
    (8 logical K each) one grid step consumes."""
    from repro.kernels import add_matmul_packed as _pk

    impl = impl or default_impl()
    if impl == "xla":
        b = _pk.unpack_bits(packed, jnp.float32)
        return _ref.add_matmul_ref(x, b)
    g, m, k = x.shape
    k8 = packed.shape[1]
    n = packed.shape[-1]
    cfg = _tuned(tune, "add_matmul_packed", g=g, m=m, k=k, n=n) or {}
    bm = sublane_block(m, cfg.get("bm", _pk.BM))
    bn = lane_block(n, cfg.get("bn", _pk.BN))
    bk8 = packed_kdim_block(k8, cfg.get("bk8", _pk.BK8)) if cfg else _pk.BK8
    return _pk.add_matmul_packed_pallas(x, packed, bm=bm, bn=bn, bk8=bk8,
                                        interpret=(impl == "interpret"))


# ---------------------------------------------------------------------------
# fused bidirectional (encoder) binary linear attention
# ---------------------------------------------------------------------------

def binary_linear_attention_bidir(q, k, v, *, impl=None, tune=None):
    """q, k: (B, H, N, Dk); v: (B, H, N, Dv) → (B, H, N, Dv). Non-causal —
    the ViT/encoder serving form of the Hamming-kernel attention.

    Inference-only (no VJP; training uses repro.core.add_attention, whose STE
    machinery this path exists to skip). impl="xla" runs the sign-trick twin;
    pallas/interpret run the fused single-pass kernel with codes in VMEM.
    `tune` is accepted for call-site uniformity: the fused kernel holds the
    whole sequence resident, so it has no block tunables — the autotuner only
    records its VMEM feasibility.
    """
    del tune  # feasibility-gated, not block-tunable (see docstring)
    from repro.kernels import bidir_linear_attention as _bidir

    impl = impl or default_impl()
    if impl == "xla":
        return _bidir.bidir_binary_attention_xla(q, k, v)
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    qg = q.reshape(b * h, n, dk)
    kg = k.reshape(b * h, n, dk)
    vg = v.reshape(b * h, n, dv)
    # Lane-align head dims and sublane-align N; the kernel masks both.
    qp = _pad_to(_pad_to(qg, 128, 2), 8, 1)
    kp = _pad_to(_pad_to(kg, 128, 2), 8, 1)
    vp = _pad_to(_pad_to(vg, 128, 2), 8, 1)
    out = _bidir.bidir_binary_attention_pallas(
        qp, kp, vp, dk_true=dk, n_true=n, interpret=(impl == "interpret"))
    return out[:, :n, :dv].reshape(b, h, n, dv)


# ---------------------------------------------------------------------------
# fused causal binary linear attention
# ---------------------------------------------------------------------------

def binary_linear_attention_fused(q, k, v, *, chunk=None, impl=None,
                                  tune=None, return_state=False):
    """q,k: (B, H, N, Dk); v: (B, H, N, Dv). Causal, includes self.

    Inference/serving path (no VJP; training uses repro.core.add_attention).
    return_state=True additionally returns the final recurrent carry
    {"kv", "ksum", "vsum", "count"} (init_decode_state layout) so a chunked
    prefill can hand off directly to the O(1) decode step.
    """
    impl = impl or default_impl()
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    if impl == "xla":
        out = _ref.binary_linear_attention_ref(q, k, v, causal=True)
        if not return_state:
            return out
        return out, _ref.binary_linear_attention_state_ref(q, k, v)
    if chunk is None:
        # Explicit chunk > tuned VMEM-residency chunk > module default.
        cfg = _tuned(tune, "linear_attention", g=b * h, n=n, dk=dk, dv=dv) or {}
        chunk = min(cfg.get("chunk", _linattn.CHUNK), n)
    qg = q.reshape(b * h, n, dk)
    kg = k.reshape(b * h, n, dk)
    vg = v.reshape(b * h, n, dv)
    # Lane-align head dims; the kernel masks the padded lanes (dk_true).
    qp = _pad_to(qg, 128, 2)
    kp = _pad_to(kg, 128, 2)
    vp = _pad_to(vg, 128, 2)
    pad_n = (-n) % chunk
    if pad_n:
        qp = _pad_to(qp, chunk, 1)
        kp = _pad_to(kp, chunk, 1)
        vp = _pad_to(vp, chunk, 1)
    res = _linattn.binary_linear_attention_pallas(
        qp, kp, vp, dk_true=dk, chunk=chunk, n_true=n,
        interpret=(impl == "interpret"), return_state=return_state)
    if not return_state:
        return res[:, :n, :dv].reshape(b, h, n, dv)
    out, kv, ksum, vsum = res
    state = {
        "kv": kv[:, :dk, :dv].reshape(b, h, dk, dv),
        "ksum": ksum[:, :dk].reshape(b, h, dk),
        "vsum": vsum[:, :dv].reshape(b, h, dv),
        "count": jnp.asarray(float(n), jnp.float32),
    }
    return out[:, :n, :dv].reshape(b, h, n, dv), state
