"""Public jit'd wrappers around the Pallas kernels.

Implementation selection (per-call `impl=` or process-wide default):

- "pallas"    — real TPU kernel (the deployment path).
- "interpret" — Pallas interpret mode: kernel body executed on CPU; used by
                the correctness tests against the ref.py oracles.
- "xla"       — semantics-identical pure-XLA twin with the *same storage
                format* (packed int8 weights, int8 binary operands). This is
                what the CPU dry-run lowers, so the roofline sees the real
                HBM layout (1 B/weight) without TPU codegen.

Default is "pallas" on TPU and "xla" elsewhere. Wrappers pick shape-adapted
block sizes and define custom VJPs (gradients flow to activations only —
packed operands are frozen deployment artifacts). Padding to tile multiples
lives in the kernels themselves (pad-and-slice), so arbitrary shapes — e.g.
the 197-token DeiT sequence — are first-class on every path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import po2_weight_from_packed
from repro.kernels import add_matmul as _addmm
from repro.kernels import linear_attention as _linattn
from repro.kernels import ref as _ref
from repro.kernels import shift_matmul as _shiftmm

_DEFAULT_IMPL = None


def default_impl() -> str:
    global _DEFAULT_IMPL
    if _DEFAULT_IMPL is None:
        _DEFAULT_IMPL = "pallas" if jax.default_backend() == "tpu" else "xla"
    return _DEFAULT_IMPL


def set_default_impl(impl: str):
    assert impl in ("pallas", "interpret", "xla")
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


from repro.kernels.tpu_compat import pad_to_multiple as _pad_to


def sublane_block(m: int, cap: int) -> int:
    """Shape-adapted M-block: the kernel's block size, shrunk to the
    sublane-aligned (multiple-of-8) cover of a small M. Single source of
    truth for the wrappers below AND repro.analysis.kernel_contracts (the
    contract table must model exactly the block geometry the wrappers pick)."""
    return min(cap, -(-m // 8) * 8)


def lane_block(n: int, cap: int) -> int:
    """Shape-adapted N-block: lane-aligned (multiple-of-128) cover of N."""
    return min(cap, -(-n // 128) * 128)


# ---------------------------------------------------------------------------
# shift_matmul: y = x @ (s * 2^P), packed int8 weights
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def shift_matmul(x, w_packed, impl=None):
    """x: (..., K) float; w_packed: (K, N) int8 → (..., N)."""
    return _shift_matmul_fwd_impl(x, w_packed, impl)


def _shift_matmul_fwd_impl(x, w_packed, impl):
    impl = impl or default_impl()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if impl == "xla":
        y = _ref.shift_matmul_ref(x2, w_packed)
    else:
        m = x2.shape[0]
        bm = sublane_block(m, _shiftmm.BM)
        y = _shiftmm.shift_matmul_pallas(
            x2, w_packed, bm=bm, interpret=(impl == "interpret"))
    return y.reshape(*lead, -1)


def _shift_matmul_vjp_fwd(x, w_packed, impl):
    return _shift_matmul_fwd_impl(x, w_packed, impl), (w_packed,)


def _shift_matmul_vjp_bwd(impl, res, g):
    (w_packed,) = res
    w = po2_weight_from_packed(w_packed, jnp.float32)
    gx = jnp.einsum("...n,kn->...k", g.astype(jnp.float32), w).astype(g.dtype)
    return (gx, None)


shift_matmul.defvjp(_shift_matmul_vjp_fwd, _shift_matmul_vjp_bwd)


# ---------------------------------------------------------------------------
# add_matmul: y = x @ b, b int8 in {-1, 0, +1}
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def add_matmul(x, b, impl=None):
    """x: (G, M, K) float; b: (G, K, N) int8 → (G, M, N)."""
    return _add_matmul_fwd_impl(x, b, impl)


def _add_matmul_fwd_impl(x, b, impl):
    impl = impl or default_impl()
    if impl == "xla":
        return _ref.add_matmul_ref(x, b)
    _, m, _ = x.shape
    n = b.shape[-1]
    bm = sublane_block(m, _addmm.BM)
    bn = lane_block(n, _addmm.BN)
    return _addmm.add_matmul_pallas(x, b, bm=bm, bn=bn,
                                    interpret=(impl == "interpret"))


def _add_matmul_vjp_fwd(x, b, impl):
    return _add_matmul_fwd_impl(x, b, impl), (b,)


def _add_matmul_vjp_bwd(impl, res, g):
    (b,) = res
    gx = jnp.einsum("gmn,gkn->gmk", g.astype(jnp.float32),
                    b.astype(jnp.float32)).astype(g.dtype)
    return (gx, None)


add_matmul.defvjp(_add_matmul_vjp_fwd, _add_matmul_vjp_bwd)


# ---------------------------------------------------------------------------
# bit-packed add_matmul (beyond-paper: 1 bit/element binary operand)
# ---------------------------------------------------------------------------

def add_matmul_bitpacked(x, packed, impl=None):
    """x: (G, M, K) float; packed: (G, K//8, N) uint8 ±1 codes → (G, M, N)."""
    from repro.kernels import add_matmul_packed as _pk

    impl = impl or default_impl()
    if impl == "xla":
        b = _pk.unpack_bits(packed, jnp.float32)
        return _ref.add_matmul_ref(x, b)
    _, m, _ = x.shape
    n = packed.shape[-1]
    bm = sublane_block(m, _pk.BM)
    bn = lane_block(n, _pk.BN)
    return _pk.add_matmul_packed_pallas(x, packed, bm=bm, bn=bn,
                                        interpret=(impl == "interpret"))


# ---------------------------------------------------------------------------
# fused bidirectional (encoder) binary linear attention
# ---------------------------------------------------------------------------

def binary_linear_attention_bidir(q, k, v, *, impl=None):
    """q, k: (B, H, N, Dk); v: (B, H, N, Dv) → (B, H, N, Dv). Non-causal —
    the ViT/encoder serving form of the Hamming-kernel attention.

    Inference-only (no VJP; training uses repro.core.add_attention, whose STE
    machinery this path exists to skip). impl="xla" runs the sign-trick twin;
    pallas/interpret run the fused single-pass kernel with codes in VMEM.
    """
    from repro.kernels import bidir_linear_attention as _bidir

    impl = impl or default_impl()
    if impl == "xla":
        return _bidir.bidir_binary_attention_xla(q, k, v)
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    qg = q.reshape(b * h, n, dk)
    kg = k.reshape(b * h, n, dk)
    vg = v.reshape(b * h, n, dv)
    # Lane-align head dims and sublane-align N; the kernel masks both.
    qp = _pad_to(_pad_to(qg, 128, 2), 8, 1)
    kp = _pad_to(_pad_to(kg, 128, 2), 8, 1)
    vp = _pad_to(_pad_to(vg, 128, 2), 8, 1)
    out = _bidir.bidir_binary_attention_pallas(
        qp, kp, vp, dk_true=dk, n_true=n, interpret=(impl == "interpret"))
    return out[:, :n, :dv].reshape(b, h, n, dv)


# ---------------------------------------------------------------------------
# fused causal binary linear attention
# ---------------------------------------------------------------------------

def binary_linear_attention_fused(q, k, v, *, chunk=None, impl=None,
                                  return_state=False):
    """q,k: (B, H, N, Dk); v: (B, H, N, Dv). Causal, includes self.

    Inference/serving path (no VJP; training uses repro.core.add_attention).
    return_state=True additionally returns the final recurrent carry
    {"kv", "ksum", "vsum", "count"} (init_decode_state layout) so a chunked
    prefill can hand off directly to the O(1) decode step.
    """
    impl = impl or default_impl()
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    if impl == "xla":
        out = _ref.binary_linear_attention_ref(q, k, v, causal=True)
        if not return_state:
            return out
        return out, _ref.binary_linear_attention_state_ref(q, k, v)
    chunk = chunk or min(_linattn.CHUNK, n)
    qg = q.reshape(b * h, n, dk)
    kg = k.reshape(b * h, n, dk)
    vg = v.reshape(b * h, n, dv)
    # Lane-align head dims; the kernel masks the padded lanes (dk_true).
    qp = _pad_to(qg, 128, 2)
    kp = _pad_to(kg, 128, 2)
    vp = _pad_to(vg, 128, 2)
    pad_n = (-n) % chunk
    if pad_n:
        qp = _pad_to(qp, chunk, 1)
        kp = _pad_to(kp, chunk, 1)
        vp = _pad_to(vp, chunk, 1)
    res = _linattn.binary_linear_attention_pallas(
        qp, kp, vp, dk_true=dk, chunk=chunk, n_true=n,
        interpret=(impl == "interpret"), return_state=return_state)
    if not return_state:
        return res[:, :n, :dv].reshape(b, h, n, dv)
    out, kv, ksum, vsum = res
    state = {
        "kv": kv[:, :dk, :dv].reshape(b, h, dk, dv),
        "ksum": ksum[:, :dk].reshape(b, h, dk),
        "vsum": vsum[:, :dv].reshape(b, h, dv),
        "count": jnp.asarray(float(n), jnp.float32),
    }
    return out[:, :n, :dv].reshape(b, h, n, dv), state
