"""Pallas TPU kernel: bit-packed MatAdd — y = x @ b with b stored 1 BIT/element.

Beyond-paper extension of the Add layer (the paper stores binarized operands
as int8 = 8 bits/element): the ±1 codes are packed 8-per-byte along the
contraction dim, cutting the binary operand's HBM traffic a further 8×
(16× vs bf16). The kernel unpacks inside VMEM with integer shifts and feeds
the MXU — same dataflow as add_matmul, different storage format.

Packing: packed[g, k8, n] bit j  ⇔  b[g, k8*8 + j, n] > 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu
from repro.kernels.tpu_compat import CompilerParams as _CompilerParams
from repro.kernels.tpu_compat import pad_to_multiple as _pad_axis


BM, BN, BK8 = 128, 128, 64          # BK8 packed rows = 512 logical K rows


def pack_bits(b):
    """b: (G, K, N) in {-1,+1} (int8/float) → (G, K//8, N) uint8."""
    g, k, n = b.shape
    assert k % 8 == 0, k
    bits = (b > 0).astype(jnp.uint8).reshape(g, k // 8, 8, n)
    weights = (1 << jnp.arange(8, dtype=jnp.uint8))[None, None, :, None]
    return jnp.sum(bits * weights, axis=2).astype(jnp.uint8)


def unpack_bits(packed, dtype=jnp.float32):
    """(G, K8, N) uint8 → (G, K8*8, N) ±1 in `dtype` (reference path)."""
    g, k8, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, None, :, None]
    bits = (packed[:, :, None, :] >> shifts) & 1
    return (bits.astype(dtype) * 2.0 - 1.0).reshape(g, k8 * 8, n)


def _kernel(x_ref, p_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = p_ref[0]                                      # (BK8, BN) uint8
    k8, bn = p.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (k8, 8, bn), 1)
    bits = (p[:, None, :] >> shifts) & jnp.uint8(1)
    b = (bits.astype(jnp.bfloat16) * 2.0 - 1.0).reshape(k8 * 8, bn)
    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.bfloat16), b,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk8", "interpret"))
def add_matmul_packed_pallas(x, packed, *, bm=BM, bn=BN, bk8=BK8,
                             interpret=False):
    """x: (G, M, K); packed: (G, K//8, N) uint8 → (G, M, N).

    M/N/K8 need not be block multiples: inputs are padded to the tile grid
    and the output sliced. Padded packed bytes decode to -1 rows, but x is
    zero-padded over the same logical K rows, so they contribute nothing.
    """
    g, m, k = x.shape
    g2, k8, n = packed.shape
    assert g == g2 and k == k8 * 8, (x.shape, packed.shape)
    x = _pad_axis(_pad_axis(x, bm, 1), bk8 * 8, 2)
    packed = _pad_axis(_pad_axis(packed, bk8, 1), bn, 2)
    (_, mp, _), (k8p, np_) = x.shape, packed.shape[1:]
    grid = (g, mp // bm, np_ // bn, k8p // bk8)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk8 * 8), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk8, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, packed)
    return y[:, :m, :n]
