"""Pallas TPU kernel: MatShift — y = x @ (s * 2^P) with packed int8 weights.

TPU adaptation of the paper's TVM MatShift (DESIGN.md §2). The paper's own
profiling says the GPU speedup is "almost fully hidden behind data movements";
on TPU we realize exactly that saving: weights live in HBM as **1 packed byte
per weight** (bit 7 = sign, bits 0-6 = P+64), halving weight traffic vs bf16.
Inside VMEM the bf16 power-of-two value is assembled with three integer ops
and a bitcast — the MXU then runs the contraction at full rate:

    bf16(s * 2^P)  =  bitcast( sign << 15  |  (P + 127) << 7 )

Grid: (M/bm, N/bn, K/bk); fp32 accumulator scratch in VMEM, K innermost
("arbitrary" semantics) so the accumulator carries across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu
from repro.kernels.tpu_compat import CompilerParams as _CompilerParams
from repro.kernels.tpu_compat import pad_to_multiple as _pad_axis


from repro.core.quant import P_MIN

# MXU-aligned default tiling: (128, 128) output tile, 512-deep K panel.
BM, BN, BK = 128, 128, 512


def _assemble_bf16(sp):
    """packed int8 (sign|P+64) → exact bf16 s*2^P, integer ops only."""
    u = jax.lax.bitcast_convert_type(sp, jnp.uint8)
    sign = (u >> 7).astype(jnp.uint16) << 15
    p = (u & 0x7F).astype(jnp.int32) + P_MIN          # P in [-64, 63]
    exp_field = (p + 127).astype(jnp.uint16) << 7     # bf16 exponent, mantissa 0
    return jax.lax.bitcast_convert_type(sign | exp_field, jnp.bfloat16)


def _shift_matmul_kernel(x_ref, sp_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _assemble_bf16(sp_ref[...])
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.bfloat16), w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def shift_matmul_pallas(x, w_packed, *, bm=BM, bn=BN, bk=BK, interpret=False):
    """x: (M, K) float; w_packed: (K, N) int8. Returns (M, N) in x.dtype.

    Shapes need NOT be multiples of the block sizes: inputs are zero-padded
    to the tile grid and the output sliced back. A padded packed byte decodes
    to a tiny-but-nonzero power of two, which is harmless: x is zero-padded
    over the same K rows, so every padded term is w · 0 = 0 and the sum is
    exact. Padded M rows / N columns are discarded by the slice.
    """
    m, k = x.shape
    k2, n = w_packed.shape
    assert k == k2, (x.shape, w_packed.shape)
    x = _pad_axis(_pad_axis(x, bm, 0), bk, 1)
    w_packed = _pad_axis(_pad_axis(w_packed, bk, 0), bn, 1)
    (mp, kp), np_ = x.shape, w_packed.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    y = pl.pallas_call(
        _shift_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed)
    return y[:m, :n]
