"""Pallas TPU kernel: MatAdd — batched y = x @ b with b ∈ {-1, 0, +1} int8.

The paper's Add layer: a MatMul whose second operand is binarized, so every
MAC degenerates to an accumulation. On TPU the win is operand bytes: b is
stored int8 (1 B/element; a bit-packed 1-bit variant is the beyond-paper
extension, see ops.add_matmul_bitpacked) and expanded to bf16 only inside
VMEM, feeding the MXU.

Used for the attention contractions Q(KᵀV) where K (and Q) are binary codes;
hence the batched (G = B*H) layout.

Grid: (G, M/bm, N/bn, K/bk), K innermost with an fp32 VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu
from repro.kernels.tpu_compat import CompilerParams as _CompilerParams
from repro.kernels.tpu_compat import pad_to_multiple as _pad_axis


BM, BN, BK = 128, 128, 512


def _add_matmul_kernel(x_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ±1/0 int8 → bf16 is exact; the "multiply" by ±1 is sign-propagation.
    b = b_ref[0].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(
        x_ref[0].astype(jnp.bfloat16), b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def add_matmul_pallas(x, b, *, bm=BM, bn=BN, bk=BK, interpret=False):
    """x: (G, M, K) float; b: (G, K, N) int8. Returns (G, M, N) in x.dtype.

    Shapes need NOT be multiples of the block sizes: inputs are zero-padded
    up to the tile grid and the output sliced back — real ViT token counts
    (197 for DeiT, 197-patch buckets) are first-class. Zero padding is exact
    for this contraction (0 · ±1 = 0).
    """
    g, m, k = x.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (x.shape, b.shape)
    x = _pad_axis(_pad_axis(x, bm, 1), bk, 2)
    b = _pad_axis(_pad_axis(b, bk, 1), bn, 2)
    (_, mp, kp), np_ = x.shape, b.shape[2]
    grid = (g, mp // bm, np_ // bn, kp // bk)
    y = pl.pallas_call(
        _add_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, b)
    return y[:, :m, :n]
