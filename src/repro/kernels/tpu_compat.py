"""Version compat for `jax.experimental.pallas.tpu` symbol renames, plus the
tiny shared helpers every kernel module needs (leaf module: kernels/* and
kernels/ops.py both import from here without cycles)."""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp


def pad_to_multiple(x, multiple, axis):
    """Zero-pad `axis` up to the next multiple (no-op when already aligned).
    The pad-and-slice half of every kernel's arbitrary-shape support."""
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "no CompilerParams/TPUCompilerParams in jax.experimental.pallas.tpu; "
        "unsupported jax version")
