"""Version compat for `jax.experimental.pallas.tpu` symbol renames."""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "no CompilerParams/TPUCompilerParams in jax.experimental.pallas.tpu; "
        "unsupported jax version")
