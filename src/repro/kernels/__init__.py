"""Pallas TPU kernels for the paper's compute hot-spots.

- shift_matmul.py — MatShift: packed-int8 power-of-two weights, bf16 exponent
  assembly in VMEM (paper Fig. 4 / App. A).
- add_matmul.py — MatAdd: batched matmul against a binary ±1 operand
  (paper Fig. 5).
- linear_attention.py — fused chunked causal binary linear attention with the
  (d_k × d_v) running state resident in VMEM (paper §4.1 on the Q(KᵀV) path).
- bidir_linear_attention.py — fused bidirectional (encoder/ViT) form: one
  pass per (batch·head) accumulating KV/ksum then emitting outputs, codes in
  VMEM; plus the no-STE sign-trick XLA twin the serving path uses off-TPU.

ops.py holds the jit'd wrappers (padding + impl selection + custom VJPs);
ref.py the pure-jnp oracles every kernel is tested against.
"""
