"""Training loop with checkpoint/restart fault tolerance and straggler
monitoring. Failures (real exceptions or injected) roll the state back to the
newest complete checkpoint and replay the deterministic data stream from
there — the standard large-job recovery path, exercised end-to-end by
tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import jax

from repro.data.pipeline import shard_batch
from repro.distributed.fault_tolerance import (
    SimulatedFailure,
    StepTimer,
    StragglerMonitor,
)
from repro.train.step import init_train_state, make_train_step
from repro.utils.logging import get_logger

log = get_logger("repro.train")


def train_loop(model, tcfg, data, *, mesh=None, checkpointer=None,
               failure_injector=None, state=None, jit=True,
               metrics_hook=None, max_restarts=8):
    """Run tcfg.total_steps steps; returns (state, history).

    data: object with .batch_at(step) (deterministic restart-replay).
    """
    key = jax.random.PRNGKey(tcfg.seed)
    if state is None:
        state = init_train_state(model, tcfg, key)
    step_fn = make_train_step(model, tcfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    if checkpointer is not None:
        restored = checkpointer.restore_latest(state)
        if restored is not None:
            start, state = restored
            log.info("restored checkpoint at step %d", start)

    monitor = StragglerMonitor()
    history = []
    restarts = 0
    step = int(state["step"])
    while step < tcfg.total_steps:
        try:
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            batch = shard_batch(data.batch_at(step), mesh)
            with StepTimer() as t:
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            if monitor.record(step, t.seconds):
                log.warning("straggler step %d: %.3fs", step, t.seconds)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            metrics["seconds"] = t.seconds
            history.append(metrics)
            if metrics_hook is not None:
                metrics_hook(metrics)
            step += 1
            if checkpointer is not None and step % tcfg.checkpoint_every == 0:
                checkpointer.save(step, state)
        except SimulatedFailure as e:
            restarts += 1
            log.warning("failure at step %d (%s); restart %d", step, e, restarts)
            if restarts > max_restarts:
                raise
            if checkpointer is None:
                log.warning("no checkpointer; restarting from current state")
                continue
            checkpointer.wait()
            restored = checkpointer.restore_latest(state)
            if restored is None:
                state = init_train_state(model, tcfg, key)
                step = 0
            else:
                step, state = restored
                step = int(step)
            log.info("resumed from step %d", step)
    if checkpointer is not None:
        checkpointer.wait()
    return state, history
