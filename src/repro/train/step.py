"""train_step / eval_step builders.

Features wired here (DESIGN.md §3):
- microbatched gradient accumulation (lax.scan over microbatches) — bounds
  logits/activation memory for the 256k-vocab cells AND gives XLA per-
  microbatch grad all-reduces to overlap with the next microbatch's compute;
- optional int8 error-feedback gradient compression before the cross-pod
  exchange;
- λ·(L_IMP + L_LOAD) (paper Eq. 4) enters through model.loss.

State is a plain dict {"params", "opt", "ef", "step"} so the checkpointer
and shardings stay structural.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.compression import int8_error_feedback
from repro.optim.optimizer import adamw, cosine_schedule


def init_train_state(model, tcfg, key):
    params = model.init(key)
    opt = make_optimizer(tcfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.grad_compression == "int8_ef":
        ef_init, _ = int8_error_feedback()
        state["ef"] = ef_init(params)
    return state


def make_optimizer(tcfg):
    sched = cosine_schedule(tcfg.learning_rate, tcfg.warmup_steps,
                            tcfg.total_steps)
    return adamw(sched, weight_decay=tcfg.weight_decay,
                 clip_norm=tcfg.grad_clip_norm)


def _split_microbatches(batch, n):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def make_train_step(model, tcfg):
    opt = make_optimizer(tcfg)
    n_micro = tcfg.microbatch or 1
    use_ef = tcfg.grad_compression == "int8_ef"
    if use_ef:
        _, ef_apply = int8_error_feedback()
    cast_dtype = (model.cfg.activation_dtype
                  if tcfg.cast_params == "compute_dtype" else None)

    def loss_fn(params, mb):
        if cast_dtype is not None:
            # Cast before use so FSDP all-gathers move the compute dtype
            # (bf16), not fp32 — and hoist out of the microbatch loop.
            from repro.utils.tree import tree_cast

            params = tree_cast(params, cast_dtype)
        return model.loss(params, mb, train=True)

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = _split_microbatches(batch, n_micro)

            def constrain_grads(g):
                if not tcfg.constrain_grad_acc:
                    return g
                from repro.distributed.sharding import constrain

                spec = model.spec(params)
                flat_g, treedef = jax.tree_util.tree_flatten(g)
                flat_s = treedef.flatten_up_to(spec)
                out = [constrain(gg, tuple(ss)) if isinstance(ss, tuple) else gg
                       for gg, ss in zip(flat_g, flat_s)]
                return treedef.unflatten(out)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                g_acc = constrain_grads(g_acc)
                return (g_acc, l_acc + l), m

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), ms)

        new_state = dict(state)
        if use_ef:
            grads, new_ef = ef_apply(grads, state["ef"])
            new_state["ef"] = new_ef
        new_params, new_opt = opt.update(grads, state["opt"], params)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch, train=False)
        return metrics

    return eval_step
