"""Router fine-tune stage: train ONLY the MoE routers against telemetry α.

The back half of the serving-telemetry loop (ROADMAP item 3): after
`serve.telemetry.apply_expert_latencies` drops measured per-expert latencies
into the model's MoE feeds, this stage minimizes the latency-aware balance
loss (core.losses, paper §4.2 Eq. 4 — L_IMP + L_LOAD with α_i = Lat_i/ΣLat_j)
with every parameter FROZEN except the router kernels. Minimizing
SCV(α·load) drives load ∝ 1/Lat: the router learns to send more tokens to
the faster (shift/add) expert, which is the paper's claim this loop proves
end-to-end — evaluation then serves the tuned router through the PR-3
deployment freeze (`prepare_inference`), where per-image capacity dispatch
keeps the retrained router batch-invariant for free.

Freezing is a gradient mask, not an optimizer fork: gradients are zeroed
everywhere outside `blocks/*/feed/router` and weight decay is 0, so AdamW's
update is exactly zero on every frozen leaf (decay would otherwise shrink
frozen weights with zero gradient).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.moe_primitives import MoEPrimitives
from repro.optim.optimizer import adamw


def router_grad_mask(params):
    """0/1 float mask over a param tree: 1.0 on every leaf whose tree path
    contains a "router" key (the MoE router kernels), 0.0 elsewhere."""
    def leaf_mask(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        del leaf
        return jnp.float32(1.0 if "router" in keys else 0.0)
    return jax.tree_util.tree_map_with_path(leaf_mask, params)


def _moe_feeds(model):
    return [blk.feed for blk in model.blocks
            if isinstance(blk.feed, MoEPrimitives)]


def router_finetune(model, params, images, *, steps=40, lr=0.05,
                    noise_std=1.0):
    """Fine-tune the MoE routers of `model` on its current feed latencies.

    model/params: a ShiftAddViT (or compatible) whose MoE feeds carry the
    latencies to balance against — apply the telemetry table first
    (`serve.telemetry.apply_expert_latencies`). images: a fixed (B, H, W, C)
    batch; the objective is the model's aggregate balance loss, which for a
    converted (zero-init) router starts with ALL tokens on expert 0.

    noise_std: smoothing width of the load estimator for the fine-tune
    objective. The serving-policy feeds are built with router_noise=0, which
    would saturate the smooth-top1 CDF (margins / 1e-6) and kill the load
    gradient — so the feeds' router_noise is set to `noise_std` for (and
    beyond) this stage. Serving is unaffected: the inference path routes on
    clean argmax and never reads router_noise.

    Returns (tuned_params, history) with history the per-step loss values
    (history[0] is the pre-update loss of the first step).
    """
    feeds = _moe_feeds(model)
    if not feeds:
        raise ValueError("model has no MoEPrimitives feeds to fine-tune")
    for feed in feeds:
        feed.router_noise = float(noise_std)

    mask = router_grad_mask(params)
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)
    imgs = jnp.asarray(images)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            _, aux = model(p, imgs, train=False)
            return aux["balance_loss"]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, mask)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    history = []
    for _ in range(max(int(steps), 1)):
        params, state, loss = step(params, state)
        history.append(float(loss))
    return params, history


def finetune_report(model, params, images, impl=None, tune=None):
    """Frozen-engine evaluation of a (possibly fine-tuned) router: builds the
    PR-3 DeployPlan for the serving token count and measures the expert
    token share under real serving routing. Returns the report dict."""
    from repro.serve.telemetry import measure_token_share

    plan = model.prepare_inference(params, impl=impl,
                                   token_counts=(model.cfg.n_patches,),
                                   tune=tune)
    share = measure_token_share(model, plan.params, images,
                                impl=impl, tune=tune)
    caps = {}
    feeds = _moe_feeds(model)
    if feeds:
        c, _ = feeds[0].capacity_plan(model.cfg.n_patches)
        caps = dict(zip(feeds[0].expert_kinds, c))
    return {"expert_token_share": share, "capacities_per_image": caps}
