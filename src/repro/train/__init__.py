from repro.train.step import make_train_step, make_eval_step, init_train_state
from repro.train.loop import train_loop
