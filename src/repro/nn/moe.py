"""Token-choice top-k MoE (the *architectures'* MoE: Qwen3-MoE, Phi-3.5-MoE).

Sort-based static-capacity dispatch: assignments are ordered by expert via a
stable argsort and scattered into an (E, C, d) buffer — O(T·d) memory instead
of the (T, E, C) one-hot dispatch tensor (which at E=128, k=8 would be
hundreds of GB). Dropped tokens (beyond capacity) contribute zero, standard
Switch behavior. Expert weights are stacked (E, ...) and shard over the
`experts` logical axis (EP on the `model` mesh axis).

Composition with the paper (DESIGN.md §5): under `policy.mlp="shift"` /
`"moe_primitives"` the expert FFNs themselves become shift experts — the
beyond-paper composition of the two MoE levels. Expert weights then store the
*latent* shift parameters; the forward fake-quantizes with STE exactly like
ShiftLinear (we inline it here because the weights are stacked per expert).

Grouping note (ISSUE 5): this module routes over FLATTENED token groups
(`group_tokens`) in both train and eval — appropriate for LM training,
where group boundaries are a sharding concern and there is no per-request
bit-identity contract. The paper's `MoEPrimitives` is the one with a
serving engine behind it; ITS inference dispatch plans capacity per image
row (`group_rows`) and carries the batch-invariance guarantee. If a
TokenChoiceMoE model ever grows a batched serving path, give it the same
per-row treatment before wiring it into the traffic gates.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quant import po2_quantize_ste
from repro.nn import layers as L


class TokenChoiceMoE:
    def __init__(self, cfg):
        self.cfg = cfg
        m = cfg.moe
        self.m = m
        self.d = cfg.d_model
        self.f = m.d_expert
        self.e = m.n_experts
        self.k = m.top_k
        self.gated = cfg.mlp_kind in ("swiglu", "geglu")
        self.act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        self.dt = cfg.activation_dtype
        self.pdt = cfg.weight_dtype
        # The paper's stage-2 policy applied inside the experts:
        self.shift_experts = cfg.policy.mlp in ("shift", "moe_primitives")
        self.router = L.make_linear("dense", self.d, self.e, False,
                                    jnp.float32, jnp.float32)
        self.shared = None
        if m.n_shared_experts:
            self.shared = L.MLP(self.d, m.d_expert * m.n_shared_experts,
                                cfg.mlp_kind,
                                "shift" if self.shift_experts else "dense",
                                cfg.use_bias, self.dt, self.pdt)

    def init(self, key):
        ks = jax.random.split(key, 5)
        std = self.d ** -0.5
        shape_up = (self.e, self.d, self.f)
        shape_down = (self.e, self.f, self.d)
        p = {
            "router": self.router.init(ks[0]),
            "up": (std * jax.random.truncated_normal(ks[1], -2, 2, shape_up)
                   ).astype(self.pdt),
            "down": ((self.f ** -0.5) * jax.random.truncated_normal(
                ks[2], -2, 2, shape_down)).astype(self.pdt),
        }
        if self.gated:
            p["gate"] = (std * jax.random.truncated_normal(ks[3], -2, 2, shape_up)
                         ).astype(self.pdt)
        if self.shared is not None:
            p["shared"] = self.shared.init(ks[4])
        return p

    def spec(self, params):
        s = {"router": L.match_linear_spec(params["router"],
                                           L.linear_spec("embed", None)),
             "up": ("experts", "embed", None),
             "down": ("experts", None, "embed")}
        if self.gated:
            s["gate"] = ("experts", "embed", None)
        if self.shared is not None:
            s["shared"] = self.shared.spec(params["shared"])
        return s

    def _expert_w(self, w):
        w = w.astype(self.dt) if not self.shift_experts else (
            po2_quantize_ste(w).astype(self.dt))
        return w

    def __call__(self, params, x, train=True, rng=None):
        from repro.distributed.sharding import constrain
        from repro.nn.dispatch import combine, dispatch, group_tokens

        xg, ungroup = group_tokens(x, self.d)
        g, s, _ = xg.shape

        logits = self.router(params["router"], xg.astype(jnp.float32))  # (G,S,E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, self.k)             # (G,S,k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)    # qwen3 norm_topk

        cap = max(int(math.ceil(self.m.capacity_factor * s * self.k / self.e)), 1)
        buf, daux = dispatch(xg, expert_idx, gate_vals, [cap] * self.e)

        # (G, E, cap, d): groups shard over data, experts over model — the
        # constraint below is where GSPMD inserts the EP all-to-all.
        expert_in = buf.reshape(g, self.e, cap, self.d)
        expert_in = constrain(expert_in, ("batch", "experts", None, None))
        up = jnp.einsum("gecd,edf->gecf", expert_in, self._expert_w(params["up"]))
        if self.gated:
            up = self.act(jnp.einsum("gecd,edf->gecf", expert_in,
                                     self._expert_w(params["gate"]))) * up
        else:
            up = self.act(up)
        expert_out = jnp.einsum("gecf,efd->gecd", up, self._expert_w(params["down"]))
        expert_out = constrain(expert_out, ("batch", "experts", None, None))

        y = combine(expert_out.reshape(g, self.e * cap, self.d), daux, s, self.d)
        y = ungroup(y)
        if self.shared is not None:
            y = y + self.shared(params["shared"], x)

        # Switch-style load-balance aux + router z-loss.
        frac = daux["tokens_per_expert"].astype(jnp.float32) / (g * s * self.k)
        mean_prob = jnp.mean(probs, axis=(0, 1))                         # P_e
        aux = {
            "balance_loss": self.e * jnp.sum(frac * mean_prob)
            + 1e-4 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
            "tokens_per_expert": daux["tokens_per_expert"],
            "drop_fraction": daux["drop_fraction"],
        }
        return y, aux
