"""Attention-free sequence mixers: RG-LRU (Griffin/RecurrentGemma) and RWKV6.

Per DESIGN.md §Arch-applicability the paper's *attention* reparameterization is
inapplicable here (there is no Q·K MatMul to binarize — these recurrences are
already additive linear-attention forms); the shift/MoE reparameterizations
apply to every projection in these blocks and are wired through `make_linear`.

Training uses `associative_scan` (RG-LRU, elementwise) or `lax.scan` over time
(RWKV6 — the (d_k × d_v)-state recurrence); decode is a single-step update
with O(1) state. Chunked RWKV6 is a §Perf candidate, not the baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers as L


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin: conv → gated LRU, GeLU side branch)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def _rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative scan over time (axis 1)."""
    if h0 is not None:
        # Fold the initial state into the first step.
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


class RGLRUBlock:
    """The full Griffin recurrent block: x → (linear → conv1d → RG-LRU) ⊙
    gelu(linear) → out linear. Shapes: (B, N, d_model) → same."""

    def __init__(self, cfg):
        self.cfg = cfg
        d = cfg.d_model
        self.dr = cfg.d_rnn or d
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        lin = cfg.policy.proj_linear()
        self.in_x = L.make_linear(lin, d, self.dr, cfg.use_bias, dt, pdt)
        self.in_gate = L.make_linear(lin, d, self.dr, cfg.use_bias, dt, pdt)
        self.out = L.make_linear(lin, self.dr, d, cfg.use_bias, dt, pdt)
        self.conv = L.DWConv1D(self.dr, width=cfg.conv1d_width, causal=True,
                               dtype=dt, param_dtype=pdt)
        self.gate_r = L.make_linear("dense", self.dr, self.dr, True, dt, pdt)
        self.gate_i = L.make_linear("dense", self.dr, self.dr, True, dt, pdt)
        self.dt = dt

    def init(self, key):
        ks = jax.random.split(key, 7)
        # Λ init so that a = exp(-c softplus(Λ) r) starts near 0.9..0.999.
        lam = jax.random.uniform(ks[6], (self.dr,), jnp.float32, 0.3, 0.8)
        lam = jnp.log(jnp.exp(-jnp.log(lam) / _RGLRU_C) - 1.0)  # inverse softplus
        return {"in_x": self.in_x.init(ks[0]), "in_gate": self.in_gate.init(ks[1]),
                "out": self.out.init(ks[2]), "conv": self.conv.init(ks[3]),
                "gate_r": self.gate_r.init(ks[4]), "gate_i": self.gate_i.init(ks[5]),
                "lambda": lam}

    def spec(self, params):
        return {
            "in_x": L.match_linear_spec(params["in_x"], L.linear_spec("embed", "mlp")),
            "in_gate": L.match_linear_spec(params["in_gate"], L.linear_spec("embed", "mlp")),
            "out": L.match_linear_spec(params["out"], L.linear_spec("mlp", "embed")),
            "conv": {"kernel": (None, "mlp"), "bias": ("mlp",)},
            "gate_r": L.match_linear_spec(params["gate_r"], L.linear_spec("mlp", None, True)),
            "gate_i": L.match_linear_spec(params["gate_i"], L.linear_spec("mlp", None, True)),
            "lambda": ("mlp",),
        }

    def _gates(self, params, u):
        r = jax.nn.sigmoid(self.gate_r(params["gate_r"], u).astype(jnp.float32))
        i = jax.nn.sigmoid(self.gate_i(params["gate_i"], u).astype(jnp.float32))
        log_a = -_RGLRU_C * jax.nn.softplus(params["lambda"].astype(jnp.float32)) * r
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
            i * u.astype(jnp.float32))
        return a, b

    def __call__(self, params, x, positions=None, train=True):
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], x))
        u = self.conv(params["conv"], self.in_x(params["in_x"], x))
        a, b = self._gates(params, u)
        h = _rglru_scan(a, b).astype(self.dt)
        return self.out(params["out"], h * gate)

    def init_cache(self, batch, max_len=None, dtype=jnp.bfloat16):
        return {"h": jnp.zeros((batch, self.dr), jnp.float32),
                "conv": jnp.zeros((batch, self.cfg.conv1d_width - 1, self.dr), dtype),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, x, cache, positions=None, lengths=None):
        """Whole-prompt pass against a fresh cache → (y, decode-ready cache).
        One associative scan replaces N sequential decode steps. lengths (B,)
        marks per-row valid prompt length for end-padded batches: the handed-
        over state (h, conv window) is taken at each row's last real token."""
        n = x.shape[1]
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], x))
        ux = self.in_x(params["in_x"], x)
        u = self.conv(params["conv"], ux)
        a, b = self._gates(params, u)
        h = _rglru_scan(a, b, h0=cache["h"])
        y = self.out(params["out"], h.astype(self.dt) * gate)
        if lengths is None:
            h_last = h[:, -1]
            new_pos = cache["pos"] + n
        else:
            h_last = jnp.take_along_axis(
                h, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            new_pos = cache["pos"] + lengths.astype(jnp.int32)
        new_cache = {"h": h_last,
                     "conv": L.trailing_window(ux, self.cfg.conv1d_width - 1,
                                               cache["conv"].dtype,
                                               lengths=lengths),
                     "pos": new_pos}
        return y, new_cache

    def decode_step(self, params, x_t, cache):
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], x_t))
        ux = self.in_x(params["in_x"], x_t)
        u, conv_state = self.conv.step(params["conv"], ux, cache["conv"])
        a, b = self._gates(params, u)
        h = a * cache["h"] + b
        y = self.out(params["out"], h.astype(self.dt) * gate)
        return y, {"h": h, "conv": conv_state, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# RWKV6 "Finch": data-dependent-decay time mix + squared-relu channel mix
# ---------------------------------------------------------------------------

def _token_shift(x):
    """x_{t-1} with zero at t=0. x: (B, N, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _last_valid(x, lengths):
    """(last real token of x (B, N, D), per-row effective length (B,)).

    lengths=None means the whole sequence is valid (x[:, -1], N).
    """
    if lengths is None:
        return x[:, -1], x.shape[1]
    last = jnp.take_along_axis(
        x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return last, lengths.astype(jnp.int32)


class RWKV6TimeMix:
    def __init__(self, cfg):
        self.cfg = cfg
        d = cfg.d_model
        self.hs = cfg.rwkv_head_size
        assert d % self.hs == 0
        self.h = d // self.hs
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        lin = cfg.policy.proj_linear()
        mk = lambda i, o: L.make_linear(lin, i, o, False, dt, pdt)
        self.r_proj, self.k_proj, self.v_proj = mk(d, d), mk(d, d), mk(d, d)
        self.g_proj, self.o_proj = mk(d, d), mk(d, d)
        # Data-dependent decay LoRA (w = exp(-exp(w0 + tanh(x W1) W2))).
        self.w_lora_dim = 64
        self.w1 = L.make_linear("dense", d, self.w_lora_dim, False, dt, pdt)
        self.w2 = L.make_linear("dense", self.w_lora_dim, d, False, dt, pdt)
        self.dt = dt
        # Beyond-paper §Perf option: chunked WKV (GLA-style) — N/C sequential
        # steps of MXU-shaped chunk matmuls instead of N per-token state
        # updates. See rwkv6_chunked below for the math + numerics envelope.
        self.chunked = getattr(cfg, "rwkv_chunked", False)
        self.chunk = 8

    def init(self, key):
        d = self.cfg.d_model
        ks = jax.random.split(key, 8)
        decay_speed = jnp.array(
            [-6.0 + 5.0 * (i / max(d - 1, 1)) ** 0.9 for i in range(d)], jnp.float32)
        return {
            "r": self.r_proj.init(ks[0]), "k": self.k_proj.init(ks[1]),
            "v": self.v_proj.init(ks[2]), "g": self.g_proj.init(ks[3]),
            "o": self.o_proj.init(ks[4]), "w1": self.w1.init(ks[5]),
            "w2": self.w2.init(ks[6]),
            "w0": decay_speed,                                  # (D,)
            "u": jnp.zeros((self.h, self.hs), jnp.float32),     # bonus
            "mu": 0.5 * jnp.ones((5, d), jnp.float32),          # r,k,v,w,g lerps
            "ln_scale": jnp.ones((d,), jnp.float32),            # per-head groupnorm
            "ln_bias": jnp.zeros((d,), jnp.float32),
        }

    def spec(self, params):
        s = {n: L.match_linear_spec(params[n], L.linear_spec("embed", "heads"))
             for n in ("r", "k", "v", "g")}
        s["o"] = L.match_linear_spec(params["o"], L.linear_spec("heads", "embed"))
        s["w1"] = L.match_linear_spec(params["w1"], L.linear_spec("embed", None))
        s["w2"] = L.match_linear_spec(params["w2"], L.linear_spec(None, "heads"))
        s.update({"w0": ("heads",), "u": (None, None), "mu": (None, "heads"),
                  "ln_scale": ("heads",), "ln_bias": ("heads",)})
        return s

    def _streams(self, params, x, x_prev):
        """Token-shift lerp then project the 5 streams. x: (B, N, D)."""
        sx = x_prev - x
        mu = params["mu"].astype(x.dtype)
        xr, xk, xv, xw, xg = (x + sx * mu[i] for i in range(5))
        r = self.r_proj(params["r"], xr)
        k = self.k_proj(params["k"], xk)
        v = self.v_proj(params["v"], xv)
        g = jax.nn.silu(self.g_proj(params["g"], xg))
        lora = self.w2(params["w2"], jnp.tanh(self.w1(params["w1"], xw)))
        logw = params["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
        w = jnp.exp(-jnp.exp(jnp.clip(logw, -8.0, 1.0)))          # decay in (0,1)
        return r, k, v, g, w

    def _heads(self, t):
        b, n, d = t.shape
        return t.reshape(b, n, self.h, self.hs)

    def _group_norm(self, params, out):
        """Per-head LayerNorm of the wkv output. out: (B, N, H, hs)."""
        mean = jnp.mean(out, axis=-1, keepdims=True)
        var = jnp.var(out, axis=-1, keepdims=True)
        y = (out - mean) * jax.lax.rsqrt(var + 1e-5)
        b, n = out.shape[:2]
        y = y.reshape(b, n, -1)
        return y * params["ln_scale"] + params["ln_bias"]

    def _wkv(self, params, x, S0=None, lengths=None):
        """Full-sequence WKV pass. Returns (out (B,N,H,hs) pre-norm f32,
        gate g, final state S) so prefill can reuse the training dataflow.

        lengths (B,): per-row valid length for end-padded batches. Padded
        steps are made state-identity (decay w=1, kv-outer-product 0), so the
        final S is exactly the unpadded row's state; padded outputs are
        garbage and never read.
        """
        b, n, d = x.shape
        r, k, v, g, w = self._streams(params, x, _token_shift(x))
        r, k, v = map(self._heads, (r, k, v))              # (B,N,H,hs)
        w = self._heads(w.astype(jnp.float32))
        u = params["u"].astype(jnp.float32)
        if lengths is not None:
            valid = (jnp.arange(n)[None, :] < lengths[:, None])[:, :, None, None]
            k = jnp.where(valid, k, 0.0)
            v = jnp.where(valid, v, 0.0)
            w = jnp.where(valid, w, 1.0)   # log-decay 0 ⇒ chunked path exact too
        if S0 is None:
            S0 = jnp.zeros((b, self.h, self.hs, self.hs), jnp.float32)

        if self.chunked and n % self.chunk == 0 and n > self.chunk:
            out, S = rwkv6_chunked(r, k, v, w, u, chunk=self.chunk, S0=S0,
                                   return_state=True)
        else:
            def step(S, xs):
                r_t, k_t, v_t, w_t = xs                    # (B,H,hs)
                kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hs,hs)
                out_t = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
                S = w_t[..., None] * S + kv
                return S, out_t

            xs = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32)
                       for t in (r, k, v, w))              # (N,B,H,hs)
            S, out = jax.lax.scan(step, S0, xs)
            out = out.transpose(1, 0, 2, 3)                # (B,N,H,hs)
        return out, g, S

    def __call__(self, params, x, positions=None, train=True):
        out, g, _ = self._wkv(params, x)
        out = self._group_norm(params, out).astype(self.dt)
        return self.o_proj(params["o"], out * g)

    def init_cache(self, batch, max_len=None, dtype=jnp.bfloat16):
        return {"S": jnp.zeros((batch, self.h, self.hs, self.hs), jnp.float32),
                "x_prev": jnp.zeros((batch, self.cfg.d_model), dtype),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, x, cache, positions=None, lengths=None):
        """Whole-prompt pass against a fresh cache → (y, decode-ready cache).
        One (optionally chunked) WKV scan replaces N decode steps. lengths
        (B,): per-row valid prompt length for end-padded batches."""
        out, g, S = self._wkv(params, x, S0=cache["S"], lengths=lengths)
        y = self.o_proj(params["o"],
                        self._group_norm(params, out).astype(self.dt) * g)
        x_last, n_eff = _last_valid(x, lengths)
        new_cache = {"S": S, "x_prev": x_last.astype(cache["x_prev"].dtype),
                     "pos": cache["pos"] + n_eff}
        return y, new_cache

    def decode_step(self, params, x_t, cache):
        x = x_t[:, None]
        r, k, v, g, w = self._streams(params, x, cache["x_prev"][:, None])
        r, k, v = (self._heads(t)[:, 0].astype(jnp.float32) for t in (r, k, v))
        w = self._heads(w.astype(jnp.float32))[:, 0]
        u = params["u"].astype(jnp.float32)
        kv = k[..., :, None] * v[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", r, cache["S"] + u[..., None] * kv)
        S = w[..., None] * cache["S"] + kv
        out = self._group_norm(params, out[:, None])[:, 0].astype(self.dt)
        y = self.o_proj(params["o"], out * g[:, 0])
        return y, {"S": S, "x_prev": x_t, "pos": cache["pos"] + 1}


def rwkv6_chunked(r, k, v, w, u, chunk=8, S0=None, return_state=False):
    """Chunked WKV recurrence (GLA-style) — beyond-paper §Perf optimization.

    Replaces the per-token scan (N sequential state updates of rank-1 math)
    with N/C sequential steps of C×C / C×hs matmuls that feed the MXU.

    Math per chunk (per head; state S ∈ (hs_k, hs_v); decays w_t ∈ (0,1)):
        cum_t  = Σ_{s≤t} log w_s              (per k-channel, within chunk)
        q̃_t    = r_t ⊙ exp(cum_{t-1})         (cum_0 ≡ 0)
        k̃_j    = k_j ⊙ exp(-cum_j)
        intra  : s_tj = q̃_t · k̃_j  (j < t);  diag: (r_t · (u ⊙ k_t)) v_t
        out_t  = q̃_t @ S + Σ_{j<t} s_tj v_j + diag_t
        S'     = exp(cum_C) ⊙ (S + k̃ᵀ V)      (row-wise over k-channels)

    Numerics envelope: log w is clamped to [-8, 0) upstream, so with C=8 the
    midpoint-centered factored exponents are bounded by C/2·8 = 32 < 45 (the
    safety clip) — the factorization is exact over the whole representable
    input range (pairwise exponents cum_{t-1} − cum_j are ≤ 0 by
    construction; only the factoring could overflow, and it cannot here).

    Shapes: r,k,v,w (B, N, H, hs); u (H, hs). Returns (B, N, H, hs) f32.
    """
    b, n, h, hs = r.shape
    nc = n // chunk
    f32 = jnp.float32

    def to_chunks(t):
        return (t.astype(f32).reshape(b, nc, chunk, h, hs)
                .transpose(1, 0, 3, 2, 4))                 # (nc, B, H, C, hs)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-8))                  # (nc,B,H,C,hs)
    cum = jnp.cumsum(logw, axis=-2)                        # inclusive
    cum_prev = cum - logw                                  # exclusive (cum_{t-1})
    # Midpoint-center the factored exponents (m cancels pairwise) so each
    # side's range halves before the ±30 safety clip.
    m = cum[..., chunk // 2: chunk // 2 + 1, :]
    qf = rc * jnp.exp(jnp.clip(cum_prev - m, -45.0, 45.0))     # q̃
    kf = kc * jnp.exp(jnp.clip(m - cum, -45.0, 45.0))          # k̃
    # inter-chunk q must NOT carry the -m centering: build it separately.
    q_inter = rc * jnp.exp(jnp.clip(cum_prev, -60.0, 0.0))     # decays ≤ 1
    # strict-lower-triangular mask (diag handled by the u bonus)
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)
    mask_decay = jnp.exp(jnp.clip(cum[..., -1:, :], -60.0, 0.0))  # exp(cum_C)

    # k with decay measured from chunk end (for the state update; exponent
    # cum_C - cum_j ≤ 0, never overflows).
    k_end = kc * jnp.exp(jnp.clip(cum[..., -1:, :] - cum, -60.0, 0.0))

    def step(S, xs):
        q_i, k_i, v_i, kt_i, r_i, qS_i, kE_i, dC = xs
        # inter-chunk: history state
        out = jnp.einsum("bhck,bhkv->bhcv", qS_i, S)
        # intra-chunk strict-causal
        s = jnp.einsum("bhck,bhjk->bhcj", q_i, k_i) * tri
        out += jnp.einsum("bhcj,bhjv->bhcv", s, v_i)
        # diagonal bonus term
        out += jnp.einsum("bhck,bhck->bhc", r_i, kt_i)[..., None] * v_i
        # state update: S' = exp(cum_C) ⊙ S + Σ_j exp(cum_C - cum_j) k_j v_jᵀ
        S = (dC[..., 0, :, None] * S
             + jnp.einsum("bhjk,bhjv->bhkv", kE_i, v_i))
        return S, out

    u_kt = kc * u[None, None, :, None, :]                  # u ⊙ k per token
    if S0 is None:
        S0 = jnp.zeros((b, h, hs, hs), f32)
    S, out = jax.lax.scan(step, S0.astype(f32),
                          (qf, kf, vc, u_kt, rc, q_inter, k_end, mask_decay))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, n, h, hs)
    if return_state:
        return out, S
    return out


class RWKV6ChannelMix:
    def __init__(self, cfg):
        self.cfg = cfg
        d, f = cfg.d_model, cfg.d_ff
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        lin = cfg.policy.proj_linear()
        self.k_proj = L.make_linear(lin, d, f, False, dt, pdt)
        self.v_proj = L.make_linear(lin, f, d, False, dt, pdt)
        self.r_proj = L.make_linear(lin, d, d, False, dt, pdt)

    def init(self, key):
        ks = jax.random.split(key, 3)
        return {"k": self.k_proj.init(ks[0]), "v": self.v_proj.init(ks[1]),
                "r": self.r_proj.init(ks[2]),
                "mu": 0.5 * jnp.ones((2, self.cfg.d_model), jnp.float32)}

    def spec(self, params):
        return {"k": L.match_linear_spec(params["k"], L.linear_spec("embed", "mlp")),
                "v": L.match_linear_spec(params["v"], L.linear_spec("mlp", "embed")),
                "r": L.match_linear_spec(params["r"], L.linear_spec("embed", "heads")),
                "mu": (None, "embed")}

    def _forward(self, params, x, x_prev):
        sx = x_prev - x
        mu = params["mu"].astype(x.dtype)
        xk = x + sx * mu[0]
        xr = x + sx * mu[1]
        k = jnp.square(jax.nn.relu(self.k_proj(params["k"], xk)))
        return jax.nn.sigmoid(self.r_proj(params["r"], xr)) * self.v_proj(params["v"], k)

    def __call__(self, params, x, positions=None, train=True):
        return self._forward(params, x, _token_shift(x))

    def init_cache(self, batch, max_len=None, dtype=jnp.bfloat16):
        return {"x_prev": jnp.zeros((batch, self.cfg.d_model), dtype),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, x, cache, positions=None, lengths=None):
        y = self._forward(params, x, _token_shift(x))
        x_last, n_eff = _last_valid(x, lengths)
        new_cache = {"x_prev": x_last.astype(cache["x_prev"].dtype),
                     "pos": cache["pos"] + n_eff}
        return y, new_cache

    def decode_step(self, params, x_t, cache):
        y = self._forward(params, x_t[:, None], cache["x_prev"][:, None])[:, 0]
        return y, {"x_prev": x_t, "pos": cache["pos"] + 1}
