from repro.core.dense import Dense
from repro.nn.layers import (
    RMSNorm,
    LayerNorm,
    Embedding,
    MLP,
    DWConv1D,
    make_linear,
    apply_rope,
    apply_mrope,
    rope_freqs,
)
from repro.nn.attention import Attention, MLAttention
from repro.nn.recurrent import RGLRUBlock, RWKV6TimeMix, RWKV6ChannelMix
from repro.nn.moe import TokenChoiceMoE
from repro.nn.blocks import TransformerBlock
from repro.nn.model import LanguageModel
from repro.nn.vit import ShiftAddViT
