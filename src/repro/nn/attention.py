"""Attention layers: GQA softmax (memory-efficient chunked), sliding-window,
MLA (latent KV), and the paper's linear / binary-linear reparameterizations —
all selected by (ModelConfig, ShiftAddPolicy).

Softmax attention uses an online-softmax scan over KV chunks (Flash-style
dataflow in XLA) so peak activation memory is O(N·chunk) instead of O(N²) —
required for the 32k prefill cells to fit the dry-run memory budget.

Decode paths:
- softmax: dense KV cache (B, Hkv, L, Dh), dynamic_update_slice writes.
- local_attn: ring-buffer KV cache of size `window`.
- linear/binary_linear: O(1) recurrent state (core.add_attention) — the
  paper's technique is what makes the 500k-context cells feasible.
- MLA: compressed latent cache (B, L, kv_lora + rope_dim) with the absorbed
  decode form (scores and context computed directly in latent space).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import add_attention as la
from repro.nn import layers as L


# ---------------------------------------------------------------------------
# Chunked online-softmax attention
# ---------------------------------------------------------------------------

def softmax_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      chunk=512, q_offset=0):
    """q: (B, Hq, Nq, D); k, v: (B, Hkv, Nkv, D). GQA-grouped, O(Nq·chunk) mem.

    q_offset: absolute position of q[0] relative to k[0] (prefill continuation
    / decode use). Causal masking compares absolute positions.
    """
    from repro.distributed.sharding import constrain

    b, hq, nq, d = q.shape
    hkv, nkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, nq, d)
    # Shard kv-heads over model when divisible; otherwise fall back to
    # sequence parallelism over the query length (indivisible-head archs
    # would otherwise replicate the O(N·chunk) score buffers 16×).
    qg = constrain(qg, ("batch", "kv_heads", None, "seq_model", None))
    chunk = min(chunk, nkv)
    assert nkv % chunk == 0, (nkv, chunk)
    nchunks = nkv // chunk
    kc = k.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nchunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    scale = d ** -0.5
    q_pos = q_offset + jnp.arange(nq)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        k_i, v_i, c0 = xs
        s = jnp.einsum("bkgnd,bkcd->bkgnc", qg.astype(jnp.float32),
                       k_i.astype(jnp.float32)) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kv_pos = c0 + jnp.arange(chunk)
        valid = jnp.ones((nq, chunk), bool)
        if causal:
            valid &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Renormalize previous accumulator; guard fully-masked rows.
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isneginf(m_prev), -jnp.inf, m_prev) - m_safe)
        alpha = jnp.where(jnp.isneginf(m_prev), 0.0, alpha)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgnc,bkcd->bkgnd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, nq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, nq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, nq, dv), jnp.float32)
    offsets = jnp.arange(nchunks) * chunk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, offsets))
    out = acc / jnp.maximum(l[..., None], 1e-9)
    return out.reshape(b, hq, nq, dv).astype(v.dtype)


def _repeat_kv(x, g):
    """(B, Hkv, N, D) → (B, Hkv*g, N, D) by group repeat."""
    if g == 1:
        return x
    b, h, n, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, h, g, n, d)).reshape(b, h * g, n, d)


# ---------------------------------------------------------------------------
# GQA attention layer (policy-aware)
# ---------------------------------------------------------------------------

class Attention:
    def __init__(self, cfg, layer_kind="attn"):
        self.cfg = cfg
        p = cfg.policy
        self.mode = p.attention          # dense | linear | binary_linear
        self.h = cfg.n_heads
        self.hkv = cfg.n_kv_heads
        self.dh = cfg.head_dim
        self.window = cfg.window if layer_kind == "local_attn" else None
        self.causal = cfg.causal
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        lin = p.proj_linear()
        d = cfg.d_model
        qb = cfg.use_bias or cfg.qkv_bias
        self.q_proj = L.make_linear(lin, d, self.h * self.dh, qb, dt, pdt)
        self.k_proj = L.make_linear(lin, d, self.hkv * self.dh, qb, dt, pdt)
        self.v_proj = L.make_linear(lin, d, self.hkv * self.dh, qb, dt, pdt)
        self.o_proj = L.make_linear(lin, self.h * self.dh, d, cfg.use_bias, dt, pdt)
        self.qk_norm = cfg.qk_norm
        if self.qk_norm:
            self.q_norm = L.RMSNorm(self.dh, cfg.norm_eps, dt, pdt)
            self.k_norm = L.RMSNorm(self.dh, cfg.norm_eps, dt, pdt)
        self.dwconv = None
        if self.mode in ("linear", "binary_linear") and p.dwconv_v:
            self.dwconv = L.DWConv1D(self.hkv * self.dh, width=3,
                                     causal=cfg.causal, dtype=dt, param_dtype=pdt)
        self.feature = "binary" if self.mode == "binary_linear" else "elu1"

    def init(self, key):
        ks = jax.random.split(key, 8)
        p = {"q": self.q_proj.init(ks[0]), "k": self.k_proj.init(ks[1]),
             "v": self.v_proj.init(ks[2]), "o": self.o_proj.init(ks[3])}
        if self.qk_norm:
            p["q_norm"] = self.q_norm.init(ks[4])
            p["k_norm"] = self.k_norm.init(ks[5])
        if self.dwconv is not None:
            p["dwconv"] = self.dwconv.init(ks[6])
        return p

    def spec(self, params):
        s = {"q": L.match_linear_spec(params["q"], L.linear_spec("embed", "heads")),
             "k": L.match_linear_spec(params["k"], L.linear_spec("embed", "heads")),
             "v": L.match_linear_spec(params["v"], L.linear_spec("embed", "heads")),
             "o": L.match_linear_spec(params["o"], L.linear_spec("heads", "embed"))}
        if self.qk_norm:
            s["q_norm"] = self.q_norm.spec()
            s["k_norm"] = self.k_norm.spec()
        if self.dwconv is not None:
            s["dwconv"] = {"kernel": (None, "heads"), "bias": ("heads",)}
        return s

    # Serving threads kernel impl/tune explicitly (blocks → here → ops).
    accepts_impl = True

    # -- shared projection helpers ------------------------------------------
    def _qkv(self, params, x, positions, impl=None, tune=None):
        """Returns (q, k, v, vraw); vraw is the pre-DWConv V projection —
        the raw stream the decode conv cache is warmed from."""
        b, n, _ = x.shape
        q = L.call_linear(self.q_proj, params["q"], x, impl,
                          tune).reshape(b, n, self.h, self.dh)
        k = L.call_linear(self.k_proj, params["k"], x, impl,
                          tune).reshape(b, n, self.hkv, self.dh)
        vraw = L.call_linear(self.v_proj, params["v"], x, impl, tune)
        vflat = vraw
        if self.dwconv is not None:
            vflat = vflat + self.dwconv(params["dwconv"], vflat)
        v = vflat.reshape(b, n, self.hkv, self.dh)
        q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        if self.qk_norm:
            q = self.q_norm(params["q_norm"], q)
            k = self.k_norm(params["k_norm"], k)
        q, k = self._rope(q, k, positions)
        return q, k, v, vraw

    def _rope(self, q, k, positions):
        cfg = self.cfg
        if cfg.rope == "none" or positions is None:
            return q, k
        if cfg.rope == "mrope":
            fn = lambda t: L.apply_mrope(t, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            fn = lambda t: L.apply_rope(t, positions, cfg.rope_theta)
        return fn(q), fn(k)

    # -- full-sequence forward (train / prefill) -----------------------------
    def __call__(self, params, x, positions=None, train=True):
        cfg = self.cfg
        q, k, v, _ = self._qkv(params, x, positions)
        b, _, n, _ = q.shape
        if self.mode == "dense":
            out = softmax_attention(q, k, v, causal=self.causal,
                                    window=self.window,
                                    softcap=cfg.attn_logit_softcap,
                                    chunk=min(512, n))
        else:
            g = self.h // self.hkv
            kf = _repeat_kv(k, g)
            vf = _repeat_kv(v, g)
            out = la.binary_linear_attention(
                q.astype(jnp.float32), kf.astype(jnp.float32),
                vf.astype(jnp.float32), causal=self.causal,
                chunk=min(128, n), train=train,
                feature=self.feature).astype(x.dtype)
        out = out.transpose(0, 2, 1, 3).reshape(b, n, self.h * self.dh)
        return self.o_proj(params["o"], out)

    # -- inference -----------------------------------------------------------
    def infer(self, params, x, positions=None, impl=None, tune=None):
        """Serving forward. For the encoder binary-linear mode (the ViT path)
        this routes through the fused bidirectional Hamming-attention op
        (kernels.ops.binary_linear_attention_bidir): one pass accumulating
        KV/ksum then emitting outputs, no STE machinery. impl/tune arrive
        threaded from the serving engine (never a process global); every
        other mode falls back to the train=False forward, whose kernels have
        no impl selection."""
        if self.mode != "binary_linear" or self.causal:
            return self(params, x, positions=positions, train=False)
        from repro.kernels import ops

        b, n, _ = x.shape
        q, k, v, _ = self._qkv(params, x, positions, impl=impl, tune=tune)
        g = self.h // self.hkv
        kf = _repeat_kv(k, g)
        vf = _repeat_kv(v, g)
        out = ops.binary_linear_attention_bidir(
            q.astype(jnp.float32), kf.astype(jnp.float32),
            vf.astype(jnp.float32), impl=impl, tune=tune).astype(x.dtype)
        out = out.transpose(0, 2, 1, 3).reshape(b, n, self.h * self.dh)
        return L.call_linear(self.o_proj, params["o"], out, impl, tune)

    # -- decode --------------------------------------------------------------
    # Every cache leaf — positions included — carries the batch axis, so a
    # packed decode batch can hold requests at different positions and
    # admitting/evicting one is a single-axis gather/scatter over the pytree
    # (serve.lm.BucketedLMEngine's continuous batching).
    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        if self.mode in ("linear", "binary_linear"):
            state = la.init_decode_state(batch, self.h, self.dh, self.dh, jnp.float32)
            if self.dwconv is not None:
                state["conv"] = jnp.zeros((batch, 2, self.hkv * self.dh), dtype)
            return state
        length = min(max_len, self.window) if self.window else max_len
        if self.cfg.kv_cache_dtype == "int8":
            # Quantized cache (per-token-per-head scales). Scales factor out
            # of both attention contractions, so decode never materializes a
            # dequantized cache copy (see decode_step).
            return {
                "k": jnp.zeros((batch, self.hkv, length, self.dh), jnp.int8),
                "v": jnp.zeros((batch, self.hkv, length, self.dh), jnp.int8),
                "k_scale": jnp.zeros((batch, self.hkv, length), jnp.float32),
                "v_scale": jnp.zeros((batch, self.hkv, length), jnp.float32),
                "slot_pos": jnp.full((batch, length), -1, jnp.int32),
                "pos": jnp.zeros((batch,), jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, self.hkv, length, self.dh), dtype),
            "v": jnp.zeros((batch, self.hkv, length, self.dh), dtype),
            "slot_pos": jnp.full((batch, length), -1, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    @staticmethod
    def _quantize_kv(t):
        """(B, Hkv, 1, Dh) → int8 values + (B, Hkv, 1) scales."""
        scale = jnp.max(jnp.abs(t), axis=-1) / 127.0 + 1e-8
        q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)

    # -- parallel prefill ----------------------------------------------------
    def prefill(self, params, x, cache, positions=None, lengths=None):
        """Whole-prompt pass against a *fresh* cache. x: (B, N, d_model).

        Returns (y (B, N, d_model), cache) where cache is decode-ready: the
        linear modes hand over the chunked pass's final recurrent carry (one
        O(N) pass instead of N decode steps); dense modes bulk-write K/V.

        lengths (B,) int32: per-row valid prompt length for bucket-padded
        prompts (tokens at positions >= lengths[b] are end-padding). The
        returned cache row is exactly the unpadded row's state; outputs at
        padded positions are garbage (never read — padding is strictly in
        every real position's causal future).
        """
        cfg = self.cfg
        b, n, _ = x.shape
        q, k, v, vraw = self._qkv(params, x, positions)
        if self.mode in ("linear", "binary_linear"):
            g = self.h // self.hkv
            kf = _repeat_kv(k, g)
            vf = _repeat_kv(v, g)
            out, state = la.binary_linear_attention(
                q.astype(jnp.float32), kf.astype(jnp.float32),
                vf.astype(jnp.float32), causal=self.causal, chunk=min(128, n),
                train=False, feature=self.feature, return_state=True,
                lengths=lengths)
            out = out.astype(x.dtype)
            # Accumulate into the caller's carry instead of replacing it: the
            # recurrent state is additive, so this is exact for the fresh
            # (zero) cache and correct for a warm-carry continuation — and it
            # consumes the donated cache buffers (serving audit JX005)
            # instead of allocating a fresh carry next to them.
            new_cache = {name: cache[name] + state[name] for name in state}
            if "conv" in cache:
                new_cache["conv"] = L.trailing_window(
                    vraw, self.dwconv.width - 1, cache["conv"].dtype,
                    lengths=lengths)
        else:
            out = softmax_attention(q, k, v, causal=self.causal,
                                    window=self.window,
                                    softcap=cfg.attn_logit_softcap,
                                    chunk=min(512, n))
            length = cache["k"].shape[2]
            if lengths is not None and n > length:
                raise ValueError("lengths-masked prefill requires the prompt "
                                 f"to fit the cache ({n} > {length})")
            m = min(n, length)          # ring buffer keeps the last `length`
            pos_abs = jnp.arange(n - m, n, dtype=jnp.int32)
            slots = jnp.mod(pos_abs, length)
            k_tail, v_tail = k[:, :, n - m:], v[:, :, n - m:]
            quantized = cfg.kv_cache_dtype == "int8"
            if quantized:
                kq, kscale = self._quantize_kv(k_tail)
                vq, vscale = self._quantize_kv(v_tail)
                ck = cache["k"].at[:, :, slots].set(kq)
                cv = cache["v"].at[:, :, slots].set(vq)
            else:
                ck = cache["k"].at[:, :, slots].set(
                    k_tail.astype(cache["k"].dtype))
                cv = cache["v"].at[:, :, slots].set(
                    v_tail.astype(cache["v"].dtype))
            pos_rows = jnp.broadcast_to(pos_abs[None], (b, m))
            if lengths is not None:
                # Padded rows stay invalid (-1); decode overwrites them
                # write-before-read as pos reaches each slot.
                pos_rows = jnp.where(pos_abs[None] < lengths[:, None],
                                     pos_rows, -1)
            slot_pos = cache["slot_pos"].at[:, slots].set(pos_rows)
            pos_new = (lengths.astype(jnp.int32) if lengths is not None
                       else jnp.full((b,), n, jnp.int32))
            new_cache = {"k": ck, "v": cv, "slot_pos": slot_pos,
                         "pos": pos_new}
            if quantized:
                new_cache["k_scale"] = cache["k_scale"].at[:, :, slots].set(kscale)
                new_cache["v_scale"] = cache["v_scale"].at[:, :, slots].set(vscale)
        out = out.transpose(0, 2, 1, 3).reshape(b, n, self.h * self.dh)
        return self.o_proj(params["o"], out), new_cache

    def decode_step(self, params, x_t, cache):
        """x_t: (B, d_model) one token. Returns (y_t, cache).

        Positions are per-row ((B,) in the cache), so a packed continuous
        decode batch can hold requests at different depths.
        """
        b = x_t.shape[0]
        pos = cache["count"].astype(jnp.int32) if "count" in cache else cache["pos"]
        positions = pos[:, None]
        if self.cfg.rope == "mrope":
            positions = jnp.broadcast_to(pos[:, None, None], (b, 3, 1))
        x = x_t[:, None, :]
        q = self.q_proj(params["q"], x).reshape(b, 1, self.h, self.dh)
        k = self.k_proj(params["k"], x).reshape(b, 1, self.hkv, self.dh)
        vflat = self.v_proj(params["v"], x)
        if self.dwconv is not None and "conv" in cache:
            vconv, conv_state = self.dwconv.step(params["dwconv"], vflat[:, 0], cache["conv"])
            vflat = vflat + vconv[:, None]
        else:
            conv_state = None
        v = vflat.reshape(b, 1, self.hkv, self.dh)
        q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        if self.qk_norm:
            q = self.q_norm(params["q_norm"], q)
            k = self.k_norm(params["k_norm"], k)
        q, k = self._rope(q, k, positions)

        if self.mode in ("linear", "binary_linear"):
            g = self.h // self.hkv
            kf = _repeat_kv(k, g)[:, :, 0].astype(jnp.float32)
            vf = _repeat_kv(v, g)[:, :, 0].astype(jnp.float32)
            state = {n: cache[n] for n in ("kv", "ksum", "vsum", "count")}
            out, state = la.binary_linear_attention_step(
                q[:, :, 0].astype(jnp.float32), kf, vf, state, self.feature)
            if conv_state is not None:
                state["conv"] = conv_state
            out = out[:, :, None].astype(x_t.dtype)
            new_cache = state
        else:
            quantized = self.cfg.kv_cache_dtype == "int8"
            length = cache["k"].shape[2]
            slot = jnp.mod(pos, length)                     # (B,)
            rows = jnp.arange(b)
            # Per-row ring-buffer write: row i lands at its own slot[i]
            # (advanced-index scatter; the advanced axes move to the front,
            # which matches the (B, Hkv, Dh) value layout).
            if quantized:
                kq, kscale = self._quantize_kv(k)
                vq, vscale = self._quantize_kv(v)
                ck = cache["k"].at[rows, :, slot].set(kq[:, :, 0])
                cv = cache["v"].at[rows, :, slot].set(vq[:, :, 0])
                ks = cache["k_scale"].at[rows, :, slot].set(kscale[:, :, 0])
                vs = cache["v_scale"].at[rows, :, slot].set(vscale[:, :, 0])
            else:
                ck = cache["k"].at[rows, :, slot].set(
                    k[:, :, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[rows, :, slot].set(
                    v[:, :, 0].astype(cache["v"].dtype))
            slot_pos = cache["slot_pos"].at[rows, slot].set(pos)
            qg = q.reshape(b, self.hkv, self.h // self.hkv, self.dh)
            # preferred_element_type avoids materializing an f32 copy of the
            # whole cache (the dominant decode temp buffer otherwise). For the
            # int8 cache the per-token scales factor OUT of the contraction
            # (s_l = (q · k_l) · scale_l), so no dequantized copy exists at all.
            s = jnp.einsum("bkgd,bkld->bkgl", qg,
                           ck.astype(qg.dtype) if not quantized else
                           ck.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * (self.dh ** -0.5)
            if quantized:
                s = s * ks[:, :, None, :]
            if self.cfg.attn_logit_softcap:
                s = jnp.tanh(s / self.cfg.attn_logit_softcap) * self.cfg.attn_logit_softcap
            valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
            if self.window:
                valid &= (pos[:, None] - slot_pos) < self.window
            s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            if quantized:
                p = p * vs[:, :, None, :]          # fold V scales into probs
                out = jnp.einsum("bkgl,bkld->bkgd", p.astype(jnp.bfloat16),
                                 cv.astype(jnp.bfloat16),
                                 preferred_element_type=jnp.float32)
            else:
                out = jnp.einsum("bkgl,bkld->bkgd", p.astype(cv.dtype), cv,
                                 preferred_element_type=jnp.float32)
            out = out.reshape(b, self.h, 1, self.dh).astype(x_t.dtype)
            new_cache = {"k": ck, "v": cv, "slot_pos": slot_pos, "pos": pos + 1}
            if quantized:
                new_cache.update(k_scale=ks, v_scale=vs)

        out = out.transpose(0, 2, 1, 3).reshape(b, self.h * self.dh)
        return self.o_proj(params["o"], out), new_cache


# ---------------------------------------------------------------------------
# Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

class MLAttention:
    def __init__(self, cfg):
        self.cfg = cfg
        m = cfg.mla
        self.m = m
        self.h = cfg.n_heads
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        lin = cfg.policy.proj_linear()
        d = cfg.d_model
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        self.qk_head = qk_head
        self.q_down = L.make_linear(lin, d, m.q_lora_rank, False, dt, pdt)
        self.q_up = L.make_linear(lin, m.q_lora_rank, self.h * qk_head, False, dt, pdt)
        self.kv_down = L.make_linear(lin, d, m.kv_lora_rank + m.qk_rope_head_dim,
                                     False, dt, pdt)
        self.kv_up = L.make_linear(lin, m.kv_lora_rank,
                                   self.h * (m.qk_nope_head_dim + m.v_head_dim),
                                   False, dt, pdt)
        self.o_proj = L.make_linear(lin, self.h * m.v_head_dim, d, False, dt, pdt)
        self.q_norm = L.RMSNorm(m.q_lora_rank, cfg.norm_eps, dt, pdt)
        self.kv_norm = L.RMSNorm(m.kv_lora_rank, cfg.norm_eps, dt, pdt)
        self.mode = cfg.policy.attention
        self.feature = "binary" if self.mode == "binary_linear" else "elu1"

    def init(self, key):
        ks = jax.random.split(key, 7)
        return {"q_down": self.q_down.init(ks[0]), "q_up": self.q_up.init(ks[1]),
                "kv_down": self.kv_down.init(ks[2]), "kv_up": self.kv_up.init(ks[3]),
                "o": self.o_proj.init(ks[4]), "q_norm": self.q_norm.init(ks[5]),
                "kv_norm": self.kv_norm.init(ks[6])}

    def spec(self, params):
        return {
            "q_down": L.match_linear_spec(params["q_down"], L.linear_spec("embed", None)),
            "q_up": L.match_linear_spec(params["q_up"], L.linear_spec(None, "heads")),
            "kv_down": L.match_linear_spec(params["kv_down"], L.linear_spec("embed", None)),
            "kv_up": L.match_linear_spec(params["kv_up"], L.linear_spec(None, "heads")),
            "o": L.match_linear_spec(params["o"], L.linear_spec("heads", "embed")),
            "q_norm": self.q_norm.spec(), "kv_norm": self.kv_norm.spec(),
        }

    def _project(self, params, x, positions):
        b, n, _ = x.shape
        m = self.m
        q = self.q_up(params["q_up"],
                      self.q_norm(params["q_norm"],
                                  self.q_down(params["q_down"], x)))
        q = q.reshape(b, n, self.h, self.qk_head).transpose(0, 2, 1, 3)
        q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
        kvd = self.kv_down(params["kv_down"], x)
        c_kv, k_rope = jnp.split(kvd, [m.kv_lora_rank], axis=-1)
        c_kv = self.kv_norm(params["kv_norm"], c_kv)                 # (B,N,r)
        k_rope = k_rope[:, None]                                     # (B,1,N,rope)
        if positions is not None:
            q_rope = L.apply_rope(q_rope, positions, self.cfg.rope_theta)
            k_rope = L.apply_rope(k_rope, positions, self.cfg.rope_theta)
        return q_nope, q_rope, c_kv, k_rope

    def _assemble_qkv(self, params, x, positions):
        """Full per-head (q, k, v) plus the latent (c_kv, k_rope) streams —
        shared by __call__ and prefill so their math can never diverge."""
        b, n, _ = x.shape
        m = self.m
        q_nope, q_rope, c_kv, k_rope = self._project(params, x, positions)
        kv = self.kv_up(params["kv_up"], c_kv)
        kv = kv.reshape(b, n, self.h, m.qk_nope_head_dim + m.v_head_dim)
        kv = kv.transpose(0, 2, 1, 3)
        k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (b, self.h, n, m.qk_rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        return q, k, v, c_kv, k_rope

    def __call__(self, params, x, positions=None, train=True):
        b, n, _ = x.shape
        m = self.m
        q, k, v, _, _ = self._assemble_qkv(params, x, positions)
        if self.mode == "dense":
            out = softmax_attention(q, k, v, causal=self.cfg.causal,
                                    chunk=min(512, n))
        else:
            out = la.binary_linear_attention(
                q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), causal=self.cfg.causal,
                chunk=min(128, n), train=train,
                feature=self.feature).astype(x.dtype)
        out = out.transpose(0, 2, 1, 3).reshape(b, n, self.h * m.v_head_dim)
        return self.o_proj(params["o"], out)

    # -- decode: compressed latent cache + absorbed form ---------------------
    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        m = self.m
        if self.mode in ("linear", "binary_linear"):
            return la.init_decode_state(batch, self.h, self.qk_head,
                                        m.v_head_dim, jnp.float32)
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params, x, cache, positions=None, lengths=None):
        """Whole-prompt pass against a fresh cache → (y, decode-ready cache).

        Linear modes hand over the chunked pass's final recurrent carry; the
        dense mode bulk-writes the compressed latent (c_kv, k_rope) rows.
        """
        b, n, _ = x.shape
        m = self.m
        q, k, v, c_kv, k_rope = self._assemble_qkv(params, x, positions)
        if self.mode in ("linear", "binary_linear"):
            out, state = la.binary_linear_attention(
                q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), causal=self.cfg.causal,
                chunk=min(128, n), train=False, feature=self.feature,
                return_state=True, lengths=lengths)
            out = out.astype(x.dtype)
            # Additive carry: accumulate into the donated cache (see the
            # GQA prefill above — exact for zeros, JX005-consumable).
            new_cache = {name: cache[name] + state[name] for name in state}
        else:
            out = softmax_attention(q, k, v, causal=self.cfg.causal,
                                    chunk=min(512, n))
            ck = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
            cr = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
                (0, 0, 0))
            # Padded latent rows beyond lengths[b] hold garbage but stay
            # masked (valid = slot <= pos) until decode overwrites them
            # write-before-read as pos reaches each row.
            pos_new = (lengths.astype(jnp.int32) if lengths is not None
                       else jnp.full((b,), n, jnp.int32))
            new_cache = {"c_kv": ck, "k_rope": cr, "pos": pos_new}
        out = out.transpose(0, 2, 1, 3).reshape(b, n, self.h * m.v_head_dim)
        return self.o_proj(params["o"], out), new_cache

    def decode_step(self, params, x_t, cache):
        b = x_t.shape[0]
        m = self.m
        pos = cache["count"].astype(jnp.int32) if "count" in cache else cache["pos"]
        positions = pos[:, None]
        x = x_t[:, None, :]
        q_nope, q_rope, c_kv, k_rope = self._project(params, x, positions)

        if self.mode in ("linear", "binary_linear"):
            kv = self.kv_up(params["kv_up"], c_kv)
            kv = kv.reshape(b, 1, self.h, m.qk_nope_head_dim + m.v_head_dim)
            kv = kv.transpose(0, 2, 1, 3)
            k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
            k = jnp.concatenate([k_nope[:, :, 0], jnp.broadcast_to(
                k_rope[:, :, 0], (b, self.h, m.qk_rope_head_dim))], axis=-1)
            q = jnp.concatenate([q_nope[:, :, 0], q_rope[:, :, 0]], axis=-1)
            out, cache = la.binary_linear_attention_step(
                q.astype(jnp.float32), k.astype(jnp.float32),
                v[:, :, 0].astype(jnp.float32), cache, self.feature)
            out = out.astype(x_t.dtype)
        else:
            rows = jnp.arange(b)
            # Per-row latent write at each row's own position.
            ck = cache["c_kv"].at[rows, pos].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype))
            cr = cache["k_rope"].at[rows, pos].set(
                k_rope[:, 0, 0].astype(cache["k_rope"].dtype))
            # Absorbed form: W_uk into q, W_uv out of the latent context.
            w_kv = params["kv_up"].get("kernel")
            if w_kv is None:  # shift-packed projections: reconstruct
                from repro.core.quant import po2_weight_from_packed
                w_kv = (po2_weight_from_packed(params["kv_up"]["w_packed"])
                        if "w_packed" in params["kv_up"]
                        else params["kv_up"]["w_latent"])
            w_kv = w_kv.reshape(m.kv_lora_rank, self.h,
                                m.qk_nope_head_dim + m.v_head_dim)
            w_uk, w_uv = jnp.split(w_kv, [m.qk_nope_head_dim], axis=-1)
            dt = ck.dtype
            q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0].astype(dt),
                               w_uk.astype(dt), preferred_element_type=jnp.float32)
            s = jnp.einsum("bhr,blr->bhl", q_abs.astype(dt), ck,
                           preferred_element_type=jnp.float32)
            s += jnp.einsum("bhp,blp->bhl", q_rope[:, :, 0].astype(dt), cr,
                            preferred_element_type=jnp.float32)
            s *= self.qk_head ** -0.5
            valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
            s = jnp.where(valid[:, None, :], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            ctx = jnp.einsum("bhl,blr->bhr", p.astype(dt), ck,
                             preferred_element_type=jnp.float32)
            out = jnp.einsum("bhr,rhv->bhv", ctx.astype(dt), w_uv.astype(dt),
                             preferred_element_type=jnp.float32)
            out = out.astype(x_t.dtype)
            cache = {"c_kv": ck, "k_rope": cr, "pos": pos + 1}

        out = out.reshape(b, self.h * m.v_head_dim)
        return self.o_proj(params["o"], out), cache
