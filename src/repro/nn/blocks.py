"""Transformer block: (mixer, feed) pair selected by (config, policy).

mixer ∈ {GQA attention, local attention, MLA, RG-LRU, RWKV6 time-mix}
feed  ∈ {MLP (dense|shift), MoE-of-primitives (the paper), token-choice MoE
         (the architecture's own), RWKV6 channel-mix}

Pre-norm residual wiring; `parallel_block=True` gives the GPT-J/Command-R
parallel attention+FFN form. Every block returns (x, aux_scalars) where aux
carries MoE balance losses (summed over layers by the model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.moe_primitives import MoEPrimitives
from repro.nn import layers as L
from repro.nn.attention import Attention, MLAttention
from repro.nn.moe import TokenChoiceMoE
from repro.nn.recurrent import RGLRUBlock, RWKV6ChannelMix, RWKV6TimeMix

ZERO_AUX = {"balance_loss": jnp.float32(0.0), "drop_fraction": jnp.float32(0.0)}


def _make_mixer(cfg, kind):
    if kind in ("attn", "local_attn"):
        if cfg.mla is not None:
            return MLAttention(cfg)
        return Attention(cfg, layer_kind=kind)
    if kind == "rglru":
        return RGLRUBlock(cfg)
    if kind == "rwkv6":
        return RWKV6TimeMix(cfg)
    raise ValueError(kind)


def _make_feed(cfg, kind):
    dt, pdt = cfg.activation_dtype, cfg.weight_dtype
    p = cfg.policy
    if kind == "rwkv6":
        return RWKV6ChannelMix(cfg)
    if cfg.moe is not None:
        return TokenChoiceMoE(cfg)
    if p.mlp == "moe_primitives":
        experts = [
            L.MLP(cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                  "dense" if ek == "mult" else p.mlp_linear(),
                  cfg.use_bias, dt, pdt)
            for ek in p.moe_experts
        ]
        # No explicit latencies: the analytic model is evaluated at the
        # model's DEPLOYMENT per-group token count (a ViT dispatches one
        # image row of n_patches tokens per group — the regime the capacity
        # split serves in). LM configs have no fixed per-group count (prefill
        # groups a whole prompt, decode a single token), so they leave the
        # ref unset and keep the nominal-regime constant — the split must not
        # vary with group size or prefill and decode route differently. The
        # telemetry loop (serve.telemetry.apply_expert_latencies) drops
        # measured values in afterwards either way.
        return MoEPrimitives(cfg.d_model, cfg.d_ff, expert_kinds=p.moe_experts,
                             capacity_factor=cfg.moe_primitives_capacity,
                             latency_aware=p.latency_aware, router_noise=0.0,
                             dtype=dt, param_dtype=pdt, experts=experts,
                             capacity_ref_tokens=cfg.moe_capacity_ref_tokens)
    lin = p.mlp_linear() if p.mlp == "shift" else "dense"
    return L.MLP(cfg.d_model, cfg.d_ff, cfg.mlp_kind, lin, cfg.use_bias, dt, pdt)


class TransformerBlock:
    def __init__(self, cfg, kind="attn"):
        self.cfg = cfg
        self.kind = kind
        self.parallel = getattr(cfg, "parallel_block", False)
        self.mixer = _make_mixer(cfg, kind)
        self.feed = _make_feed(cfg, kind)
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        self.norm1 = L.make_norm(cfg.norm, cfg.d_model, cfg.norm_eps, dt, pdt)
        self.norm2 = None if self.parallel else L.make_norm(
            cfg.norm, cfg.d_model, cfg.norm_eps, dt, pdt)
        self._feed_has_aux = isinstance(self.feed, (TokenChoiceMoE, MoEPrimitives))

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"mixer": self.mixer.init(k1), "feed": self.feed.init(k2),
             "norm1": self.norm1.init(k3)}
        if self.norm2 is not None:
            p["norm2"] = self.norm2.init(k4)
        return p

    def spec(self, params):
        s = {"mixer": self.mixer.spec(params["mixer"]),
             "feed": self.feed.spec(params["feed"]),
             "norm1": self.norm1.spec()}
        if self.norm2 is not None:
            s["norm2"] = self.norm2.spec()
        return s

    def _apply_feed(self, params, x, train):
        if self._feed_has_aux:
            y, aux = self.feed(params["feed"], x, train=train)
            return y, {"balance_loss": aux["balance_loss"].astype(jnp.float32),
                       "drop_fraction": aux["drop_fraction"].astype(jnp.float32)}
        return self.feed(params["feed"], x), ZERO_AUX

    def __call__(self, params, x, positions=None, train=True):
        h = self.norm1(params["norm1"], x)
        mix = self.mixer(params["mixer"], h, positions=positions, train=train)
        if self.parallel:
            ff, aux = self._apply_feed(params, h, train)
            return x + mix + ff, aux
        x = x + mix
        h2 = self.norm2(params["norm2"], x)
        ff, aux = self._apply_feed(params, h2, train)
        return x + ff, aux

    # -- inference -----------------------------------------------------------
    # impl/tune thread from the engine to the kernel-selecting leaves
    # (ShiftLinear, the fused attention op) and stop at layers without one.
    def _infer_feed(self, params, x, impl=None, tune=None):
        if hasattr(self.feed, "infer"):
            if getattr(self.feed, "accepts_impl", False):
                return self.feed.infer(params["feed"], x, impl=impl,
                                       tune=tune)
            return self.feed.infer(params["feed"], x)
        if self._feed_has_aux:
            y, _ = self.feed(params["feed"], x, train=False)
            return y
        if getattr(self.feed, "accepts_impl", False):
            return self.feed(params["feed"], x, impl=impl, tune=tune)
        return self.feed(params["feed"], x)

    def _infer_mixer(self, params, h, positions, impl=None, tune=None):
        if hasattr(self.mixer, "infer"):
            if getattr(self.mixer, "accepts_impl", False):
                return self.mixer.infer(params["mixer"], h,
                                        positions=positions, impl=impl,
                                        tune=tune)
            return self.mixer.infer(params["mixer"], h, positions=positions)
        return self.mixer(params["mixer"], h, positions=positions, train=False)

    def infer(self, params, x, positions=None, impl=None, tune=None):
        """Aux-free inference forward: same residual wiring as __call__ with
        train=False, but mixers take their serving path (fused bidirectional
        Hamming attention for encoder binary-linear mode) and MoE feeds their
        deterministic gather dispatch (clean-logit argmax, no rng, no
        balance/drop bookkeeping) with capacity planned PER BATCH ROW — a
        row's output never depends on its co-batched neighbors, so the whole
        block forward is batch-invariant per row. Returns x only — the
        serving engines jit this, typically closed over a core.deploy
        DeployPlan's frozen params so no per-call weight decode survives in
        the compiled program."""
        h = self.norm1(params["norm1"], x)
        mix = self._infer_mixer(params, h, positions, impl=impl, tune=tune)
        if self.parallel:
            return x + mix + self._infer_feed(params, h, impl=impl, tune=tune)
        x = x + mix
        h2 = self.norm2(params["norm2"], x)
        return x + self._infer_feed(params, h2, impl=impl, tune=tune)

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        cache = {"mixer": self.mixer.init_cache(batch, max_len, dtype)}
        if hasattr(self.feed, "init_cache"):
            cache["feed"] = self.feed.init_cache(batch, max_len, dtype)
        return cache

    def prefill(self, params, x, cache, positions=None, lengths=None):
        """Whole-prompt pass against a fresh cache. x: (B, N, d_model) →
        (y (B, N, d_model), decode-ready cache). Same residual wiring as
        __call__; the mixer fills its decode state in one chunked pass.
        lengths (B,) int32 marks per-row valid prompt length for
        bucket-padded prompts (end padding never enters the handed-over
        state)."""
        h = self.norm1(params["norm1"], x)
        mix, mixer_cache = self.mixer.prefill(params["mixer"], h,
                                              cache["mixer"],
                                              positions=positions,
                                              lengths=lengths)
        new_cache = {"mixer": mixer_cache}
        if self.parallel:
            ff, fc = self._feed_prefill(params, h, cache, lengths)
            if fc is not None:
                new_cache["feed"] = fc
            return x + mix + ff, new_cache
        x = x + mix
        h2 = self.norm2(params["norm2"], x)
        ff, fc = self._feed_prefill(params, h2, cache, lengths)
        if fc is not None:
            new_cache["feed"] = fc
        return x + ff, new_cache

    def _feed_prefill(self, params, h, cache, lengths=None):
        if hasattr(self.feed, "prefill"):
            return self.feed.prefill(params["feed"], h, cache["feed"],
                                     lengths=lengths)
        if self._feed_has_aux:
            y, _ = self.feed(params["feed"], h, train=False)
            return y, None
        return self.feed(params["feed"], h), None

    def decode_step(self, params, x_t, cache):
        """x_t: (B, d_model) → (y_t, cache)."""
        h = self.norm1(params["norm1"], x_t[:, None])[:, 0]
        mix, mixer_cache = self.mixer.decode_step(params["mixer"], h, cache["mixer"])
        new_cache = {"mixer": mixer_cache}
        if self.parallel:
            ff, fc = self._feed_step(params, h, cache)
            if fc is not None:
                new_cache["feed"] = fc
            return x_t + mix + ff, new_cache
        x_t = x_t + mix
        h2 = self.norm2(params["norm2"], x_t[:, None])[:, 0]
        ff, fc = self._feed_step(params, h2, cache)
        if fc is not None:
            new_cache["feed"] = fc
        return x_t + ff, new_cache

    def _feed_step(self, params, h, cache):
        if hasattr(self.feed, "decode_step"):
            return self.feed.decode_step(params["feed"], h, cache["feed"])
        if self._feed_has_aux:
            y, _ = self.feed(params["feed"], h[:, None], train=False)
            return y[:, 0], None
        return self.feed(params["feed"], h[:, None])[:, 0], None
