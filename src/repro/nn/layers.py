"""Substrate layers: norms, embeddings, rotary (incl. M-RoPE), MLPs, DWConv.

Every weight-bearing layer honors the ShiftAddPolicy through `make_linear`:
dense (Mult.) or ShiftLinear (s·2^P). Each module exposes `.spec()` — a
pytree of logical-axis name tuples mirroring its params — consumed by
repro.distributed.sharding to produce mesh PartitionSpecs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import Dense
from repro.core.shift_linear import ShiftLinear


def make_linear(kind, d_in, d_out, use_bias=False, dtype=jnp.bfloat16,
                param_dtype=jnp.float32):
    """kind: "dense" | "shift" | "shift_packed" — the policy switch for one
    projection (packed = int8 deployment format, frozen)."""
    if kind == "dense":
        return Dense(d_in, d_out, use_bias=use_bias, dtype=dtype,
                     param_dtype=param_dtype)
    mode = "packed" if kind == "shift_packed" else "latent"
    return ShiftLinear(d_in, d_out, use_bias=use_bias, dtype=dtype,
                       param_dtype=param_dtype, mode=mode)


def call_linear(layer, params, x, impl=None, tune=None):
    """Apply a `make_linear` product, threading kernel impl/tune selection to
    layers that have one (ShiftLinear → kernels.ops). Dense has no kernel
    selection; the kwargs stop here instead of leaking a process global into
    the call (the old `ops.default_impl()` memoization bug)."""
    if getattr(layer, "accepts_impl", False):
        return layer(params, x, impl=impl, tune=tune)
    return layer(params, x)


def linear_spec(in_axis, out_axis, use_bias=False):
    """Logical spec for Dense/ShiftLinear params (same tree keys either way:
    kernel/w_latent/w_packed are all (in, out))."""
    spec = {"kernel": (in_axis, out_axis)}
    if use_bias:
        spec["bias"] = (out_axis,)
    return spec


def match_linear_spec(params, spec):
    """Rename the kernel key of a linear spec to match actual param keys."""
    out = {}
    for key in params:
        if key == "bias":
            out["bias"] = spec.get("bias", (spec["kernel"][-1],))
        else:
            out[key] = spec["kernel"]
    return out


class RMSNorm:
    def __init__(self, dim, eps=1e-6, dtype=jnp.bfloat16, param_dtype=jnp.float32):
        self.dim, self.eps, self.dtype, self.param_dtype = dim, eps, dtype, param_dtype

    def init(self, key):
        return {"scale": jnp.ones((self.dim,), self.param_dtype)}

    def spec(self):
        return {"scale": (None,)}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"].astype(jnp.float32)).astype(self.dtype)


class LayerNorm:
    def __init__(self, dim, eps=1e-6, dtype=jnp.bfloat16, param_dtype=jnp.float32):
        self.dim, self.eps, self.dtype, self.param_dtype = dim, eps, dtype, param_dtype

    def init(self, key):
        return {"scale": jnp.ones((self.dim,), self.param_dtype),
                "bias": jnp.zeros((self.dim,), self.param_dtype)}

    def spec(self):
        return {"scale": (None,), "bias": (None,)}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(self.dtype)


def make_norm(kind, dim, eps, dtype, param_dtype):
    cls = RMSNorm if kind == "rmsnorm" else LayerNorm
    return cls(dim, eps, dtype, param_dtype)


class Embedding:
    def __init__(self, vocab, dim, dtype=jnp.bfloat16, param_dtype=jnp.float32):
        self.vocab, self.dim, self.dtype, self.param_dtype = vocab, dim, dtype, param_dtype

    def init(self, key):
        table = jax.random.normal(key, (self.vocab, self.dim), jnp.float32) * 0.02
        return {"table": table.astype(self.param_dtype)}

    def spec(self):
        return {"table": ("vocab", "embed")}

    def __call__(self, params, ids):
        return params["table"].astype(self.dtype)[ids]

    def attend(self, params, x):
        """Tied output head: logits = x @ tableᵀ."""
        return jnp.einsum("...d,vd->...v", x.astype(self.dtype),
                          params["table"].astype(self.dtype))


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta=10000.0):
    """x: (B, H, N, D); positions: (B, N) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    ang = positions.astype(jnp.float32)[:, None, :, None] * freqs  # (B,1,N,D/2)
    return _rotate(x.astype(jnp.float32), jnp.sin(ang), jnp.cos(ang)).astype(x.dtype)


def apply_mrope(x, positions, sections, theta=10000.0):
    """Multimodal RoPE (Qwen2-VL): positions (B, 3, N) = (t, h, w) ids;
    the head-dim frequency bands are split across the three position streams.
    sections: per-stream *pair* counts summing to D/2 (e.g. 16/24/24 for D=128).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    # Build a (B, N, D/2) angle tensor: each frequency band uses the position
    # stream its section assigns.
    parts = []
    start = 0
    for s_idx, width in enumerate(sections):
        f = freqs[start:start + width]
        pos = positions[:, s_idx].astype(jnp.float32)              # (B, N)
        parts.append(pos[:, :, None] * f)                          # (B,N,width)
        start += width
    ang = jnp.concatenate(parts, axis=-1)[:, None]                 # (B,1,N,D/2)
    return _rotate(x.astype(jnp.float32), jnp.sin(ang), jnp.cos(ang)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (policy-aware)
# ---------------------------------------------------------------------------

class MLP:
    """mlp: up→act→down.  swiglu/geglu: (gate, up)→act(gate)*up→down."""

    def __init__(self, d_model, d_ff, kind="swiglu", linear="dense",
                 use_bias=False, dtype=jnp.bfloat16, param_dtype=jnp.float32):
        self.kind = kind
        self.gated = kind in ("swiglu", "geglu")
        self.act = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu, "mlp": jax.nn.gelu}[kind]
        mk = lambda i, o: make_linear(linear, i, o, use_bias, dtype, param_dtype)
        if self.gated:
            self.gate = mk(d_model, d_ff)
        self.up = mk(d_model, d_ff)
        self.down = mk(d_ff, d_model)

    def init(self, key):
        ks = jax.random.split(key, 3)
        p = {"up": self.up.init(ks[0]), "down": self.down.init(ks[1])}
        if self.gated:
            p["gate"] = self.gate.init(ks[2])
        return p

    def spec(self, params):
        s = {"up": match_linear_spec(params["up"], linear_spec("embed", "mlp")),
             "down": match_linear_spec(params["down"], linear_spec("mlp", "embed"))}
        if self.gated:
            s["gate"] = match_linear_spec(params["gate"], linear_spec("embed", "mlp"))
        return s

    # Shift-MLPs route through ShiftLinear; serving threads impl/tune here.
    accepts_impl = True

    def __call__(self, params, x, impl=None, tune=None):
        h = call_linear(self.up, params["up"], x, impl, tune)
        if self.gated:
            h = self.act(call_linear(self.gate, params["gate"], x,
                                     impl, tune)) * h
        else:
            h = self.act(h)
        return call_linear(self.down, params["down"], h, impl, tune)


class DWConv1D:
    """Depthwise temporal conv. Causal for decoders (RG-LRU conv, V-branch
    DWConv of the paper's linear attention); 'same' for encoders."""

    def __init__(self, dim, width=4, causal=True, dtype=jnp.bfloat16,
                 param_dtype=jnp.float32):
        self.dim, self.width, self.causal = dim, width, causal
        self.dtype, self.param_dtype = dtype, param_dtype

    def init(self, key):
        k = jax.random.normal(key, (self.width, self.dim), jnp.float32)
        return {"kernel": (k * (self.width ** -0.5)).astype(self.param_dtype),
                "bias": jnp.zeros((self.dim,), self.param_dtype)}

    def spec(self):
        return {"kernel": (None, "embed"), "bias": (None,)}

    def __call__(self, params, x):
        """x: (B, N, D) → (B, N, D).

        Computed as `width` shifted multiply-adds rather than a grouped
        conv_general_dilated: a depthwise conv with D groups lowers to a
        pathologically slow per-channel loop on CPU XLA (~19 ms/layer at
        D=128 — it single-handedly kept the stage-1 serving arm 4× over
        dense), while the shifted-add form is three fused elementwise FMAs.
        """
        w = params["kernel"].astype(self.dtype)
        n = x.shape[1]
        if self.causal:
            left, right = self.width - 1, 0
        else:
            left, right = (self.width - 1) // 2, self.width // 2
        xp = jnp.pad(x.astype(self.dtype), ((0, 0), (left, right), (0, 0)))
        y = xp[:, 0:n, :] * w[0]
        for t in range(1, self.width):
            y = y + xp[:, t:t + n, :] * w[t]
        return y + params["bias"].astype(self.dtype)

    def step(self, params, x_t, conv_state):
        """Decode step. x_t: (B, D); conv_state: (B, width-1, D)."""
        w = params["kernel"].astype(self.dtype)
        window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)
        y = jnp.einsum("bwd,wd->bd", window.astype(self.dtype), w)
        return y + params["bias"].astype(self.dtype), window[:, 1:]


def trailing_window(x, w, dtype=None, lengths=None):
    """Last `w` steps of x (B, N, D), front-zero-padded to exactly `w`.

    Warms a causal-conv decode state from a full-sequence (prefill) pass: the
    zeros for N < w reproduce the conv's implicit causal left-padding.

    lengths (B,) int32: per-row valid length for end-padded batches — row b's
    window ends at position lengths[b]-1, with the same zero left-padding for
    lengths[b] < w.
    """
    b, n, d = x.shape
    if lengths is not None:
        idx = lengths[:, None] - w + jnp.arange(w, dtype=lengths.dtype)[None, :]
        tail = jnp.take_along_axis(x, jnp.clip(idx, 0, n - 1)[:, :, None],
                                   axis=1)
        tail = jnp.where(idx[:, :, None] >= 0, tail, 0)
        return tail.astype(dtype or x.dtype)
    tail = x[:, max(0, n - w):]
    if n < w:
        tail = jnp.pad(tail, ((0, 0), (w - n, 0), (0, 0)))
    return tail.astype(dtype or x.dtype)
