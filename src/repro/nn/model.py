"""LanguageModel: embeddings → scanned block stack → norm → logits.

Covers all assigned families: decoder LMs (dense/GQA/MLA/MoE), hybrid
(RG-LRU + local attention), SSM (RWKV6), encoder-only (HuBERT — causal=False),
and stub-frontend modalities (input_mode="embeddings" for [vlm]/[audio]:
the backbone consumes precomputed patch/frame embeddings per the assignment).

Depth is organized as `n_cycles` repetitions of `block_pattern` (+ remainder
blocks) and scanned with `lax.scan` so HLO size is O(1) in depth; per-block
remat policy per cfg.remat. Decode threads a per-cycle cache stack through the
same scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn.blocks import TransformerBlock


def _stack_init(block, key, n):
    return jax.vmap(block.init)(jax.random.split(key, n))


def _remat(fn, mode):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    raise ValueError(mode)


class LanguageModel:
    def __init__(self, cfg):
        self.cfg = cfg
        pattern = cfg.block_pattern
        self.pattern = pattern
        self.n_cycles = cfg.n_layers // len(pattern)
        self.n_rem = cfg.n_layers % len(pattern)
        self.blocks = [TransformerBlock(cfg, k) for k in pattern]
        self.rem_blocks = [TransformerBlock(cfg, pattern[j])
                           for j in range(self.n_rem)]
        dt, pdt = cfg.activation_dtype, cfg.weight_dtype
        self.embed = None
        if cfg.input_mode == "tokens":
            self.embed = L.Embedding(cfg.vocab_size, cfg.d_model, dt, pdt)
        self.final_norm = L.make_norm(cfg.norm, cfg.d_model, cfg.norm_eps, dt, pdt)
        self.head = None
        if not (cfg.tie_embeddings and self.embed is not None):
            from repro.core.dense import Dense
            self.head = Dense(cfg.d_model, cfg.vocab_size, use_bias=False,
                              dtype=dt, param_dtype=pdt)

    # -- params ----------------------------------------------------------------
    def init(self, key):
        keys = jax.random.split(key, len(self.blocks) + self.n_rem + 3)
        p = {"layers": [
            _stack_init(blk, keys[j], self.n_cycles)
            for j, blk in enumerate(self.blocks)
        ]}
        if self.n_rem:
            p["rem"] = [blk.init(keys[len(self.blocks) + j])
                        for j, blk in enumerate(self.rem_blocks)]
        if self.embed is not None:
            p["embed"] = self.embed.init(keys[-3])
        p["final_norm"] = self.final_norm.init(keys[-2])
        if self.head is not None:
            p["head"] = self.head.init(keys[-1])
        return p

    def spec(self, params):
        """Logical-axis tree matching init() output. Scanned stacks get a
        leading 'layers' axis."""
        def add_layers(tree):
            return jax.tree_util.tree_map(
                lambda axes: ("layers",) + tuple(axes), tree,
                is_leaf=lambda x: isinstance(x, tuple))

        def unstack(a):
            # Works for arrays and ShapeDtypeStructs (eval_shape'd params).
            if isinstance(a, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
            return a[0]

        s = {"layers": [
            add_layers(blk.spec(jax.tree_util.tree_map(unstack, params["layers"][j])))
            for j, blk in enumerate(self.blocks)
        ]}
        if self.n_rem:
            s["rem"] = [blk.spec(params["rem"][j])
                        for j, blk in enumerate(self.rem_blocks)]
        if self.embed is not None:
            s["embed"] = self.embed.spec()
        s["final_norm"] = self.final_norm.spec()
        if self.head is not None:
            s["head"] = {"kernel": ("embed", "vocab")}
        return s

    # -- forward ----------------------------------------------------------------
    def _inputs_to_x(self, params, inputs):
        from repro.distributed.sharding import constrain

        if self.embed is not None:
            x = self.embed(params["embed"], inputs)
        else:
            x = inputs.astype(self.cfg.activation_dtype)
        # Keep the embedding-gather output batch-sharded (avoids GSPMD's
        # involuntary full remat on the vocab-sharded table gather).
        axes = ("batch",) + (None,) * (x.ndim - 1)
        return constrain(x, axes)

    def _default_positions(self, batch, n):
        pos = jnp.arange(n, dtype=jnp.int32)[None]
        if self.cfg.rope == "mrope":
            return jnp.broadcast_to(pos[:, None], (batch, 3, n))
        return jnp.broadcast_to(pos, (batch, n))

    def __call__(self, params, inputs, positions=None, train=True):
        """inputs: (B, N) int32 tokens or (B, N, d) embeddings.
        Returns (logits (B, N, vocab), aux)."""
        cfg = self.cfg
        x = self._inputs_to_x(params, inputs)
        b, n = x.shape[0], x.shape[1]
        if positions is None:
            positions = self._default_positions(b, n)

        def apply_block(blk, p, x):
            fn = lambda pp, xx: blk(pp, xx, positions=positions, train=train)
            return _remat(fn, cfg.remat)(p, x)

        bal = jnp.float32(0.0)
        drop = jnp.float32(0.0)
        if cfg.scan_layers and self.n_cycles > 0:
            def body(carry, layer_params):
                x, bal, drop = carry
                for j, blk in enumerate(self.blocks):
                    x, aux = apply_block(blk, layer_params[j], x)
                    bal += aux["balance_loss"]
                    drop += aux["drop_fraction"]
                return (x, bal, drop), None

            (x, bal, drop), _ = jax.lax.scan(
                body, (x, bal, drop), tuple(params["layers"]))
        else:
            for i in range(self.n_cycles):
                for j, blk in enumerate(self.blocks):
                    pj = jax.tree_util.tree_map(lambda a: a[i], params["layers"][j])
                    x, aux = apply_block(blk, pj, x)
                    bal += aux["balance_loss"]
                    drop += aux["drop_fraction"]
        for j, blk in enumerate(self.rem_blocks):
            x, aux = apply_block(blk, params["rem"][j], x)
            bal += aux["balance_loss"]
            drop += aux["drop_fraction"]

        x = self.final_norm(params["final_norm"], x)
        if self.head is not None:
            logits = self.head(params["head"], x)
        else:
            logits = self.embed.attend(params["embed"], x)
        aux = {"balance_loss": bal / max(cfg.n_layers, 1),
               "drop_fraction": drop / max(cfg.n_layers, 1)}
        return logits, aux

    # -- loss ---------------------------------------------------------------
    def loss(self, params, batch, train=True):
        """batch: {"inputs": ..., "labels": (B,N) int32, "positions": opt}.
        Returns (scalar, metrics). Adds λ·(L_IMP+L_LOAD)/token-choice aux."""
        logits, aux = self(params, batch["inputs"],
                           positions=batch.get("positions"), train=train)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("mask")
        if mask is None:
            ce = -jnp.mean(ll)
        else:
            ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        lam = self.cfg.policy.balance_loss_weight
        total = ce + lam * aux["balance_loss"]
        metrics = {"ce": ce, "balance_loss": aux["balance_loss"],
                   "drop_fraction": aux["drop_fraction"], "loss": total}
        return total, metrics

    # -- decode ----------------------------------------------------------------
    def init_cache(self, batch, max_len, dtype=None):
        dtype = dtype or self.cfg.activation_dtype

        def stacked(blk):
            one = blk.init_cache(batch, max_len, dtype)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.n_cycles,) + a.shape), one)

        cache = {"layers": [stacked(blk) for blk in self.blocks]}
        if self.n_rem:
            cache["rem"] = [blk.init_cache(batch, max_len, dtype)
                            for blk in self.rem_blocks]
        return cache

    def prefill(self, params, inputs, cache, positions=None, last_only=False,
                lengths=None):
        """Parallel prefill: one chunked full-sequence pass that fills a fresh
        decode cache (linear-state carries, dense KV rows, conv windows).

        inputs: (B, N) int32 tokens or (B, N, d) embeddings; cache from
        init_cache (must be fresh — positions are assumed to start at 0).
        Returns (logits (B, N, vocab), decode-ready cache); logits[:, -1] is
        the next-token distribution the decode loop samples from.
        last_only=True applies norm+head to the final position only (logits
        (B, 1, vocab)) — serving never reads the other N-1 rows, and for real
        vocabularies the full (B, N, vocab) buffer dominates prefill cost.
        lengths (B,) int32: per-row valid prompt length for bucket-padded
        prompts (the serving engine pads every prompt up to a shape bucket).
        End padding never enters the handed-over cache, and with
        last_only=True the returned logits row is each row's *last real*
        token — so a bucketed prefill is bit-comparable to an exact-length
        one up to XLA reduction-shape effects, and identical across calls of
        the same bucket.
        """
        if self.cfg.is_encoder:
            raise ValueError("prefill() is a decode-path API; "
                             f"{self.cfg.name} is encoder-only (causal=False)")
        x = self._inputs_to_x(params, inputs)
        b, n = x.shape[0], x.shape[1]
        if positions is None:
            positions = self._default_positions(b, n)

        if self.cfg.scan_layers and self.n_cycles > 0:
            def body(x, xs):
                layer_params, layer_cache = xs
                new_caches = []
                for j, blk in enumerate(self.blocks):
                    x, c = blk.prefill(layer_params[j], x, layer_cache[j],
                                       positions=positions, lengths=lengths)
                    new_caches.append(c)
                return x, tuple(new_caches)

            x, new_stacks = jax.lax.scan(
                body, x, (tuple(params["layers"]), tuple(cache["layers"])))
            new_cache = {"layers": list(new_stacks)}
        else:
            # Cycle-major (cycle 0: block 0..K, cycle 1: block 0..K, ...) to
            # match __call__ and the scanned branch.
            stack_c = [[] for _ in self.blocks]
            for i in range(self.n_cycles):
                for j, blk in enumerate(self.blocks):
                    pj = jax.tree_util.tree_map(lambda a: a[i], params["layers"][j])
                    cj = jax.tree_util.tree_map(lambda a: a[i], cache["layers"][j])
                    x, c = blk.prefill(pj, x, cj, positions=positions,
                                       lengths=lengths)
                    stack_c[j].append(c)
            new_cache = {"layers": [
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cs)
                for cs in stack_c]}
        if self.n_rem:
            new_rem = []
            for j, blk in enumerate(self.rem_blocks):
                x, c = blk.prefill(params["rem"][j], x, cache["rem"][j],
                                   positions=positions, lengths=lengths)
                new_rem.append(c)
            new_cache["rem"] = new_rem

        if last_only:
            if lengths is not None:
                x = jnp.take_along_axis(
                    x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
            else:
                x = x[:, -1:]
        x = self.final_norm(params["final_norm"], x)
        if self.head is not None:
            logits = self.head(params["head"], x)
        else:
            logits = self.embed.attend(params["embed"], x)
        return logits, new_cache

    def decode_step(self, params, inputs_t, cache):
        """inputs_t: (B,) int32 token or (B, d) embedding → (logits_t, cache)."""
        if self.embed is not None:
            x_t = self.embed(params["embed"], inputs_t)
        else:
            x_t = inputs_t.astype(self.cfg.activation_dtype)

        if self.cfg.scan_layers and self.n_cycles > 0:
            def body(x_t, xs):
                layer_params, layer_cache = xs
                new_caches = []
                for j, blk in enumerate(self.blocks):
                    x_t, c = blk.decode_step(layer_params[j], x_t, layer_cache[j])
                    new_caches.append(c)
                return x_t, tuple(new_caches)

            x_t, new_stacks = jax.lax.scan(
                body, x_t, (tuple(params["layers"]), tuple(cache["layers"])))
            new_cache = {"layers": list(new_stacks)}
        else:
            # Cycle-major to match __call__ (block-major would run a
            # different network for multi-block patterns with n_cycles > 1).
            stack_c = [[] for _ in self.blocks]
            for i in range(self.n_cycles):
                for j, blk in enumerate(self.blocks):
                    pj = jax.tree_util.tree_map(lambda a: a[i], params["layers"][j])
                    cj = jax.tree_util.tree_map(lambda a: a[i], cache["layers"][j])
                    x_t, c = blk.decode_step(pj, x_t, cj)
                    stack_c[j].append(c)
            new_cache = {"layers": [
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cs)
                for cs in stack_c]}
        if self.n_rem:
            new_rem = []
            for j, blk in enumerate(self.rem_blocks):
                x_t, c = blk.decode_step(params["rem"][j], x_t, cache["rem"][j])
                new_rem.append(c)
            new_cache["rem"] = new_rem

        x_t = self.final_norm(params["final_norm"], x_t[:, None])[:, 0]
        if self.head is not None:
            logits = self.head(params["head"], x_t)
        else:
            logits = self.embed.attend(params["embed"], x_t)
        return logits, new_cache
