"""ShiftAddViT — the paper's own model family, used for the faithful
reproduction experiments (sensitivity Tab. 2, MoE routing Fig. 6, LL-loss
Tab. 7) on synthetic image-classification tasks.

A compact PVT/DeiT-style encoder: patchify (linear on flattened patches) →
bidirectional transformer blocks whose attention / projections / MLPs follow
the ShiftAddPolicy (exactly the paper's reparameterization surface) → mean
pool → classifier head. `convert_from` implements the paper's two-stage
reparameterization from a pretrained dense ViT's params.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import reparam
from repro.core.dense import Dense
from repro.core.policy import ShiftAddPolicy
from repro.configs.base import ModelConfig
from repro.nn.blocks import TransformerBlock


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image_size: int = 32
    patch_size: int = 4
    in_channels: int = 3
    n_classes: int = 10
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    policy: ShiftAddPolicy = ShiftAddPolicy()
    dtype: str = "float32"
    moe_capacity: float = 1.25

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    def model_config(self) -> ModelConfig:
        return ModelConfig(
            name="shiftadd_vit", family="vit", n_layers=self.n_layers,
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_heads,
            d_ff=self.d_ff, vocab_size=self.n_classes, mlp_kind="mlp",
            causal=False, rope="none", norm="layernorm", use_bias=True,
            input_mode="embeddings", policy=self.policy, scan_layers=False,
            remat="none", dtype=self.dtype, param_dtype="float32",
            moe_primitives_capacity=self.moe_capacity,
            moe_capacity_ref_tokens=self.n_patches)


class ShiftAddViT:
    def __init__(self, cfg: ViTConfig):
        self.cfg = cfg
        mc = cfg.model_config()
        self.mc = mc
        dt = mc.activation_dtype
        patch_dim = cfg.patch_size ** 2 * cfg.in_channels
        self.patch_embed = Dense(patch_dim, cfg.d_model, dtype=dt)
        self.blocks = [TransformerBlock(mc, "attn") for _ in range(cfg.n_layers)]
        from repro.nn.layers import make_norm
        self.final_norm = make_norm("layernorm", cfg.d_model, 1e-6, dt, jnp.float32)
        self.head = Dense(cfg.d_model, cfg.n_classes, dtype=dt)

    def init(self, key):
        ks = jax.random.split(key, len(self.blocks) + 3)
        return {
            "patch_embed": self.patch_embed.init(ks[0]),
            "blocks": [b.init(ks[1 + i]) for i, b in enumerate(self.blocks)],
            "final_norm": self.final_norm.init(ks[-2]),
            "head": self.head.init(ks[-1]),
        }

    def patchify(self, images):
        """(B, H, W, C) → (B, n_patches, patch_dim)."""
        c = self.cfg
        b, h, w, ch = images.shape
        p = c.patch_size
        x = images.reshape(b, h // p, p, w // p, p, ch)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, (h // p) * (w // p), p * p * ch)
        return x

    def __call__(self, params, images, train=True):
        """images: (B, H, W, C) → (logits (B, n_classes), aux)."""
        x = self.patch_embed(params["patch_embed"],
                             self.patchify(images).astype(self.mc.activation_dtype))
        bal = jnp.float32(0.0)
        drop = jnp.float32(0.0)
        aux_all = []
        for blk, p in zip(self.blocks, params["blocks"]):
            x, aux = blk(p, x, positions=None, train=train)
            bal += aux["balance_loss"]
            drop += aux["drop_fraction"]
            aux_all.append(aux)
        x = self.final_norm(params["final_norm"], x)
        logits = self.head(params["head"], jnp.mean(x, axis=1))
        n = max(len(self.blocks), 1)
        return logits, {"balance_loss": bal / n, "drop_fraction": drop / n}

    def prepare_inference(self, params, impl=None, token_counts=(),
                          tune=None):
        """Deployment freeze (core.deploy): decode/pack every shift weight
        once and warm MoE capacity plans. Returns a DeployPlan whose `params`
        feed `infer` with exact logit parity — the serving engine closes its
        jitted forward over them. `tune` (a kernels.autotune.TuneTable) is
        recorded on the plan and must be threaded to `infer` alongside the
        frozen params."""
        from repro.core.deploy import prepare_inference
        return prepare_inference(self, params, impl=impl,
                                 token_counts=token_counts, tune=tune)

    def infer(self, params, images, impl=None, tune=None):
        """Inference fast path: images (B, H, W, C) → logits (B, n_classes).

        The serving forward (repro.serve.vision jits this): no aux-loss
        computation, binary-linear attention through the fused bidirectional
        op, MoE feeds through the deterministic gather dispatch on
        clean-logit argmax with capacity planned per image row — no rng
        anywhere, so two calls on the same batch return identical logits.
        Pass a DeployPlan's frozen params (see `prepare_inference`) to also
        hoist every shift-weight decode out of the compiled program; logits
        are bit-identical either way.

        Batch-invariance contract (ISSUE 5): a given image's logits are
        bit-identical no matter what it is batched with, in which row, at
        which bucket padding, on how many replicas. Every reduction in the
        forward is within-row (attention/MLP/norms reduce over tokens or
        channels of one image; the MoE capacity domain is one row), and the
        classifier head below is written as an explicit broadcast-multiply
        + within-row reduce rather than a (B, d)·(d, k) dot: XLA CPU picks
        a different gemm/gemv strategy for tiny-M matmuls as M crosses ~1,
        which was the one op whose row values depended on the batch size.
        """
        x = self.patch_embed(params["patch_embed"],
                             self.patchify(images).astype(self.mc.activation_dtype))
        for blk, p in zip(self.blocks, params["blocks"]):
            x = blk.infer(p, x, positions=None, impl=impl, tune=tune)
        x = self.final_norm(params["final_norm"], x)
        pooled = jnp.mean(x, axis=1)                       # (B, d)
        w = params["head"]["kernel"].astype(pooled.dtype)
        logits = jnp.sum(pooled[:, :, None] * w[None], axis=1)
        if "bias" in params["head"]:
            logits = logits + params["head"]["bias"].astype(pooled.dtype)
        return logits

    def loss(self, params, batch, train=True):
        logits, aux = self(params, batch["images"], train=train)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        ce = -jnp.mean(jnp.take_along_axis(logp, batch["labels"][:, None], 1))
        lam = self.mc.policy.balance_loss_weight
        total = ce + lam * aux["balance_loss"]
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return total, {"ce": ce, "acc": acc, "balance_loss": aux["balance_loss"],
                       "loss": total}

    # -- the paper's two-stage conversion ------------------------------------
    def convert_from(self, dense_model: "ShiftAddViT", dense_params, stage=2):
        """Reparameterize a pretrained dense ViT into this policy's structure.

        stage 0: structural copy only (the dense arm of a policy sweep).
        stage 1: attention → (binary-)linear (+ shift projections if policy
                 says so); MLPs untouched.
        stage 2: + MLPs → shift or MoE-of-primitives (Mult expert = pretrained
                 MLP, Shift expert = its po2 projection).
        """
        assert dense_model.cfg.n_layers == self.cfg.n_layers
        p = self.cfg.policy
        out = jax.tree_util.tree_map(lambda x: x, dense_params)  # copy
        if stage < 1:
            return out
        for i, blk in enumerate(self.blocks):
            src = dense_params["blocks"][i]
            dst = dict(src)
            mixer = dict(src["mixer"])
            if p.projections == "shift":
                for name in ("q", "k", "v", "o"):
                    mixer[name] = reparam.dense_to_shift(mixer[name])
            if p.attention in ("linear", "binary_linear") and p.dwconv_v:
                # New parameter introduced by the reparam: zero-init so the
                # converted model starts as the pure linear-attention of the
                # pretrained weights (the DWConv grows in during finetuning).
                key = jax.random.PRNGKey(1000 + i)
                fresh = blk.mixer.dwconv.init(key)
                mixer["dwconv"] = jax.tree_util.tree_map(jnp.zeros_like, fresh)
            dst["mixer"] = mixer
            if stage >= 2:
                if p.mlp == "shift":
                    dst["feed"] = {
                        "up": reparam.dense_to_shift(src["feed"]["up"]),
                        "down": reparam.dense_to_shift(src["feed"]["down"]),
                    }
                elif p.mlp == "moe_primitives":
                    dst["feed"] = reparam.dense_mlp_to_moe(
                        src["feed"], p.moe_experts)
            out["blocks"][i] = dst
        return out
