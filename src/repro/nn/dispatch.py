"""Grouped capacity dispatch for MoE layers (GShard-style groups, sort-based).

Why groups: routing over the *global* token axis (argsort/cumsum/scatter over
~1M tokens) forces GSPMD to replicate dispatch buffers on every device — the
69 GiB/device failure mode. Tokens are instead split into G groups that shard
over the (pod, data) mesh axes; every dispatch op is per-group, so routing
stays device-local and the only cross-device movement is the expert-parallel
reshard of the (G, E, cap, d) buffer on the model axis (the classic MoE
all-to-all, inserted by GSPMD at the sharding constraint).

Capacity is per-group (cap_e per expert per group) — statistically equivalent
to global capacity for iid token order, and the paper's latency-aware
capacities translate per group unchanged. Supports heterogeneous per-expert
capacities (the MoE-of-primitives needs them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def choose_groups(tokens: int, target_group=4096, min_groups=32) -> int:
    """Number of routing groups: ≥ min_groups when possible (so groups shard
    over pod×data), each ≥64 tokens (smaller groups degrade routing quality
    more than replication costs at that size), and G | tokens."""
    if tokens % target_group == 0 and tokens // target_group >= min_groups:
        return tokens // target_group
    for size in (2048, 1024, 512, 256, 128, 64):
        if tokens % size == 0 and tokens // size >= min_groups:
            return tokens // size
    if tokens % min_groups == 0 and tokens // min_groups >= 64:
        return min_groups
    return 1


def dispatch(xg, expert_idx, keep_gate, caps, stats=True):
    """Per-group sort-based dispatch, vmapped over the leading group axis.

    xg: (G, S, d); expert_idx: (G, S, k); keep_gate: (G, S, k) combine weights.
    caps: python list of per-expert capacities (static).
    Returns (buf (G, total, d), aux) where total = sum(caps); expert e owns
    rows [offset_e, offset_e + cap_e). aux carries what combine() needs.

    stats=False is the inference path: aux carries only what combine() needs,
    no tokens_per_expert / drop_fraction bookkeeping (the serving engine never
    reads them, and leaving them out keeps the compiled program free of the
    cross-group reductions).
    """
    n_exp = len(caps)
    offsets = [0]
    for c in caps:
        offsets.append(offsets[-1] + c)
    total = offsets[-1]
    caps_arr = jnp.asarray(caps, jnp.int32)
    offs_arr = jnp.asarray(offsets[:-1], jnp.int32)

    def one(x, idx, gate):
        s, k = idx.shape
        flat_e = idx.reshape(s * k)
        flat_g = gate.reshape(s * k)
        flat_t = jnp.repeat(jnp.arange(s), k)
        counts = jnp.bincount(flat_e, length=n_exp)
        starts = jnp.cumsum(counts) - counts
        order = jnp.argsort(flat_e, stable=True)      # token-order priority
        e_sorted = flat_e[order]
        pos = jnp.arange(s * k) - starts[e_sorted]
        keep = pos < caps_arr[e_sorted]
        slot = jnp.where(keep, offs_arr[e_sorted] + pos, total)
        tok = flat_t[order]
        gathered = x[tok] * keep[:, None].astype(x.dtype)
        buf = jnp.zeros((total + 1, x.shape[-1]), x.dtype).at[slot].set(gathered)
        w = flat_g[order] * keep.astype(flat_g.dtype)
        return buf[:-1], slot, tok, w, counts, keep

    buf, slot, tok, w, counts, keep = jax.vmap(one)(xg, expert_idx, keep_gate)
    aux = {"slot": slot, "tok": tok, "w": w, "total": total}
    if stats:
        aux["tokens_per_expert"] = jnp.sum(counts, axis=0)
        aux["drop_fraction"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return buf, aux


def combine(expert_out_flat, aux, s, d):
    """expert_out_flat: (G, total, d) expert outputs in slot order → (G, S, d)."""
    total = aux["total"]

    def one(out_flat, slot, tok, w):
        y_sorted = out_flat[jnp.minimum(slot, total - 1)]
        contrib = y_sorted * w[:, None].astype(y_sorted.dtype)
        return jnp.zeros((s, d), out_flat.dtype).at[tok].add(contrib)

    return jax.vmap(one)(expert_out_flat, aux["slot"], aux["tok"], aux["w"])


# ---------------------------------------------------------------------------
# Inference dispatch: gather-ordered segment buffer (ISSUE 3 tentpole, part 3)
# ---------------------------------------------------------------------------
#
# The training dispatch above scatters tokens into a zeroed buffer
# (`zeros().at[slot].set`) and the combine scatter-adds back — correct under
# vmap/grad, but at serve time (top-1, no drop statistics) both scatters are
# avoidable: the buffer can be built by a GATHER from the token array (rows
# in expert-segment order), experts run on per-expert static views of it,
# and each token's output is a gather from its expert's segment. No
# scatter-into-zeros, no concatenate of expert outputs.

def dispatch_infer(xg, expert_idx, gate, caps):
    """Top-1 inference dispatch. xg: (G, S, d); expert_idx: (G, S) int;
    gate: (G, S) combine weights; caps: python list of static capacities.

    Returns (buf (G, total, d), info). Expert e owns rows
    [offset_e, offset_e + cap_e) of buf; rows are filled by gathering the
    tokens routed to e in token order (priority identical to `dispatch`),
    zero beyond the expert's live count. info carries what `combine_infer`
    needs: each token's within-expert rank (pos), its keep flag, its expert
    and its gate.

    All row movement is a single FLAT gather from the (G·S, d) token array —
    a vmapped per-group gather lowers to a batched gather that CPU/older-TPU
    XLA executes as a scalar loop, which is exactly the dispatch tax this
    path exists to remove.
    """
    g, s, d = xg.shape
    n_exp = len(caps)
    offsets = [0]
    for c in caps:
        offsets.append(offsets[-1] + c)
    total = offsets[-1]
    caps_arr = jnp.asarray(caps, jnp.int32)
    offs_arr = jnp.asarray(offsets[:-1], jnp.int32)
    # Static row → expert map of the segment buffer.
    row_e = jnp.asarray(
        [e for e, c in enumerate(caps) for _ in range(c)], jnp.int32)

    onehot = (expert_idx[..., None] == jnp.arange(n_exp)).astype(jnp.int32)
    counts = jnp.sum(onehot, axis=1)                           # (G, E)
    starts = jnp.cumsum(counts, axis=-1) - counts              # (G, E)
    # Token-order rank of each token within its expert (same priority rule
    # as the sort-based dispatch: earlier tokens win capacity ties).
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - onehot,
                              expert_idx[..., None], axis=2)[..., 0]  # (G,S)
    keep = pos < caps_arr[expert_idx]
    # Buffer row r of expert e, local slot l = r − offset_e, holds the l-th
    # token routed to e: sorted-order index starts[e] + l.
    order = jnp.argsort(expert_idx, axis=-1, stable=True)      # (G, S)
    local = jnp.arange(total) - offs_arr[row_e]                # (total,)
    src_sorted = jnp.clip(starts[:, row_e] + local[None], 0, s - 1)
    src = jnp.take_along_axis(order, src_sorted, axis=-1)      # (G, total)
    flat_src = (src + jnp.arange(g, dtype=src.dtype)[:, None] * s).reshape(-1)
    buf = xg.reshape(g * s, d)[flat_src].reshape(g, total, d)
    # Rows past an expert's live token count hold clipped duplicates of real
    # tokens rather than zeros — deliberately unmasked: combine_infer reads
    # only rows [starts_e, starts_e + min(count_e, cap_e)) back, so zeroing
    # the dead rows would be a (G, total, d) elementwise op spent on values
    # nothing consumes. (The training `dispatch` zero-fills because its
    # scatter-add combine touches every buffer row.)
    info = {"expert": expert_idx, "pos": pos, "keep": keep, "gate": gate,
            "caps": tuple(caps)}
    return buf, info


def combine_infer(expert_outs, info):
    """expert_outs: list of (G, cap_e, d) per-expert outputs in segment order
    → (G, S, d). Pure gathers: each token reads row `pos` of its expert's
    segment (top-1 ⇒ exactly one contribution), scaled by gate·keep. Flat
    single-gather per expert, same rationale as dispatch_infer."""
    expert, pos, keep, gate = (info["expert"], info["pos"], info["keep"],
                               info["gate"])
    g, s = expert.shape
    y = None
    for e, out_e in enumerate(expert_outs):
        cap_e = out_e.shape[1]
        sel = jnp.clip(pos, 0, cap_e - 1)
        flat = (sel + jnp.arange(g, dtype=sel.dtype)[:, None] * cap_e).reshape(-1)
        got = out_e.reshape(g * cap_e, -1)[flat].reshape(g, s, -1)
        got = jnp.where((expert == e)[..., None], got, 0.0)
        y = got if y is None else y + got
    w = (gate * keep.astype(gate.dtype)).astype(y.dtype)
    return y * w[..., None]


def group_tokens(x, d_model, target_group=4096, min_groups=32):
    """(..., d) → (G, S, d) plus an ungroup closure.

    TRAINING grouping: the token axis is flattened across batch rows and cut
    into G size-balanced groups (see module docstring for why). Group
    boundaries therefore ignore image/sequence boundaries — a token's
    capacity competitors are whatever the flattening put next to it, which
    is statistically fine for training but makes an image's routing depend
    on its co-batched neighbors. Serving uses `group_rows` instead."""
    lead = x.shape[:-1]
    tokens = 1
    for s in lead:
        tokens *= int(s)
    g = choose_groups(tokens, target_group, min_groups)
    xg = x.reshape(g, tokens // g, d_model)
    xg = constrain(xg, ("batch", None, None))

    def ungroup(y):
        return y.reshape(*lead, d_model)

    return xg, ungroup


def group_rows(x, d_model):
    """(..., S, d) → (G, S, d) with ONE routing group per batch row, plus an
    ungroup closure — the SERVING grouping (ISSUE 5 tentpole).

    Each image (batch row) is its own capacity domain: expert capacities are
    planned from the per-row token count and every dispatch op is vmapped
    over rows, so a row's routing reads nothing but that row's tokens. This
    is the batch-invariance contract the shiftadd serving path asserts:
    per-image logits are bit-identical across batch composition, row order,
    bucket padding and replica count. Tokens-per-row is static per engine
    bucket, so shapes (and the memoized capacity plan) stay jit-stable.

    A 2-D input (S, d) is treated as a single row. Rows shard over the
    mesh's batch axes exactly like the flattened grouping did — per-row
    dispatch is device-local under the `batch → data` rule."""
    if x.ndim == 2:
        xg = x[None]
    else:
        lead = x.shape[:-2]
        rows = 1
        for s in lead:
            rows *= int(s)
        xg = x.reshape(rows, x.shape[-2], d_model)
    xg = constrain(xg, ("batch", None, None))

    def ungroup(y):
        return y.reshape(*x.shape[:-1], d_model)

    return xg, ungroup
