"""repro — ShiftAddViT (NeurIPS 2023) as a production multi-pod JAX framework.

The paper's contribution (mixture of multiplication primitives: binary-add
attention, power-of-two shift linears, heterogeneous mult/shift MoE with a
latency-aware load-balancing loss) lives in :mod:`repro.core` and is plumbed
through the model substrate in :mod:`repro.nn` via ``ShiftAddPolicy``.
"""

__version__ = "0.1.0"
