"""Serving-path benchmark: parallel chunked prefill vs token-by-token cache
warmup, and scan-fused decode throughput. Writes BENCH_serve.json so later
PRs have a trajectory for the serving hot path.

    PYTHONPATH=src python benchmarks/bench_serve.py [--prompt-len 512]

The headline number is `prefill_speedup`: how much faster one chunked
full-prompt pass fills the decode cache than P sequential `decode_step`
dispatches (the pre-refactor warmup path). On the CPU `xla` impl the win is
dominated by dispatch-count (P jitted calls → 1) and the O(P) chunked scan;
on TPU the same structure feeds the fused Pallas kernel.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import STAGE1
from repro.kernels import ops
from repro.nn.model import LanguageModel
from repro.serve.decode import make_decode_loop, make_prefill, make_serve_step
from repro.serve.metrics import gate_percentile, latency_summary


def _model(policy, vocab=512):
    cfg = ModelConfig(name="bench-serve", family="dense", policy=policy,
                      n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                      d_ff=512, vocab_size=vocab, dtype="float32",
                      scan_layers=True, remat="none")
    model = LanguageModel(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def bench(prompt_len=512, batch=4, new_tokens=64, iters=3):
    model, params, cfg = _model(STAGE1)
    max_len = prompt_len + new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, cfg.vocab_size)

    # -- chunked parallel prefill (one fused pass) --------------------------
    # Per-iteration samples summarized by serve.metrics.latency_summary
    # (nearest-rank percentiles, n/method recorded) instead of ad-hoc means:
    # one GC pause or host hiccup used to shift the whole headline number.
    prefill = jax.jit(make_prefill(model))
    logits_all, cache = prefill(params, prompts,
                                model.init_cache(batch, max_len))  # compile
    jax.block_until_ready(logits_all)
    prefill_samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        logits_all, cache = prefill(params, prompts,
                                    model.init_cache(batch, max_len))
        jax.block_until_ready(logits_all)
        prefill_samples.append(time.perf_counter() - t0)
    prefill_lat = latency_summary(prefill_samples)

    # -- token-by-token warmup (the pre-refactor path) ----------------------
    step = jax.jit(make_serve_step(model))
    warm = model.init_cache(batch, max_len)
    lg, warm = step(params, prompts[:, 0], warm)   # compile
    jax.block_until_ready(lg)

    def warmup_loop():
        c = model.init_cache(batch, max_len)
        lg = None
        for t in range(prompt_len):
            lg, c = step(params, prompts[:, t], c)
        jax.block_until_ready(lg)

    t0 = time.perf_counter()
    warmup_loop()
    warmup_s = time.perf_counter() - t0

    # -- scan-fused decode --------------------------------------------------
    loop = jax.jit(make_decode_loop(model, 0.0))
    keys = jnp.zeros((new_tokens, 2), jnp.uint32)
    logits0 = logits_all[:, -1]
    toks, _ = loop(params, logits0, cache, keys)   # compile
    jax.block_until_ready(toks)
    decode_samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        toks, _ = loop(params, logits0, cache, keys)
        jax.block_until_ready(toks)
        decode_samples.append(time.perf_counter() - t0)
    decode_lat = latency_summary(decode_samples)

    # Stats are read at the percentile the sample count supports (p50 at
    # the CI iteration counts); the scalar *_s keys stay, now defined as
    # that gated percentile rather than a mean.
    gate_key = gate_percentile(iters)
    prefill_s = prefill_lat[gate_key]
    decode_s = decode_lat[gate_key]

    return {
        "impl": ops.default_impl(),
        "backend": jax.default_backend(),
        "arch": "bench-serve(4L,256d,stage1)",
        "batch": batch,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "gate_key": gate_key,
        "prefill_s": prefill_s,
        "prefill_latency": prefill_lat,
        "prefill_toks_per_s": batch * prompt_len / prefill_s,
        "token_by_token_warmup_s": warmup_s,
        "token_by_token_toks_per_s": batch * prompt_len / warmup_s,
        "prefill_speedup": warmup_s / prefill_s,
        "decode_s": decode_s,
        "decode_latency": decode_lat,
        "decode_toks_per_s": batch * new_tokens / decode_s,
    }


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: small prompt, CSV row contract.
        rec = bench(prompt_len=64, batch=2, new_tokens=8, iters=1)
        rows.append(("serve_prefill", rec["prefill_s"] * 1e6,
                     f"speedup_vs_warmup={rec['prefill_speedup']:.1f}"))
        rows.append(("serve_decode", rec["decode_s"] * 1e6,
                     f"toks_s={rec['decode_toks_per_s']:.0f}"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    rec = bench(args.prompt_len, args.batch, args.new_tokens)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"prefill   : {rec['prefill_toks_per_s']:>10.0f} tok/s "
          f"({rec['prefill_s'] * 1e3:.1f} ms for {args.batch}x{args.prompt_len})")
    print(f"warmup    : {rec['token_by_token_toks_per_s']:>10.0f} tok/s "
          f"(token-by-token, {rec['token_by_token_warmup_s'] * 1e3:.1f} ms)")
    print(f"speedup   : {rec['prefill_speedup']:>10.1f}x (chunked prefill vs warmup)")
    print(f"decode    : {rec['decode_toks_per_s']:>10.0f} tok/s (scan-fused)")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
