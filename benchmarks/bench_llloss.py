"""Paper Tab. 7: latency-aware load-balancing loss ablation.

Trains the MoE-of-primitives router with and without the LL-loss on the
synthetic image task, then reports the *modeled synchronization latency* of
the MoE layer: with parallel heterogeneous experts the layer takes
max_e(tokens_e · per_token_latency_e); the LL-loss should shift load toward
the fast expert and cut that max (the paper reports ~14.6% at iso-accuracy).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.policy import ShiftAddPolicy
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw
from repro.serve.telemetry import load_telemetry

TELEMETRY_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "TELEMETRY_experts.json")


def _expert_latencies(cfg):
    """(per-expert seconds, source label) for the α of this ablation.

    Measured serving telemetry when the repo-root table exists (fail-open,
    same loader as the router arm); otherwise the analytic model in the
    t=1 weight-bound regime — per-token cost at these demo dims (d=48,
    f=96: packed-int8 shift weights vs bf16 mult ⇒ ~1.9:1), the regime the
    paper's Tab. 7 operates in. The old hardcoded [2.0e-5, 1.0e-5] froze
    that ratio as magic numbers, silently diverging from both sources.
    """
    kinds = cfg.policy.moe_experts
    telem = load_telemetry(TELEMETRY_PATH)
    if telem is not None:
        try:
            return telem.expert_latencies(kinds), f"telemetry:{telem.mode}"
        except (KeyError, ValueError):
            pass        # table from a different expert mix — fall through
    return energy.expert_latencies(1, cfg.d_model, cfg.d_ff,
                                   kinds), "analytic"


def _run(latency_aware, balance_weight, steps=150):
    policy = ShiftAddPolicy(mlp="moe_primitives", latency_aware=latency_aware,
                            balance_loss_weight=balance_weight)
    cfg = ViTConfig(image_size=16, patch_size=4, n_classes=4, n_layers=2,
                    d_model=48, n_heads=2, d_ff=96, policy=policy,
                    moe_capacity=4.0)
    model = ShiftAddViT(cfg)
    lat_values, lat_src = _expert_latencies(cfg)
    for blk in model.blocks:
        blk.feed.latencies = lat_values
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32,
                              seed=3)
    opt = adamw(3e-3, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, m

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()
                 if k != "object_yx"}
        params, state, m = step(params, state, batch)

    # measure load split + accuracy on held-out batches
    moe = model.blocks[0].feed
    lat = np.asarray(moe.latencies)
    sync, accs, splits = [], [], []
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(5000 + i).items()
                 if k != "object_yx"}
        _, m = model.loss(params, batch, train=False)
        accs.append(float(m["acc"]))
        _, aux = moe(params["blocks"][0]["feed"],
                     model.patch_embed(params["patch_embed"],
                                       model.patchify(batch["images"])),
                     train=False)
        tokens = np.asarray(aux["tokens_per_expert"], np.float64)
        splits.append(tokens)
        sync.append(np.max(tokens * lat))   # parallel experts: max finish time
    return (float(np.mean(accs)), float(np.mean(sync)),
            np.mean(splits, axis=0).round(1).tolist(), lat_src)


def main(rows=None):
    own = rows is None
    rows = [] if own else rows
    # Baseline = the paper's "previous solutions": homogeneous experts,
    # treated equally (uniform-α balance loss); LL arm = latency-aware α.
    acc_no, sync_no, split_no, src = _run(latency_aware=False,
                                          balance_weight=0.01)
    acc_ll, sync_ll, split_ll, src = _run(latency_aware=True,
                                          balance_weight=0.01)
    rows.append(("llloss_without", 0.0,
                 f"acc={acc_no:.3f};norm_latency=100%;split={split_no};"
                 f"lat_src={src}"))
    rows.append(("llloss_with", 0.0,
                 f"acc={acc_ll:.3f};norm_latency={sync_ll / sync_no:.1%};"
                 f"split={split_ll};lat_src={src}"))
    if own:
        for r in rows:
            print(",".join(str(c) for c in r))
    return rows


if __name__ == "__main__":
    main()
