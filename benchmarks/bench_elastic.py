"""Elastic-serving benchmark: the diurnal autoscaling + failure-injection +
graceful-degradation scenario. Writes BENCH_elastic.json.

    PYTHONPATH=src python benchmarks/bench_elastic.py [--requests 220]
    PYTHONPATH=src python benchmarks/bench_elastic.py --no-faults

One seeded diurnal trace, deliberately calibrated ABOVE a fixed
min-replica pool: offered load is --utilization (> 1) of the min-replica
capacity and the sinusoidal peak multiplies it by RAMP_HI on top. Two arms
share the same warmed pools, trace, and virtual clock:

- baseline: a FIXED pool of min-replicas (no autoscaler, no degradation,
  no faults) — it must MISS deadlines at the peak (recorded miss rate > 0,
  or the scenario proves nothing).
- elastic: the control plane (serve.elastic) scales between min and max
  replicas from the warm pool, sheds saturated-pool load to the shiftadd
  degrade arm per deadline class, and survives an injected replica kill
  plus an injected straggler (slowdown → monitor eviction → warm-pool
  backfill) at chosen virtual times — with ZERO deadline misses and ZERO
  recompiles (the warm-pool trace_count invariant spans every scale and
  recovery event).

A replay from a reset control plane must reproduce the full elastic
signature (routing incl. arm, scale timeline, fault firings, degradation
decisions) and every logit bit for bit. benchmarks/check_elastic.py gates
all of it, and additionally that the scenario actually exercised the
machinery (scale-ups happened, the kill fired, requests degraded).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.nn.vit import ViTConfig
from repro.serve.elastic import elastic_sweep
from repro.serve.traffic import SCENARIOS


def run(scenario="diurnal", requests=220, seed=0, min_replicas=1,
        max_replicas=2, spares=2, utilization=1.15, image_size=56, layers=4,
        d_model=128, impl=None, tune=None, kill_at_frac=0.35,
        slowdown_at_frac=0.6, slowdown_factor=4.0, verify_replay=True,
        buckets=None):
    cfg = ViTConfig(image_size=image_size, n_layers=layers, d_model=d_model,
                    d_ff=2 * d_model)
    return elastic_sweep(
        cfg, scenario=scenario, n_requests=requests, seed=seed,
        min_replicas=min_replicas, max_replicas=max_replicas, spares=spares,
        utilization=utilization, impl=impl, tune=tune, buckets=buckets,
        kill_at_frac=kill_at_frac, slowdown_at_frac=slowdown_at_frac,
        slowdown_factor=slowdown_factor, verify_replay=verify_replay)


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: tiny geometry, CSV row contract.
        rec = run(requests=60, image_size=16, layers=2, d_model=32,
                  buckets=(1, 2, 4), verify_replay=False)
        for arm in ("baseline", "elastic"):
            r = rec[arm]
            rows.append((f"elastic_{arm}_p99", r["latency"]["p99_s"] * 1e6,
                         f"miss={r['deadline_miss_rate']:.3f}"))
        rows.append(("elastic_replica_seconds",
                     rec["elastic"]["replica_seconds"] * 1e6,
                     f"max_active={rec['elastic']['max_active']}"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="diurnal", choices=SCENARIOS)
    ap.add_argument("--requests", type=int, default=220)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=2)
    ap.add_argument("--spares", type=int, default=2,
                    help="extra pre-warmed engines beyond max-replicas "
                         "(failure-recovery headroom; every spare is "
                         "compiled at warmup, attach never traces)")
    ap.add_argument("--utilization", type=float, default=1.15,
                    help="offered load as a fraction of the MIN-replica "
                         "capacity — above 1 so the fixed baseline "
                         "saturates at the diurnal peak")
    ap.add_argument("--image-size", type=int, default=56)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None)
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json")
    ap.add_argument("--kill-at", type=float, default=0.35, metavar="FRAC",
                    help="inject a replica kill at this fraction of the "
                         "trace horizon (virtual time)")
    ap.add_argument("--slowdown-at", type=float, default=0.6, metavar="FRAC",
                    help="inject a straggler (service-time multiplier) at "
                         "this fraction of the horizon")
    ap.add_argument("--slowdown-factor", type=float, default=4.0)
    ap.add_argument("--no-faults", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_elastic.json")
    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            print(f"WARNING: could not load tune table {args.tune}; "
                  f"serving with default block caps")

    rec = run(scenario=args.scenario, requests=args.requests, seed=args.seed,
              min_replicas=args.min_replicas, max_replicas=args.max_replicas,
              spares=args.spares, utilization=args.utilization,
              image_size=args.image_size, layers=args.layers,
              d_model=args.d_model, impl=args.impl, tune=tune,
              kill_at_frac=None if args.no_faults else args.kill_at,
              slowdown_at_frac=None if args.no_faults else args.slowdown_at,
              slowdown_factor=args.slowdown_factor)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    for arm in ("baseline", "elastic"):
        r = rec[arm]
        lat = r["latency"]
        print(f"{arm:>9}: p50 {lat['p50_s'] * 1e3:7.1f} ms  "
              f"p99 {lat['p99_s'] * 1e3:7.1f} ms  "
              f"miss {r['deadline_miss_rate']:.3f}  "
              f"shed {r['shed_requests']}  "
              f"recompiles {r['recompiles_after_warmup']}")
    e = rec["elastic"]
    print(f"  elastic: ups {e['scale_ups']}  downs {e['scale_downs']}  "
          f"kills {e['kills']}  straggler_evictions "
          f"{e['straggler_evictions']}  recoveries {e['recoveries']}  "
          f"degraded {e['degraded_requests']} {e['degraded_by_class']}  "
          f"max_active {e['max_active']}  "
          f"replica_s {e['replica_seconds']:.1f}")
    if "replay_identical_events" in rec:
        print(f"  replay: events={rec['replay_identical_events']} "
              f"logits={rec['replay_bit_identical_logits']}")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
