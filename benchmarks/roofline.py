"""§Roofline: three-term roofline per (arch × shape × mesh) from the dry-run
artifacts (launch/dryrun.py writes one JSON per cell).

    t_compute    = HLO_FLOPs_per_device / peak            (bf16 MXU)
    t_memory     = HLO_bytes_per_device / HBM_bw
    t_collective = collective_bytes_per_device / link_bw

All inputs are per-device (post-SPMD HLO), trip-count-corrected by
launch.hlo_analysis. Dominant term = bottleneck. MODEL_FLOPS ratio =
(6·N·D or 2·N·D) / (HLO_FLOPs × devices) — how much compiled compute is
"useful". Roofline fraction = t_compute / max(all terms): the fraction of
the cell's time the MXU would be busy if terms overlapped perfectly.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.energy import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def analytic_bytes_per_device(rec):
    """Napkin HBM-traffic model (per device, per step) — the TPU-lowering
    counterpart of the CPU-HLO write-once estimate (which is inflated by f32
    upcasts and scan-stacked backward buffers; see EXPERIMENTS.md §Roofline).

    Terms: optimizer state traffic (train), weight reads per microbatch×layer
    (fwd [+ bwd ×2]), activation traffic (~A materialized tensors of
    tokens×d_model per layer), logits, KV-cache/state traffic (decode).
    Attention scores are assumed VMEM-resident (flash-style chunking).
    """
    cfg = get_config(rec["arch"])
    dev = rec["n_devices"]
    model_par = 16
    p_dev = rec["n_params"] / dev                 # fully sharded (data×model)
    p_model_shard = rec["n_params"] / model_par   # after data all-gather
    active_frac = rec["n_params_active"] / max(rec["n_params"], 1)
    kind = rec["kind"]
    b, n = rec["global_batch"], rec["seq_len"]

    if kind == "train":
        n_micro = 16
        tokens_dev_micro = b * n / (dev / model_par) / n_micro
        opt = p_dev * 24.0                        # f32 p/m/v read+write
        weights = n_micro * p_model_shard * active_frac * 2.0 * 3.0  # fwd+bwd
        acts = n_micro * cfg.n_layers * tokens_dev_micro * cfg.d_model * 2.0 * 30.0
        logits = n_micro * tokens_dev_micro * (cfg.vocab_size / model_par) * 4.0 * 3.0
        return opt + weights + acts + logits
    if kind == "prefill":
        tokens_dev = b * n / (dev / model_par)
        weights = p_model_shard * active_frac * 2.0
        acts = cfg.n_layers * tokens_dev * cfg.d_model * 2.0 * 10.0
        logits = tokens_dev * (cfg.vocab_size / model_par) * 2.0
        return weights + acts + logits
    # decode: weights once per token + cache traffic
    weights = p_model_shard * active_frac * 2.0
    cache = rec["memory"]["argument_bytes"]       # per-device cache+params
    return weights + cache


def terms(rec):
    t_c = rec["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    t_m_hlo = rec["hlo_bytes_per_device"] / HBM_BW
    t_m_ana = analytic_bytes_per_device(rec) / HBM_BW
    t_m_xla = rec.get("xla_cost", {}).get("bytes accessed", 0.0) / HBM_BW
    # Ring-cost-aware wire bytes: all-reduce moves 2·(n-1)/n · operand bytes,
    # all-gather / reduce-scatter / all-to-all move (n-1)/n — double AR so
    # reduce-scatter-based strategies get fair credit.
    bd = rec.get("collective_breakdown", {})
    coll = sum(bd.values()) + bd.get("all-reduce", 0.0)
    if not bd:
        coll = rec["collective_bytes_per_device"]
    t_x = coll / ICI_BW
    t_m = t_m_ana                                  # dominant-call uses analytic
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    bound = max(t_c, t_m, t_x)
    useful = rec["model_flops_global"] / max(
        rec["hlo_flops_per_device"] * rec["n_devices"], 1.0)
    return {
        "t_compute": t_c, "t_memory": t_m, "t_memory_hlo": t_m_hlo,
        "t_memory_xla": t_m_xla, "t_collective": t_x,
        "dominant": dominant,
        "roofline_fraction": t_c / bound if bound else 0.0,
        "model_flops_ratio": useful,
    }


def load(artifact_dir=None, pattern="*.json"):
    recs = []
    for p in sorted(glob.glob(os.path.join(artifact_dir or ARTIFACT_DIR, pattern))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def report(artifact_dir=None, csv=True):
    rows = []
    for rec in load(artifact_dir):
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "policy": rec["policy"],
                         "skipped": rec["reason"]})
            continue
        t = terms(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "policy": rec["policy"],
            "t_compute_s": t["t_compute"], "t_memory_s": t["t_memory"],
            "t_memory_hlo_s": t["t_memory_hlo"],
            "t_collective_s": t["t_collective"], "dominant": t["dominant"],
            "roofline_fraction": t["roofline_fraction"],
            "model_flops_ratio": t["model_flops_ratio"],
            "temp_GiB": rec["memory"]["temp_bytes"] / 2**30,
            "args_GiB": rec["memory"]["argument_bytes"] / 2**30,
        })
    if csv:
        cols = ["arch", "shape", "mesh", "policy", "t_compute_s", "t_memory_s",
                "t_memory_hlo_s", "t_collective_s", "dominant",
                "roofline_fraction", "model_flops_ratio", "temp_GiB",
                "args_GiB", "skipped"]
        print(",".join(cols))
        for r in rows:
            print(",".join(
                f"{r.get(c):.5g}" if isinstance(r.get(c), float) else str(r.get(c, ""))
                for c in cols))
    return rows


def main():
    report(sys.argv[1] if len(sys.argv) > 1 else None)


if __name__ == "__main__":
    main()
