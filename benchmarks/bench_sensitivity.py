"""Paper Tab. 2 (sensitivity analysis): which components tolerate
reparameterization. Reduced-scale faithful reproduction: pretrain a dense ViT
on the synthetic object-classification task, apply each component
conversion, finetune briefly, report accuracy.

Expected ordering (the paper's finding, validated in EXPERIMENTS.md):
  attention reparam (LA+Add / Shift-proj) ≈ baseline;
  Shift on MLPs drops accuracy;
  MoE-of-primitives recovers it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ShiftAddPolicy, DENSE
from repro.data.pipeline import SyntheticImageData
from repro.nn.vit import ShiftAddViT, ViTConfig
from repro.optim.optimizer import adamw

VARIANTS = {
    "msa_dense": DENSE,
    "attn_la_add": ShiftAddPolicy(attention="binary_linear"),
    "attn_shift": ShiftAddPolicy(projections="shift"),
    "mlp_shift": ShiftAddPolicy(mlp="shift"),
    "mlp_moe": ShiftAddPolicy(mlp="moe_primitives"),
}

CFG = dict(image_size=16, patch_size=4, n_classes=4, n_layers=2, d_model=48,
           n_heads=2, d_ff=96)


def _train(model, params, data, steps, lr, seed_offset=0):
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, m

    for i in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch_at(seed_offset + i).items()
                 if k != "object_yx"}
        params, state, m = step(params, state, batch)
    return params


def _acc(model, params, data, n=8):
    accs = []
    for i in range(n):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(5000 + i).items()
                 if k != "object_yx"}
        _, m = model.loss(params, batch, train=False)
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


def main(rows=None, pretrain_steps=150, finetune_steps=60):
    own = rows is None
    rows = [] if own else rows
    data = SyntheticImageData(image_size=16, n_classes=4, global_batch=32,
                              seed=7)
    dense_cfg = ViTConfig(**CFG, policy=DENSE)
    dense = ShiftAddViT(dense_cfg)
    params = dense.init(jax.random.PRNGKey(0))
    params = _train(dense, params, data, pretrain_steps, 3e-3)
    base_acc = _acc(dense, params, data)
    rows.append(("sensitivity_msa_dense", 0.0, f"acc={base_acc:.3f}"))

    for name, policy in VARIANTS.items():
        if name == "msa_dense":
            continue
        cfg = ViTConfig(**CFG, policy=policy)
        model = ShiftAddViT(cfg)
        p = model.convert_from(dense, params, stage=2)
        p = _train(model, p, data, finetune_steps, 3e-4, seed_offset=300)
        acc = _acc(model, p, data)
        rows.append((f"sensitivity_{name}", 0.0,
                     f"acc={acc:.3f};delta={acc - base_acc:+.3f}"))
    if own:
        for r in rows:
            print(",".join(str(c) for c in r))
    return rows


if __name__ == "__main__":
    main()
