"""Paper Fig. 4/5 (+ App. A): MatShift / MatAdd kernel comparison.

On-target (TPU) the win is data movement; this container is CPU-only, so we
report (a) measured CPU wall time of the semantics-equivalent XLA paths as a
sanity harness, and (b) the *derived* roofline-model speedup on v5e from the
operand-byte reduction (packed int8 weights / binary operands vs bf16), which
is the quantity the paper's GPU numbers correspond to.

Shapes follow the paper's Fig. 4/5 convention: inputs (B, K, M) weights (K, N)
for MatShift; (B, H, K, M) x (B, H, K, N) for MatAdd, dims w.r.t. PVT sizes.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.energy import HBM_BW, PEAK_FLOPS_BF16, PEAK_OPS_INT8
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _roofline_time(flops, bytes_, int8=False):
    peak = PEAK_OPS_INT8 if int8 else PEAK_FLOPS_BF16
    return max(flops / peak, bytes_ / HBM_BW)


def bench_matshift(rows):
    # First three follow the paper's Fig. 4 PVT shapes (activation-dominated:
    # gains hide behind data movement exactly as the paper observes); the
    # last two are decode-regime weight-dominated shapes where the packed
    # int8 weights pay off directly.
    shapes = [(1, 512, 3136, 64), (1, 1024, 784, 128), (32, 512, 196, 320),
              (1, 4096, 64, 11008), (1, 8192, 16, 8192)]
    for b, k, m, n in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (b * m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
        wp = quant.pack_from_dense(w)
        wb = w.astype(jnp.bfloat16)
        t_dense = _time(jax.jit(lambda x, w: x @ w.astype(x.dtype)), x, wb)
        t_shift = _time(jax.jit(lambda x, wp: ops.shift_matmul(x, wp, "xla")), x, wp)
        flops = 2.0 * b * m * k * n
        bytes_dense = (b * m * k + k * n + b * m * n) * 2
        bytes_shift = b * m * k * 2 + k * n * 1 + b * m * n * 2
        derived = (_roofline_time(flops, bytes_dense)
                   / _roofline_time(flops, bytes_shift, int8=True))
        rows.append(("matshift_%dx%dx%dx%d" % (b, k, m, n), t_shift,
                     f"tpu_speedup_vs_dense={derived:.2f};cpu_dense_us={t_dense:.0f}"))


def bench_matadd_bitpacked(rows):
    """Beyond-paper: 1-bit packed binary operand (8× less than the paper's
    int8). Derived roofline gain shows where operand traffic dominates."""
    from repro.kernels.add_matmul_packed import pack_bits

    g, m, k, n = 8, 64, 4096, 4096        # decode-regime KV contraction
    b = (jax.random.randint(jax.random.PRNGKey(1), (g, k, n), 0, 2,
                            jnp.int8) * 2 - 1).astype(jnp.int8)
    packed = pack_bits(b)
    x = jax.random.normal(jax.random.PRNGKey(0), (g, m, k))
    t = _time(jax.jit(lambda x, p: ops.add_matmul_bitpacked(x, p, "xla")),
              x, packed, iters=2)
    flops = 2.0 * g * m * k * n
    bytes_int8 = g * (m * k * 2 + k * n * 1 + m * n * 2)
    bytes_bit = g * (m * k * 2 + k * n / 8 + m * n * 2)
    derived = (_roofline_time(flops, bytes_int8, int8=True)
               / _roofline_time(flops, bytes_bit, int8=True))
    rows.append((f"matadd_bitpacked_{g}x{m}x{k}x{n}", t,
                 f"tpu_speedup_vs_int8_operand={derived:.2f}"))


def bench_matadd(rows):
    shapes = [(1, 8, 64, 3136, 64), (1, 8, 64, 784, 784)]
    for b, h, k, m, n in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (b * h, m, k))
        bq = (jax.random.randint(jax.random.PRNGKey(1), (b * h, k, n), 0, 2,
                                 jnp.int8) * 2 - 1).astype(jnp.int8)
        bf = bq.astype(jnp.float32)
        t_dense = _time(jax.jit(lambda x, b: jnp.einsum("gmk,gkn->gmn", x, b)), x, bf)
        t_add = _time(jax.jit(lambda x, b: ops.add_matmul(x, b, "xla")), x, bq)
        flops = 2.0 * b * h * m * k * n
        bytes_dense = (b * h) * (m * k + k * n + m * n) * 2
        bytes_add = (b * h) * (m * k * 2 + k * n * 1 + m * n * 2)
        derived = (_roofline_time(flops, bytes_dense)
                   / _roofline_time(flops, bytes_add, int8=True))
        rows.append(("matadd_%dx%dx%dx%dx%d" % (b, h, k, m, n), t_add,
                     f"tpu_speedup_vs_dense={derived:.2f};cpu_dense_us={t_dense:.0f}"))


def main(rows=None):
    own = rows is None
    rows = [] if own else rows
    bench_matshift(rows)
    bench_matadd(rows)
    bench_matadd_bitpacked(rows)
    if own:
        for r in rows:
            print(",".join(str(c) for c in r))
    return rows


if __name__ == "__main__":
    main()
