"""Paper Tab. 3 / Fig. 3: energy comparison, analytic 45 nm model (Tab. 1
unit energies + Horowitz-style data movement — the ShiftAdd-ASIC view).

Reports per-model energy under each policy stage and the attention/MLP
breakdown (the paper's Fig. 3 structure: Add cuts MatMul energy ~94%, Shift
cuts Linear energy ~30-40%, end-to-end 19-43% savings).
"""
from __future__ import annotations

from repro.core import energy
from repro.configs.registry import get_config

# DeiT-T-like ViT (the paper's Tab. 3 row) + two assigned LM archs.
MODELS = {
    "deit_tiny_224": dict(n_layers=12, d_model=192, n_heads=3, d_ff=768,
                          tokens=197),
    "yi-9b@4k": None,
    "rwkv6-3b@4k": None,
}


def _vit_energy(spec, policy):
    L, d, h, f, n = (spec["n_layers"], spec["d_model"], spec["n_heads"],
                     spec["d_ff"], spec["tokens"])
    dh = d // h
    attn_mm = energy.OpEnergy(0, 0)
    attn_lin = energy.OpEnergy(0, 0)
    mlp = energy.OpEnergy(0, 0)
    for _ in range(L):
        # qkvo projections
        lin = (energy.shift_matmul_energy if policy in ("shift_attn", "full")
               else lambda m, k, nn: energy.matmul_energy(m, k, nn, "fp16"))
        for _ in range(4):
            attn_lin += lin(n, d, d)
        # attention contractions per head: (QK)V quadratic or Q(KV) linear+Add
        for _ in range(h):
            if policy in ("la_add", "shift_attn", "full", "moe"):
                attn_mm += energy.add_matmul_energy(dh, n, dh)   # KᵀV
                attn_mm += energy.add_matmul_energy(n, dh, dh)   # Q(KV)
            else:
                attn_mm += energy.matmul_energy(n, dh, n)        # QKᵀ
                attn_mm += energy.matmul_energy(n, n, dh)        # AV
        # MLP
        if policy == "full":
            mlp += energy.shift_matmul_energy(n, d, f)
            mlp += energy.shift_matmul_energy(n, f, d)
        elif policy == "moe":
            # latency-aware split ≈ 2/3 tokens to shift, 1/3 to mult
            mlp += energy.shift_matmul_energy(int(n * 2 / 3), d, f)
            mlp += energy.shift_matmul_energy(int(n * 2 / 3), f, d)
            mlp += energy.matmul_energy(n - int(n * 2 / 3), d, f, "fp16")
            mlp += energy.matmul_energy(n - int(n * 2 / 3), f, d, "fp16")
        else:
            mlp += energy.matmul_energy(n, d, f, "fp16")
            mlp += energy.matmul_energy(n, f, d, "fp16")
    return attn_mm, attn_lin, mlp


def main(rows=None):
    own = rows is None
    rows = [] if own else rows
    spec = MODELS["deit_tiny_224"]
    base = None
    for policy in ("dense", "la_add", "shift_attn", "full", "moe"):
        mm, lin, mlp = _vit_energy(spec, policy)
        total = (mm + lin + mlp).total_pj / 1e9  # mJ
        if base is None:
            base = total
        rows.append((f"energy_deit_t_{policy}", 0.0,
                     f"total_mJ={total:.3f};savings={1 - total / base:+.1%};"
                     f"attn_mJ={(mm + lin).total_pj / 1e9:.3f};"
                     f"mlp_mJ={mlp.total_pj / 1e9:.3f}"))
    # LM archs: per-4k-token forward energy. 1 MAC/param/token; weights read
    # once; dense fp16 (2 B/w) vs shift (shift+add compute, 1 B/w).
    for arch in ("yi-9b", "rwkv6-3b"):
        cfg = get_config(arch)
        toks = 4096
        n_p = cfg.param_count()
        macs = float(toks) * n_p
        dense_c = macs * (energy.MULT_PJ["fp16"] + energy.ADD_PJ["fp16"])
        shift_c = macs * (energy.SHIFT_PJ["int8"] + energy.ADD_PJ["int32"])
        dense_m = energy.DRAM_PJ_PER_BYTE * n_p * 2.0
        shift_m = energy.DRAM_PJ_PER_BYTE * n_p * 1.0
        rows.append((f"energy_{arch}_per4k", 0.0,
                     f"dense_J={(dense_c + dense_m) / 1e12:.2f};"
                     f"shiftadd_J={(shift_c + shift_m) / 1e12:.2f};"
                     f"savings={1 - (shift_c + shift_m) / (dense_c + dense_m):+.1%}"))
    if own:
        for r in rows:
            print(",".join(str(c) for c in r))
    return rows


if __name__ == "__main__":
    main()
