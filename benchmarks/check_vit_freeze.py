"""CI gate for the ShiftAddViT serving benchmarks (vit-serve job).

    python benchmarks/check_vit_freeze.py BENCH_vit.json BENCH_vit_freeze_ab.json

BENCH_vit.json is the headline frozen policy sweep (bench_vit.py default);
BENCH_vit_freeze_ab.json is the interleaved frozen-vs-live A/B
(bench_vit.py --ab-freeze — both arms timed in alternating rounds in one
process, so shared-runner load drift cancels instead of swamping the freeze
effect).

Fails (exit 1) if:
- any arm in either record recompiled after warmup, or
- the frozen shiftadd arm is slower than the live (unfrozen) arm beyond a
  small noise margin — a real regression (the per-forward po2 decode landing
  back in the hot loop) costs well over the margin, or
- the headline record's frozen shiftadd latency exceeds dense (the paper's
  crossover, the PR's acceptance criterion). The comparison runs at the
  percentile the sweep's sample count supports
  (serve.metrics.gate_percentile: p50 below 20 samples) — the summaries'
  percentiles are nearest-rank observed samples, never interpolated.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.metrics import gate_percentile

NOISE_MARGIN = 1.05


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    headline = json.load(open(argv[1]))
    ab = json.load(open(argv[2]))

    failures = []
    # The effective bucket set is read off the record, which the sweep read
    # off the engine — the gate re-declares nothing. Every arm must have
    # compiled exactly one program per effective bucket at warmup.
    buckets = headline.get("buckets")
    if not buckets:
        failures.append(f"{argv[1]}: record carries no engine-surfaced "
                        f"bucket set")
    for name, r in headline.get("policies", {}).items():
        if r["recompiles_after_warmup"] > 0:
            failures.append(
                f"{argv[1]}: policy {name} recompiled after warmup "
                f"({r['recompiles_after_warmup']} extra traces)")
        if buckets and r.get("buckets") != buckets:
            failures.append(
                f"{argv[1]}: policy {name} served buckets {r.get('buckets')}"
                f" != the record's engine-surfaced set {buckets}")
        if buckets and r.get("compiles") != len(buckets):
            failures.append(
                f"{argv[1]}: policy {name} compiled {r.get('compiles')} "
                f"programs for {len(buckets)} effective buckets {buckets}")
    if ab.get("recompiles_after_warmup", 1) > 0:
        failures.append(f"{argv[2]}: A/B engines recompiled after warmup")

    ratio_ab = ab.get("frozen_vs_live")
    if ratio_ab is None:
        failures.append(f"{argv[2]} is not an --ab-freeze record")
    else:
        print(f"freeze A/B ({ab.get('policy')}): frozen "
              f"{ab['frozen_latency_s'] * 1e3:.2f} ms vs live "
              f"{ab['live_latency_s'] * 1e3:.2f} ms ({ratio_ab:.3f}x)")
        if ratio_ab > NOISE_MARGIN:
            failures.append(
                f"frozen shiftadd is slower than unfrozen "
                f"({ratio_ab:.3f}x > {NOISE_MARGIN}x noise margin)")

    ratio = headline.get("shiftadd_vs_dense_latency")
    if not headline.get("frozen", False):
        failures.append("headline record must be the frozen arm")
    if ratio is None:
        failures.append("headline record has no shiftadd_vs_dense_latency "
                        "(dense or shiftadd arm missing from the sweep)")
    else:
        # Gate at the percentile the sweep's sample count supports (p50 at
        # the CI iters counts — nearest-rank observed samples, not the old
        # interpolated-p99 noise; serve.metrics.gate_percentile).
        pols = headline["policies"]
        d_lat = pols.get("dense", {}).get("latency")
        s_lat = pols.get("shiftadd", {}).get("latency")
        if d_lat and s_lat:
            key = gate_percentile(min(d_lat["n"], s_lat["n"]))
            ratio = (s_lat[key] / d_lat[key] if d_lat[key] else ratio)
        else:
            key = "latency_s_per_batch"
        print(f"headline shiftadd vs dense at {key}: {ratio:.3f}x "
              f"(frozen={headline.get('frozen')})")
        if ratio > 1.0:
            failures.append(f"frozen shiftadd is not at-or-below dense "
                            f"latency at {key} ({ratio:.3f}x > 1.0)")

    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("freeze gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
