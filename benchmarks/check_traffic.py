"""CI gate for the traffic-serving benchmark (vit-traffic job).

    python benchmarks/check_traffic.py BENCH_traffic.json

Fails (exit 1) if, on the calibrated default-load trace:
- any policy arm recompiled a bucket program after warmup,
- any policy arm missed a deadline or shed a request (the default load is
  calibrated to be feasible — misses there are scheduler bugs, not
  tightness; the virtual clock makes this machine-independent),
- the shiftadd arm's per-request p99 exceeds the dense arm's on the same
  trace (the serving-level restatement of the paper's latency crossover),
- a replay-verification field is present and false (routing or logits
  failed to reproduce bit-identically under the same seed).
"""
from __future__ import annotations

import json
import sys


def main(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    rec = json.load(open(argv[1]))
    failures = []
    for name, r in rec.get("policies", {}).items():
        if r["recompiles_after_warmup"] > 0:
            failures.append(f"{name}: recompiled after warmup "
                            f"({r['recompiles_after_warmup']} extra traces)")
        if r["deadline_miss_rate"] > 0:
            failures.append(f"{name}: deadline-miss rate "
                            f"{r['deadline_miss_rate']:.4f} > 0 at the "
                            f"calibrated default load")
        if r["shed_requests"] > 0:
            failures.append(f"{name}: {r['shed_requests']} requests shed at "
                            f"the calibrated default load")
        for key in ("replay_identical_routing",
                    "replay_bit_identical_logits"):
            if key in r and not r[key]:
                failures.append(f"{name}: {key} is false — the seeded trace "
                                f"did not replay deterministically")
        print(f"{name:>9}: p99 {r['latency']['p99_s'] * 1e3:.1f} ms  "
              f"miss {r['deadline_miss_rate']:.3f}  "
              f"recompiles {r['recompiles_after_warmup']}")
    ratio = rec.get("shiftadd_vs_dense_p99")
    if ratio is None:
        failures.append("record has no shiftadd_vs_dense_p99 "
                        "(dense or shiftadd arm missing)")
    else:
        print(f"shiftadd vs dense p99: {ratio:.3f}x")
        if ratio > 1.0:
            failures.append(f"shiftadd p99 above dense p99 on the same "
                            f"trace ({ratio:.3f}x > 1.0)")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("traffic gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
