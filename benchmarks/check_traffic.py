"""CI gate for the traffic-serving benchmark (vit-traffic / vit-router jobs).

    python benchmarks/check_traffic.py BENCH_traffic.json

Fails (exit 1) if, on the calibrated default-load trace:
- any policy arm recompiled a bucket program after warmup,
- any policy arm missed a deadline or shed a request (the default load is
  calibrated to be feasible — misses there are scheduler bugs, not
  tightness; the virtual clock makes this machine-independent),
- the shiftadd arm's per-request latency exceeds the dense arm's on the
  same trace at the percentile the sample count supports (the serving-level
  restatement of the paper's latency crossover). The gate percentile comes
  from serve.metrics.gate_percentile(n): p99 only when the trace has >= 100
  served requests, p95 at >= 20, else p50 — gating p99 at small n compared
  extrapolated noise (satellite bugfix; percentiles are now nearest-rank
  observed samples),
- the telemetry-trained `router` arm is missing, its latency exceeds the
  analytic shiftadd arm's at the gate percentile, or its shift-expert token
  share did not INCREASE over the analytic router's — the paper's §4.2
  claim (router trained on real latencies sends more tokens to the cheap
  expert and p99 does not regress), served and gated,
- a replay/1-vs-N verification field is false, OR is MISSING from an MoE
  arm (shiftadd or router). MoE arms used to be silently exempt: before
  the per-image capacity dispatch their logits depended on co-batching,
  the bench could not verify them, and the gate's `if key in record` let
  the absence pass. Batch invariance (ISSUE 5) makes the determinism gates
  policy-complete — the retrained router rides the same per-image capacity
  dispatch, so it inherits the strict gate — and an absent field on an MoE
  arm means the benchmark did not verify what this gate exists to verify.

Verification fields: `replay_identical_routing` /
`replay_bit_identical_logits` (same seed, same pool → same routing, same
bits) and `one_vs_n_bit_identical_logits` (same trace on a one-slot pool →
different batch compositions, same per-request bits).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.metrics import gate_percentile

VERIFY_KEYS = ("replay_identical_routing", "replay_bit_identical_logits",
               "one_vs_n_bit_identical_logits")

# Arms where a MISSING verification field is a failure, not a skip (the
# MoE arms the batch-invariance contract exists for).
STRICT_VERIFY_ARMS = ("shiftadd", "router")


def gate_record(rec, perf_gates=True) -> list:
    """All gate failures for one BENCH_traffic.json record (prints the
    per-arm summary lines as it goes).

    perf_gates=False drops the shiftadd-vs-dense crossover failure (ratio
    still printed) — the harness smoke runs at 16px/d=32 where dense wins
    on raw speed and only the determinism + router-behavior gates are
    meaningful; the CLI path (CI, real geometry) always gates it. The
    router-vs-shiftadd gates stay on either way: the router arm shares the
    shiftadd service model whenever their capacity plans agree, so its
    latency gate is deterministic at any geometry.
    """
    failures = []
    for name, r in rec.get("policies", {}).items():
        if r["recompiles_after_warmup"] > 0:
            failures.append(f"{name}: recompiled after warmup "
                            f"({r['recompiles_after_warmup']} extra traces)")
        if r["deadline_miss_rate"] > 0:
            failures.append(f"{name}: deadline-miss rate "
                            f"{r['deadline_miss_rate']:.4f} > 0 at the "
                            f"calibrated default load")
        if r["shed_requests"] > 0:
            failures.append(f"{name}: {r['shed_requests']} requests shed at "
                            f"the calibrated default load")
        for key in VERIFY_KEYS:
            if key not in r:
                if name in STRICT_VERIFY_ARMS:
                    failures.append(
                        f"{name}: {key} missing — the benchmark did not "
                        f"run the determinism verification on the MoE arm "
                        f"(the batch-invariance gate may not be skipped)")
            elif not r[key]:
                failures.append(f"{name}: {key} is false — per-request "
                                f"logits are not deterministic/"
                                f"batch-invariant under this arm")
        total_requests = rec.get("trace", {}).get("requests")
        if "one_vs_n_bit_identical_logits" in r and (
                r.get("one_vs_n_solo_shed", 0) > 0
                or (total_requests is not None
                    and r.get("one_vs_n_compared") != total_requests)):
            # A partial verification must not impersonate a full one: every
            # request of the trace must appear in BOTH runs' logits (the
            # solo pool serves with an unbounded queue precisely so nothing
            # is shed; a logits-collection or reassembly regression would
            # also shrink the compared count and land here).
            failures.append(
                f"{name}: 1-vs-N verification was partial — "
                f"{r.get('one_vs_n_compared', '?')} of "
                f"{total_requests} requests compared "
                f"(solo pool shed {r.get('one_vs_n_solo_shed', '?')})")
        labels = {"replay_identical_routing": "routing",
                  "replay_bit_identical_logits": "replay",
                  "one_vs_n_bit_identical_logits": "1vsN"}
        print(f"{name:>9}: p99 {r['latency']['p99_s'] * 1e3:.1f} ms  "
              f"miss {r['deadline_miss_rate']:.3f}  "
              f"recompiles {r['recompiles_after_warmup']}  "
              f"verify [" + " ".join(
                  f"{labels[k]}={r.get(k, 'absent')}"
                  for k in VERIFY_KEYS) + "]")
    pols = rec.get("policies", {})
    if "dense" not in pols or "shiftadd" not in pols:
        failures.append("record has no dense+shiftadd pair "
                        "(crossover cannot be gated)")
    else:
        # Gate at the percentile the sample count supports — p99 of a
        # 40-request smoke trace is just the max of the tail and flaps.
        d_lat, s_lat = pols["dense"]["latency"], pols["shiftadd"]["latency"]
        key = gate_percentile(min(d_lat["n"], s_lat["n"]))
        ratio = s_lat[key] / d_lat[key] if d_lat[key] else float("inf")
        print(f"shiftadd vs dense {key[:-2]}: {ratio:.3f}x "
              f"(n={min(d_lat['n'], s_lat['n'])}, gate key {key})")
        if perf_gates and ratio > 1.0:
            failures.append(f"shiftadd {key[:-2]} above dense on the same "
                            f"trace ({ratio:.3f}x > 1.0)")
    if "router" not in pols:
        failures.append("record has no router arm — the telemetry-trained "
                        "router was not served (ROADMAP item-3 gate)")
    elif "shiftadd" in pols:
        ro, sa = pols["router"], pols["shiftadd"]
        ro_lat, sa_lat = ro["latency"], sa["latency"]
        key = gate_percentile(min(ro_lat["n"], sa_lat["n"]))
        ratio = ro_lat[key] / sa_lat[key] if sa_lat[key] else float("inf")
        ro_share = ro.get("expert_token_share", {}).get("shift")
        sa_share = sa.get("expert_token_share", {}).get("shift")
        src = ro.get("expert_latency_source", "absent")
        print(f"router vs shiftadd {key[:-2]}: {ratio:.3f}x  "
              f"shift share {sa_share} → {ro_share}  (alpha {src}"
              + (f", service model shared with "
                 f"{ro['service_model_shared_with']}"
                 if "service_model_shared_with" in ro else "") + ")")
        if ratio > 1.0:
            failures.append(f"router {key[:-2]} above the analytic shiftadd "
                            f"arm on the same trace ({ratio:.3f}x > 1.0) — "
                            f"telemetry training must not regress latency")
        if ro_share is None or sa_share is None:
            failures.append("expert_token_share missing on the router or "
                            "shiftadd arm — the share gate cannot run")
        elif ro_share <= sa_share:
            failures.append(
                f"router shift-expert token share did not increase "
                f"({sa_share:.3f} → {ro_share:.3f}) — the latency-aware "
                f"loss should move tokens toward the cheap expert")
    return failures


def main(rows) -> None:
    """benchmarks/run.py harness mode: tiny verified record, gate verdict."""
    import time

    try:
        from benchmarks import bench_traffic
    except ImportError:          # standalone: benchmarks/ is sys.path[0]
        import bench_traffic

    t0 = time.time()
    rec = bench_traffic.run(requests=60, image_size=16, layers=2, d_model=32,
                            router_steps=20, verify_replay=True,
                            verify_one_vs_n=True)
    failures = gate_record(rec, perf_gates=False)
    rows.append(("traffic_gate", (time.time() - t0) * 1e6,
                 f"failures={len(failures)}"))


def cli(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    rec = json.load(open(argv[1]))
    failures = gate_record(rec)
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("traffic gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(cli(sys.argv))
