"""CI gate for the elastic-serving benchmark (vit-elastic job).

    python benchmarks/check_elastic.py BENCH_elastic.json

Fails (exit 1) unless the diurnal overload scenario shows exactly the
story the control plane exists to tell:

- the FIXED baseline missed deadlines (miss rate > 0 recorded) — the
  trace genuinely overloads min-replicas at the peak; a feasible trace
  would make the elastic arm's zero-miss vacuous,
- the ELASTIC arm missed ZERO deadlines and shed ZERO requests across
  that same peak, the injected kill, and the injected straggler,
- ZERO recompiles after warmup across BOTH arms and BOTH pools (primary
  + degrade), every reserve engine counted — the warm-pool invariant:
  no scale-up, scale-down, kill, straggler eviction, or recovery may
  trace a program,
- the machinery was actually exercised: at least one scale-up, the kill
  fired (kills >= 1), a replacement was attached after it
  (scale_ups + recoveries >= 2 when faults are present), the straggler
  was evicted, and at least one request degraded to the cheap arm,
- seeded replay reproduced the full elastic signature (routing incl.
  arm, scaling timeline, fault firings, degradation decisions) and every
  logit bit for bit — missing replay fields fail, absence is not a pass.
"""
from __future__ import annotations

import json
import sys

REPLAY_KEYS = ("replay_identical_events", "replay_bit_identical_logits")


def gate_record(rec):
    """Pure gate: record → list of failure strings (empty = pass)."""
    failures = []
    base, ela = rec.get("baseline"), rec.get("elastic")
    if not base or not ela:
        return ["record has no baseline+elastic pair"]

    if base["deadline_miss_rate"] <= 0:
        failures.append(
            "baseline miss rate is 0 — the trace does not overload the "
            "fixed min-replica pool, so the elastic zero-miss result is "
            "vacuous (raise --utilization)")
    if ela["deadline_miss_rate"] > 0:
        failures.append(f"elastic miss rate "
                        f"{ela['deadline_miss_rate']:.4f} > 0 — the control "
                        f"plane failed to absorb the peak/faults")
    if ela["shed_requests"] > 0:
        failures.append(f"elastic arm shed {ela['shed_requests']} requests "
                        f"— degradation should absorb overflow, not drop it")
    total_recompiles = rec.get("recompiles_after_warmup",
                               base["recompiles_after_warmup"]
                               + ela["recompiles_after_warmup"])
    if total_recompiles > 0:
        failures.append(f"{total_recompiles} recompiles after warmup — a "
                        f"scale/failure/degradation event traced a program "
                        f"(warm-pool invariant broken)")

    if ela["scale_ups"] < 1:
        failures.append("no scale-ups — the autoscaler never grew the pool")
    if ela["degraded_requests"] < 1:
        failures.append("no degraded requests — the saturation ladder never "
                        "engaged")
    faults_planned = rec.get("faults", [])
    if faults_planned:
        if ela["kills"] < 1:
            failures.append("a kill was scheduled but never fired")
        if any(f["kind"] == "slowdown" for f in faults_planned) \
                and ela["straggler_evictions"] < 1:
            failures.append("a slowdown was scheduled but the straggler "
                            "monitor never evicted the replica")
        if ela["scale_ups"] + ela["recoveries"] < 2:
            failures.append("no warm-pool re-admission after the fault "
                            "(scale_ups + recoveries < 2)")

    for key in REPLAY_KEYS:
        if key not in rec:
            failures.append(f"{key} missing — the benchmark did not verify "
                            f"replay (determinism gates may not be skipped)")
        elif not rec[key]:
            failures.append(f"{key} is false — the elastic run is not "
                            f"deterministic under replay")
    return failures


def main(rows) -> None:
    """benchmarks/run.py harness mode: tiny verified record, gate verdict."""
    import time

    try:
        from benchmarks import bench_elastic
    except ImportError:          # standalone: benchmarks/ is sys.path[0]
        import bench_elastic

    t0 = time.time()
    rec = bench_elastic.run(requests=60, image_size=16, layers=2, d_model=32,
                            buckets=(1, 2, 4), verify_replay=True)
    failures = gate_record(rec)
    rows.append(("elastic_gate", (time.time() - t0) * 1e6,
                 f"failures={len(failures)}"))


def cli(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    rec = json.load(open(argv[1]))
    failures = gate_record(rec)
    base, ela = rec.get("baseline"), rec.get("elastic")
    if base and ela:
        for arm, r in (("baseline", base), ("elastic", ela)):
            print(f"{arm:>9}: p99 {r['latency']['p99_s'] * 1e3:.1f} ms  "
                  f"miss {r['deadline_miss_rate']:.3f}  "
                  f"shed {r['shed_requests']}  "
                  f"recompiles {r['recompiles_after_warmup']}")
        print(f"  elastic: ups {ela['scale_ups']} downs {ela['scale_downs']} "
              f"kills {ela['kills']} evictions {ela['straggler_evictions']} "
              f"recoveries {ela['recoveries']} "
              f"degraded {ela['degraded_requests']} "
              f"max_active {ela['max_active']}  replay [" + " ".join(
                  f"{k.split('_', 1)[1]}={rec.get(k, 'absent')}"
                  for k in REPLAY_KEYS) + "]")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("elastic gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(cli(sys.argv))
