"""CI gate wrapper for the serving-contract static analyzer (static-analysis
job), and the analyzer's row in benchmarks/run.py's rows contract.

    python benchmarks/check_analysis.py [TABLE_PATH]

Standalone: runs `repro.analysis.check` with --fail-on-findings (exit 1 on
any active finding), writing the kernel × geometry contract table artifact
to TABLE_PATH (default: the CLI's artifacts/analysis/ location).

As a harness module: `main(rows)` appends one row per pass —
(analysis_<pass>, wall-us, finding/cell counts) — so the analyzer's cost and
coverage ride along the benchmark CSV like every other check script.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(rows) -> None:
    from repro.analysis import check as acheck
    from repro.analysis.findings import split_allowlisted

    for name in acheck.PASSES:
        t0 = time.time()
        findings, info = acheck.run_passes((name,))
        active, waived = split_allowlisted(findings)
        us = (time.time() - t0) * 1e6
        derived = f"findings={len(active)} waived={len(waived)}"
        if name == "kernels":
            rowset = info["contract_rows"]
            derived += (f" cells={len(rowset)} overflow="
                        f"{sum(c.classification == 'vmem_overflow' for c in rowset)}")
        elif name == "jaxpr":
            derived += f" programs={len(info['audited_programs'])}"
        else:
            derived += f" files={info['linted_files']}"
        rows.append((f"analysis_{name}", us, derived))


if __name__ == "__main__":
    from repro.analysis import check as acheck

    argv = ["--fail-on-findings"]
    if len(sys.argv) > 1:
        argv += ["--table", sys.argv[1]]
    sys.exit(acheck.main(argv))
